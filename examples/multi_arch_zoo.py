"""Architecture-zoo tour (deliverable b/f): instantiate every assigned architecture
(reduced variant), run a forward + one CoCoDC round on each, and decode a few
tokens — demonstrating that the protocol layer is architecture-agnostic
(fragments are slices of whatever the layer stack is).

    PYTHONPATH=src python examples/multi_arch_zoo.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, CoCoDCConfig, get_config
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.models import api


def main():
    print(f"{'arch':28s} {'family':8s} {'params':>9s} {'loss0':>7s} "
          f"{'loss_end':>8s} {'syncs':>5s} {'decode':>7s}")
    for arch in ARCH_IDS:
        t0 = time.time()
        mcfg = get_config(arch).reduced()
        ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                            overlap_depth=2)
        tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                             total_steps=16, warmup_steps=4, inner_lr=3e-3,
                             eval_batch=4)
        tr = CrossRegionTrainer(mcfg, ccfg, tcfg)
        loss0 = tr.train_one_step()
        for _ in range(15):
            loss_end = tr.train_one_step()
        # decode three tokens from the consensus model
        cache = api.init_cache(mcfg, 1, 8)
        toks = jnp.zeros((1,), jnp.int32)
        for _ in range(3):
            logits, cache = api.decode_step(mcfg, tr.engine.theta_g, cache, toks)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        n = api.param_count(tr.engine.theta_g)
        print(f"{arch:28s} {mcfg.family:8s} {n/1e6:8.2f}M {loss0:7.3f} "
              f"{loss_end:8.3f} {tr.engine.n_syncs:5d} "
              f"{'ok':>7s}  ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
