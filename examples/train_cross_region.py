"""End-to-end driver (deliverable b): train a ~100M-class model for a few hundred
steps across 4 simulated datacenters with the full stack — non-IID data pipeline,
worker-stacked AdamW, CoCoDC protocol engine, consensus evaluation, checkpointing.

By default runs the paper's 150M config at a CPU-tractable sequence length; pass
--full-model to use the exact paper shape (needs a real accelerator for speed).

    PYTHONPATH=src python examples/train_cross_region.py --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--method", default="cocodc")
    ap.add_argument("--full-model", action="store_true")
    args = ap.parse_args()
    argv = [
        "--arch", "paper_150m",
        "--method", args.method,
        "--steps", str(args.steps),
        "--workers", "4",
        "--H", "100", "--fragments", "4", "--tau", "5",
        "--local-batch", "4", "--seq-len", "64",
        "--eval-every", "50",
        "--ckpt", f"checkpoints/{args.method}_paper150m.msgpack",
        "--history-out", f"experiments/train_{args.method}.json",
    ]
    if not args.full_model:
        argv.append("--reduced")
        argv.extend(["--lr", "3e-3"])
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
