"""End-to-end driver (deliverable b): train a ~100M-class model for a few hundred
steps across 4 simulated datacenters with the full stack — non-IID data pipeline,
worker-stacked AdamW, CoCoDC protocol engine, consensus evaluation, checkpointing.

By default runs the paper's 150M config at a CPU-tractable sequence length on
the calibrated symmetric network; pass --full-model to use the exact paper
shape (needs a real accelerator for speed), or a heterogeneous WAN scenario:

    PYTHONPATH=src python examples/train_cross_region.py --steps 300
    PYTHONPATH=src python examples/train_cross_region.py --topology asym4 \
        --steps 200          # asymmetric 4-region mesh + per-link stats
    PYTHONPATH=src python examples/train_cross_region.py \
        --topology hub_spoke --steps 200   # hierarchical all-reduce via a hub
    PYTHONPATH=src python examples/train_cross_region.py --mesh random_geo \
        --workers 8 --dynamics 'diurnal:depth=0.6,hub_failure:start=80:dur=40' \
        --steps 200          # generated 8-region mesh on time-varying links

Runs are defined by a declarative ExperimentSpec (repro.api) and built through
`build_experiment`; pass --print-spec to see the spec this example's flags map
onto, and replay it later with `repro.launch.train --spec <file>`.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.network import MESH_PROFILES, SCENARIOS
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--method", default="cocodc")
    ap.add_argument("--topology", default=None, choices=sorted(SCENARIOS),
                    help="heterogeneous WAN scenario (e.g. asym4 = asymmetric "
                         "4-region mesh with transpacific bottleneck)")
    ap.add_argument("--mesh", default=None, choices=sorted(MESH_PROFILES),
                    help="generated N-region mesh (N = --workers)")
    ap.add_argument("--mesh-seed", type=int, default=0)
    ap.add_argument("--dynamics", default=None,
                    help="time-varying link spec, e.g. "
                         "'diurnal:depth=0.6,hub_failure:start=80:dur=40'")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--engine-impl", default="jit", choices=["jit", "host"])
    ap.add_argument("--loop", default="segment", choices=["segment", "per_step"],
                    help="segment-scanned execution engine vs per-step loop")
    ap.add_argument("--link-pricing", action="store_true")
    ap.add_argument("--routing", default="static",
                    choices=["static", "routed"],
                    help="routed multi-hop communication plans over the "
                         "current link state")
    ap.add_argument("--hub-failover", action="store_true",
                    help="with --routing routed: re-elect the hub while the "
                         "declared one's links are out")
    ap.add_argument("--adaptive-resync", action="store_true",
                    help="re-derive Eq. 9's N per round from measured T_s")
    ap.add_argument("--resume", default=None,
                    help="trainer_state_v1 checkpoint to continue from")
    ap.add_argument("--print-spec", action="store_true",
                    help="print the composed ExperimentSpec JSON and exit")
    ap.add_argument("--full-model", action="store_true")
    args = ap.parse_args()
    net_tag = args.mesh and f"{args.mesh}{args.workers}" or args.topology
    tag = args.method if net_tag is None else f"{args.method}_{net_tag}"
    argv = [
        "--arch", "paper_150m",
        "--method", args.method,
        "--steps", str(args.steps),
        "--workers", str(args.workers),
        "--H", "100", "--fragments", "4", "--tau", "5",
        "--local-batch", "4", "--seq-len", "64",
        "--eval-every", "50",
        "--engine-impl", args.engine_impl,
        "--loop", args.loop,
        "--ckpt", f"checkpoints/{tag}_paper150m.msgpack",
        "--history-out", f"experiments/train_{tag}.json",
    ]
    if args.topology:
        argv.extend(["--topology", args.topology])
    if args.mesh:
        argv.extend(["--mesh", args.mesh, "--mesh-seed", str(args.mesh_seed)])
    if args.dynamics:
        argv.extend(["--dynamics", args.dynamics])
    if args.resume:
        argv.extend(["--resume", args.resume])
    if args.link_pricing:
        argv.append("--link-pricing")
    if args.routing != "static":
        argv.extend(["--routing", args.routing])
    if args.hub_failover:
        argv.append("--hub-failover")
    if args.adaptive_resync:
        argv.append("--adaptive-resync")
    if args.print_spec:
        argv.append("--print-spec")
    if not args.full_model:
        argv.append("--reduced")
        argv.extend(["--lr", "3e-3"])
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
