"""Batched serving example (deliverable b): prefill a batch of prompts through a
small dense model, then decode continuations with the ring-buffer KV cache —
the same serve_step the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api, transformer


def main():
    cfg = get_config("qwen3_0_6b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len, gen_len = 4, 24, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len), 0,
                                 cfg.vocab)

    t0 = time.time()
    logits, cache = transformer.prefill(cfg, params, {"tokens": prompts},
                                        cache_len=prompt_len + gen_len)
    print(f"prefill: batch={B} len={prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [toks]
    t0 = time.time()
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(toks)
    gen = jnp.stack(outs, axis=1)
    dt = time.time() - t0
    print(f"decoded {gen_len} tokens x {B} seqs in {dt:.2f}s "
          f"({B*gen_len/dt:.1f} tok/s on CPU)")
    for b in range(B):
        print(f"  seq{b}: {list(map(int, gen[b]))}")


if __name__ == "__main__":
    main()
