"""Batched serving example (deliverable b): prefill a batch of prompts through a
small dense model, then decode continuations with the ring-buffer KV cache —
the same serve_step the decode_32k / long_500k dry-run shapes lower. Then the
same prompts again through the continuous-batching `ServeEngine` (slotted KV
cache, requests joining/leaving with zero recompiles).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api, transformer
from repro.serve import Request, ServeEngine


def main():
    cfg = get_config("qwen3_0_6b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len, gen_len = 4, 24, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len), 0,
                                 cfg.vocab)

    t0 = time.time()
    logits, cache = transformer.prefill(cfg, params, {"tokens": prompts},
                                        cache_len=prompt_len + gen_len)
    print(f"prefill: batch={B} len={prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [toks]
    t0 = time.time()
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(toks)
    gen = jnp.stack(outs, axis=1)
    dt = time.time() - t0
    print(f"decoded {gen_len} tokens x {B} seqs in {dt:.2f}s "
          f"({B*gen_len/dt:.1f} tok/s on CPU)")
    for b in range(B):
        print(f"  seq{b}: {list(map(int, gen[b]))}")

    # same prompts through the continuous-batching engine: staggered
    # arrivals, chunked prefill interleaved with decode, one traced step
    eng = ServeEngine(cfg, params, n_slots=B, cache_len=prompt_len + gen_len,
                      max_prompt=prompt_len, prefill_chunk=8,
                      mode="continuous", temperature=0.0)
    reqs = [Request(rid=b, prompt=np.asarray(prompts[b]),
                    max_new_tokens=gen_len, arrival_s=0.05 * b)
            for b in range(B)]
    recs = eng.run_trace(reqs)
    s = eng.stats()
    print(f"engine: {s['tok_per_s']:.1f} tok/s (virtual), occupancy "
          f"{s['occupancy']:.2f}, decode traced {eng.decode_trace_count()}x")
    for rec in recs:
        match = "==" if rec.tokens == list(map(int, gen[rec.rid])) else "!="
        print(f"  req{rec.rid}: ttft {rec.ttft_s*1e3:.0f}ms, greedy tokens "
              f"{match} lock-step")


if __name__ == "__main__":
    main()
