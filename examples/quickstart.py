"""Quickstart: train a tiny LLaMA-style model across 4 simulated datacenters with
CoCoDC (communication-computation overlap + delay compensation) and compare the
consensus-model perplexity against plain DiLoCo.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import CoCoDCConfig, get_config
from repro.core.trainer import CrossRegionTrainer, TrainerConfig

STEPS = 120


def run(method: str):
    mcfg = get_config("paper_150m").reduced()   # CPU-friendly variant
    ccfg = CoCoDCConfig(num_workers=4, local_steps=20, num_fragments=4,
                        overlap_depth=3)
    tcfg = TrainerConfig(method=method, local_batch=4, seq_len=48,
                         total_steps=STEPS, warmup_steps=10, inner_lr=3e-3)
    tr = CrossRegionTrainer(mcfg, ccfg, tcfg)
    tr.run(eval_every=30, log=lambda s: print("  " + s))
    final = tr.history[-1]
    stats = tr.engine.stats()
    return final, stats


def main():
    print("== CoCoDC quickstart: 4 simulated DCs, H=20 local steps, tau=3 ==")
    results = {}
    for method in ("diloco", "cocodc"):
        print(f"-- {method} --")
        final, stats = run(method)
        results[method] = (final, stats)
    print("\nmethod    final_ppl   sim_wall_clock   comm_hidden")
    for method, (final, stats) in results.items():
        hidden = "yes (overlapped)" if method == "cocodc" else "no (blocking)"
        print(f"{method:9s} {final['ppl']:9.2f}   {stats['wall_clock_s']:10.0f}s"
              f"   {hidden}")
    d, c = results["diloco"], results["cocodc"]
    speedup = d[1]["wall_clock_s"] / c[1]["wall_clock_s"]
    print(f"\nCoCoDC simulated wall-clock speedup over DiLoCo: {speedup:.2f}x "
          f"(comm fully hidden under compute)")


if __name__ == "__main__":
    main()
