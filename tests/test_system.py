"""End-to-end system tests: the full cross-region training stack converges and
behaves per the paper's claims (scaled down), checkpoints round-trip, and the
sharded step functions lower on a CPU debug mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CoCoDCConfig, get_config
from repro.configs.base import ModelConfig
from repro.core.trainer import CrossRegionTrainer, TrainerConfig

TINY = ModelConfig(name="sys-tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=1, d_ff=128, vocab=256,
                   compute_dtype="float32")


def make_trainer(method: str, steps: int = 60, **ccfg_kw):
    ccfg = CoCoDCConfig(num_workers=2, local_steps=12, num_fragments=2,
                        overlap_depth=3, **ccfg_kw)
    tcfg = TrainerConfig(method=method, local_batch=2, seq_len=24,
                         total_steps=steps, warmup_steps=6, inner_lr=3e-3,
                         eval_batch=4)
    return CrossRegionTrainer(TINY, ccfg, tcfg)


@pytest.mark.parametrize("method", ["diloco", "streaming", "cocodc"])
def test_method_trains_and_improves(method):
    tr = make_trainer(method, steps=60)
    tr.run(eval_every=30, log=lambda s: None)
    first, last = tr.history[0], tr.history[-1]
    assert last["nll"] < first["nll"] + 0.05  # no divergence
    assert np.isfinite(last["nll"])
    st = tr.engine.stats()
    assert st["n_syncs"] > 0
    if method != "diloco":
        assert st["overlap_ratio"] > 0  # comm hidden under compute


def test_cocodc_consensus_tracks_workers():
    """After training, the consensus model's loss is in the same regime as the
    workers' train loss (the outer loop actually aggregates)."""
    tr = make_trainer("cocodc", steps=48)
    tr.run(eval_every=48, log=lambda s: None)
    ev = tr.evaluate()
    assert ev["nll"] < 6.0  # well below random (ln 256 = 5.55) after warmup


def test_protocol_state_checkpoint_roundtrip(tmp_path):
    import os
    from repro.checkpoint import load_pytree, save_pytree
    tr = make_trainer("cocodc", steps=30)
    tr.run(steps=30, eval_every=30, log=lambda s: None)
    path = os.path.join(tmp_path, "state.msgpack")
    save_pytree(path, {"theta_g": tr.engine.theta_g,
                       "momentum": tr.engine.momentum,
                       "step": tr.step})
    out = load_pytree(path)
    assert out["step"] == 30
    for a, b in zip(jax.tree.leaves(out["theta_g"]),
                    jax.tree.leaves(tr.engine.theta_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_sharded_train_step_lowers_on_debug_mesh():
    """The production step functions lower+compile on the 1-chip debug mesh —
    the cheap CI version of the dry-run."""
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_debug_mesh
    from repro.configs import INPUT_SHAPES
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3_0_6b").reduced(), name="dbg")
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64,
                                global_batch=2)
    mesh = make_debug_mesh()
    sds = steps_lib.input_specs(cfg, shape)
    shards = steps_lib.shardings_for(cfg, shape, mesh)
    with mesh:
        fn = steps_lib.make_train_step(cfg)
        compiled = jax.jit(fn, in_shardings=(
            shards["params"], shards["opt_state"], shards["batch"], shards["lr"]
        )).lower(sds["params"], sds["opt_state"], sds["batch"], sds["lr"]).compile()
    assert compiled.cost_analysis() is not None


def test_serve_step_lowers_on_debug_mesh():
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_debug_mesh
    from repro.configs import INPUT_SHAPES
    import dataclasses
    cfg = get_config("rwkv6_3b").reduced()
    shape = dataclasses.replace(INPUT_SHAPES["decode_32k"], seq_len=64,
                                global_batch=2)
    mesh = make_debug_mesh()
    sds = steps_lib.input_specs(cfg, shape)
    shards = steps_lib.shardings_for(cfg, shape, mesh)
    with mesh:
        fn = steps_lib.make_serve_step(cfg)
        compiled = jax.jit(fn, in_shardings=(
            shards["params"], shards["cache"], shards["tokens"]
        )).lower(sds["params"], sds["cache"], sds["tokens"]).compile()
    assert compiled is not None


def test_paper_hyperparameters_flow():
    """Paper §IV settings produce the expected derived schedule: N=8, h=12."""
    tr = make_trainer("cocodc")
    # engine computed N from the calibrated network (T_s = tau*T_c)
    assert tr.engine.N >= tr.engine.K
    assert tr.engine.h_cocodc == max(1, tr.engine.H // tr.engine.N)


def test_wallclock_accounting_consistency():
    tr = make_trainer("cocodc", steps=36)
    tr.run(eval_every=36, log=lambda s: None)
    st = tr.engine.stats()
    # simulated wall clock = steps * t_c for fully-overlapped methods
    assert st["wall_clock_s"] == pytest.approx(36 * tr.network.t_c, rel=1e-6)
    assert st["bytes_sent"] > 0
