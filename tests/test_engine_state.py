"""Functional-engine refactor tests: EngineState pytree mechanics, golden-
trajectory parity between the jitted EngineState path and the eager host path,
contention-aware delivery, heterogeneous Topology cost models, per-link stats."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core import engine_state as es
from repro.core.fragments import make_fragmenter
from repro.core.network import (NetworkModel, Topology, as_topology,
                                four_region_asymmetric, hub_and_spoke,
                                make_scenario, paper_network)
from repro.core.protocol import ProtocolEngine
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.models import api

KEY = jax.random.PRNGKey(0)

TINY = ModelConfig(name="es-tiny", family="dense", n_layers=4, d_model=64,
                   n_heads=2, n_kv_heads=1, d_ff=128, vocab=128,
                   compute_dtype="float32")


def make_stack(M=2, cfg=TINY):
    params = api.init_params(cfg, KEY)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)


def engine_for(method, M=2, H=10, K=2, tau=2, network=None,
               engine_impl="jit", **ccfg_kw):
    ccfg = CoCoDCConfig(num_workers=M, local_steps=H, num_fragments=K,
                        overlap_depth=tau, **ccfg_kw)
    stack = make_stack(M)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, K)
    if network is None:
        network = paper_network(M, fragment_bytes=frag.total_bytes // K,
                                tau=tau)
    eng = ProtocolEngine(method, ccfg, frag, network, stack,
                         engine_impl=engine_impl)
    return eng, stack


def perturb(stack, scale=0.01):
    leaves, treedef = jax.tree.flatten(stack)
    out = []
    for i, l in enumerate(leaves):
        noise = jax.random.normal(jax.random.fold_in(KEY, 100 + i),
                                  l.shape) * scale
        out.append(l + noise.astype(l.dtype))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# EngineState pytree mechanics
# ---------------------------------------------------------------------------


def test_engine_state_is_pytree():
    eng, _ = engine_for("cocodc")
    leaves, treedef = jax.tree.flatten(eng.state)
    assert all(hasattr(l, "shape") for l in leaves)
    rt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rt, es.EngineState)
    # jit-transparent: a jitted identity-ish function accepts the state whole
    bumped = jax.jit(lambda s: dataclasses.replace(
        s, delta_norm=s.delta_norm + 1))(eng.state)
    np.testing.assert_allclose(np.asarray(bumped.delta_norm),
                               np.asarray(eng.state.delta_norm) + 1)


def test_engine_state_fixed_capacity_inflight():
    """In-flight payloads live in fixed-capacity stacked buffers, one slot per
    fragment; initiating marks the slot active, delivery clears it."""
    eng, stack = engine_for("cocodc", H=10, K=2, tau=2)
    stack = perturb(stack)
    assert not bool(np.any(np.asarray(eng.state.inflight_active)))
    stack = eng.on_step_end(0, stack)        # initiation at t=0
    active = np.asarray(eng.state.inflight_active)
    assert active.sum() == 1
    p = int(np.argmax(active))
    assert eng.in_flight[0].frag == p
    for t in range(1, 4):
        stack = eng.on_step_end(t, stack)    # delivery by t approx tau
    assert not np.asarray(eng.state.inflight_active)[p] or eng.n_syncs >= 1


def test_availability_mask_lives_in_state():
    eng, _ = engine_for("cocodc", M=2)
    eng.set_worker_availability(1, False)
    np.testing.assert_array_equal(np.asarray(eng.state.worker_available),
                                  [True, False])
    eng.set_worker_availability(1, True)
    np.testing.assert_array_equal(np.asarray(eng.state.worker_available),
                                  [True, True])


# ---------------------------------------------------------------------------
# golden-trajectory parity: jitted EngineState path == eager host path
# ---------------------------------------------------------------------------


def _golden_trainer(method, engine_impl, steps):
    mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                               compute_dtype="float32")
    ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                        overlap_depth=2)
    tcfg = TrainerConfig(method=method, local_batch=2, seq_len=16,
                         total_steps=steps, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, engine_impl=engine_impl)
    tr = CrossRegionTrainer(mcfg, ccfg, tcfg)
    tr.run(eval_every=8, log=lambda s: None)
    return tr


@pytest.mark.parametrize("method", ["diloco", "streaming", "cocodc"])
def test_golden_trajectory_jit_matches_host(method):
    """The jitted EngineState engine reproduces the eager (legacy host-side)
    engine step-for-step on the paper_150m config at toy scale: identical
    sync/bytes accounting, eval-NLL trace within 1e-5."""
    steps = 24
    tr_host = _golden_trainer(method, "host", steps)
    tr_jit = _golden_trainer(method, "jit", steps)

    s_host, s_jit = tr_host.engine.stats(), tr_jit.engine.stats()
    for k in ("bytes_sent", "n_syncs", "wall_clock_s", "comm_seconds",
              "target_syncs_N", "busiest_link_bytes"):
        assert s_host[k] == s_jit[k], f"stats[{k}] diverged: " \
                                      f"{s_host[k]} vs {s_jit[k]}"

    nll_host = [rec["nll"] for rec in tr_host.history]
    nll_jit = [rec["nll"] for rec in tr_jit.history]
    assert len(nll_host) == len(nll_jit) > 0
    np.testing.assert_allclose(nll_host, nll_jit, atol=1e-5)

    # consensus models agree leaf-for-leaf (jit-vs-eager fusion reorders f32
    # arithmetic, so allow the accumulated per-leaf drift a looser tolerance
    # than the observable NLL trace)
    for a, b in zip(jax.tree.leaves(tr_host.engine.theta_g),
                    jax.tree.leaves(tr_jit.engine.theta_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


# ---------------------------------------------------------------------------
# contention-aware delivery (the old fixed `t + tau` bug)
# ---------------------------------------------------------------------------


def test_contention_delays_delivery():
    """Back-to-back initiations on one WAN channel queue: the second fragment's
    effective overlap depth exceeds the first's by the queueing delay."""
    # t_s = 5 * t_c on a single channel; initiations at t=0 and t=1 (H=2,K=2
    # -> round-robin every step)
    stack = make_stack(2)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, 2)
    fb = frag.total_bytes // 2
    net = as_topology(paper_network(2, fragment_bytes=fb, tau=5))
    ccfg = CoCoDCConfig(num_workers=2, local_steps=2, num_fragments=2,
                        overlap_depth=5)
    eng = ProtocolEngine("streaming", ccfg, frag, net, stack)
    s = perturb(stack)
    s = eng.on_step_end(0, s)
    s = eng.on_step_end(1, s)
    evs = sorted(eng.in_flight, key=lambda e: e.t_init)
    assert len(evs) == 2
    depth0 = evs[0].deliver_at - evs[0].t_init
    depth1 = evs[1].deliver_at - evs[1].t_init
    assert depth1 > depth0, (depth0, depth1)
    # the queue shifts delivery by the channel-busy time, not just one step
    assert evs[1].finish_time > evs[0].finish_time


def test_concurrent_channels_remove_queueing():
    """Same schedule with 2 concurrent WAN channels: the second fragment no
    longer queues behind the first."""
    def second_depth(channels):
        stack = make_stack(2)
        shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
        frag = make_fragmenter(TINY, shape, 2)
        fb = frag.total_bytes // 2
        base = as_topology(paper_network(2, fragment_bytes=fb, tau=5))
        net = dataclasses.replace(base, concurrent_collectives=channels)
        ccfg = CoCoDCConfig(num_workers=2, local_steps=2, num_fragments=2,
                            overlap_depth=5)
        eng = ProtocolEngine("streaming", ccfg, frag, net, stack)
        s = perturb(stack)
        s = eng.on_step_end(0, s)
        s = eng.on_step_end(1, s)
        ev = sorted(eng.in_flight, key=lambda e: e.t_init)[1]
        return ev.deliver_at - ev.t_init

    assert second_depth(2) < second_depth(1)


def test_uncontended_delivery_matches_paper_tau():
    """On the calibrated symmetric network with a free channel, the derived
    delivery step reduces exactly to the paper's t + tau."""
    eng, stack = engine_for("streaming", H=10, K=2, tau=2)
    stack = perturb(stack)
    stack = eng.on_step_end(0, stack)
    ev = eng.in_flight[0]
    # fragment bytes differ slightly from the calibrated mean; allow +-1 step
    assert abs((ev.deliver_at - ev.t_init) - 2) <= 1


# ---------------------------------------------------------------------------
# heterogeneous topology cost models
# ---------------------------------------------------------------------------


def test_uniform_topology_matches_network_model():
    net = NetworkModel(num_workers=4, latency_s=0.1, bandwidth_Bps=1e9)
    topo = net.to_topology()
    for nbytes in (0, 1_000_000, 1_000_000_000):
        assert topo.allreduce_time(nbytes) == pytest.approx(
            net.allreduce_time(nbytes), rel=1e-9)


def test_ring_bottleneck_link_dominates():
    """One slow link paces every ring phase."""
    fast = Topology.uniform(4, latency_s=0.01, bandwidth_Bps=1e9)
    slow = fast.degrade_link(0, 1, bandwidth_factor=0.1, symmetric=False)
    n = 100_000_000
    t_fast = fast.allreduce_time(n)
    t_slow = slow.allreduce_time(n)
    assert t_slow > t_fast
    # phase time = max(lat + chunk/bw); slow link bw 1e8, chunk n/4
    expect = 2 * 3 * (0.01 + (n / 4) / 1e8)
    assert t_slow == pytest.approx(expect, rel=1e-9)


def test_hierarchical_collective_cost():
    topo = hub_and_spoke(4, spoke_latency_s=0.05, spoke_bandwidth_Bps=1e9)
    n = 10_000_000
    # gather + broadcast, each paced by identical spokes: 2 * (lat + n/bw)
    assert topo.allreduce_time(n) == pytest.approx(2 * (0.05 + n / 1e9),
                                                   rel=1e-9)
    lb = topo.link_bytes(n)
    # each spoke link carries the payload once per direction
    assert lb.sum() == pytest.approx(6 * n)
    assert lb[0, 0] == 0.0


def test_ring_link_bytes_conservation():
    topo = Topology.uniform(4, latency_s=0.01, bandwidth_Bps=1e9)
    n = 4_000_000
    lb = topo.link_bytes(n)
    # 4 directed ring links x 2(M-1)/M * n each
    assert lb.sum() == pytest.approx(4 * 2 * 3 / 4 * n)
    assert (lb > 0).sum() == 4


def test_asymmetric_scenario_shape_and_asymmetry():
    topo = four_region_asymmetric()
    assert topo.num_workers == 4
    assert not topo.is_symmetric
    assert topo.regions == ("us-east", "us-west", "eu-west", "ap-northeast")
    with pytest.raises(ValueError):
        make_scenario("asym4", num_workers=8)
    with pytest.raises(KeyError):
        make_scenario("nope")


def test_scenario_engine_produces_per_link_stats():
    """Acceptance: a heterogeneous 4-region run yields per-link transfer
    stats with region-named links and a busiest link."""
    topo = dataclasses.replace(four_region_asymmetric(),
                               step_time_s=1.0)
    eng, stack = engine_for("cocodc", M=4, H=8, K=2, tau=2, network=topo)
    stack = perturb(stack)
    for t in range(16):
        stack = eng.on_step_end(t, stack)
    assert eng.n_syncs > 0
    ls = eng.link_stats()
    assert ls["links"], "expected per-link traffic"
    assert ls["busiest_link"] in ls["links"]
    assert any("ap-northeast" in k for k in ls["links"])
    total = sum(rec["bytes"] for rec in ls["links"].values())
    # ring: every sync's wire bytes cross 4 links at 2(M-1)/M each
    assert total == pytest.approx(eng.bytes_sent * 4 * 2 * 3 / 4)


# ---------------------------------------------------------------------------
# unified bytes accounting (blocking DiLoCo vs overlapped)
# ---------------------------------------------------------------------------


def test_diloco_bytes_respect_wire_format():
    """The blocking DiLoCo branch now charges the same compressed wire bytes
    as the overlapped methods (bf16 halves, top-k scales by 2*frac)."""
    eng_raw, s = engine_for("diloco", H=5)
    eng_bf16, s2 = engine_for("diloco", H=5, sync_dtype="bfloat16")
    s, s2 = perturb(s), perturb(s2)
    for t in range(5):
        s = eng_raw.on_step_end(t, s)
        s2 = eng_bf16.on_step_end(t, s2)
    assert eng_raw.n_syncs == eng_bf16.n_syncs == 1
    assert eng_bf16.bytes_sent == eng_raw.bytes_sent // 2
    # and the blocking time shrinks with the payload
    assert eng_bf16.wall_clock < eng_raw.wall_clock


def test_link_pricing_prefers_cheap_fragment():
    """With link pricing on, equal rates tie-break to the cheaper fragment."""
    from repro.core.adaptive import AdaptiveState, select_fragment
    st = AdaptiveState(K=2, H=100)
    st.rate = [1.0, 1.0]
    st.last_sync = [0, 0]
    # fragment 1 is 10x cheaper to ship
    assert select_fragment(st, 10, costs=[10.0, 1.0]) == 1
    # without costs, ties resolve to the lowest index (Eq. 12 determinism)
    assert select_fragment(st, 10) == 0


# ---------------------------------------------------------------------------
# pseudograd_mean: sync_dtype cast + top-k sparsification paths
# ---------------------------------------------------------------------------


def _pg_inputs(M=3, shape=(4, 8)):
    stack = {"w": jax.random.normal(jax.random.fold_in(KEY, 1),
                                    (M,) + shape, jnp.float32)}
    theta = {"w": jax.random.normal(jax.random.fold_in(KEY, 2), shape,
                                    jnp.float32)}
    return stack, theta


def test_pseudograd_mean_sync_dtype_quantizes_the_wire():
    """The payload crosses the WAN in sync_dtype: deltas are CAST to bf16
    before averaging (a real quantization, not a no-op), and the result
    returns to f32 for the outer update."""
    stack, theta = _pg_inputs()
    mask = jnp.ones((3,), bool)
    out32 = es.pseudograd_mean(stack, theta, mask, sync_dtype="float32")
    out16 = es.pseudograd_mean(stack, theta, mask, sync_dtype="bfloat16")
    assert out16["w"].dtype == out32["w"].dtype == jnp.float32
    # bf16 wire values are exactly representable in bf16...
    as16 = out16["w"].astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(as16), np.asarray(out16["w"]))
    # ...and genuinely differ from the f32 wire (quantization happened)
    assert float(jnp.max(jnp.abs(out16["w"] - out32["w"]))) > 0.0
    # oracle: mean of the per-worker bf16 deltas
    d = (stack["w"] - theta["w"][None]).astype(jnp.bfloat16)
    want = (jnp.sum(d.astype(jnp.bfloat16)
                    * jnp.ones((3, 1, 1), jnp.bfloat16), axis=0)
            / jnp.bfloat16(3.0)).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out16["w"]), np.asarray(want),
                               rtol=1e-2, atol=1e-3)


def test_pseudograd_mean_topk_sparsifies_per_worker():
    """topk_frac keeps each worker's top |delta| entries: the averaged delta
    has at most M*k nonzeros, and the kept entries are exactly the per-worker
    magnitude-top-k (es.sparsify oracle)."""
    stack, theta = _pg_inputs(M=2, shape=(4, 8))
    mask = jnp.ones((2,), bool)
    frac = 0.25
    out = es.pseudograd_mean(stack, theta, mask, sync_dtype="float32",
                             topk_frac=frac)
    k = max(1, int(32 * frac))
    nnz = int(jnp.sum(out["w"] != 0.0))
    assert 0 < nnz <= 2 * k
    d = stack["w"] - theta["w"][None]
    want = jnp.mean(jax.vmap(lambda v: es.sparsify(v, frac))(d), axis=0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want),
                               rtol=1e-6, atol=0)


def test_pseudograd_mean_masks_offline_workers():
    """An offline worker's delta is excluded and the denominator shrinks —
    in BOTH the per-leaf and the flat-plane implementations."""
    stack, theta = _pg_inputs(M=3, shape=(2, 16))
    mask = jnp.asarray([True, False, True])
    out = es.pseudograd_mean(stack, theta, mask, sync_dtype="float32")
    d = stack["w"] - theta["w"][None]
    want = (d[0] + d[2]) / 2.0
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want),
                               rtol=1e-6, atol=1e-7)
    # flat-plane twin over the raveled buffer agrees with the same oracle
    flat_stack = stack["w"].reshape(3, 1, 32)
    flat_theta = theta["w"].reshape(1, 32)
    got = es.flat_pseudograd_mean(flat_stack, flat_theta, mask,
                                  sync_dtype="float32")
    np.testing.assert_allclose(np.asarray(got.reshape(2, 16)),
                               np.asarray(want), rtol=1e-6, atol=1e-7)


def test_flat_pseudograd_mean_topk_ranks_fragment_as_one_pool():
    """Documented flat-plane semantic: top-k ranks the fragment's
    concatenated elements as ONE pool (per worker), not per leaf."""
    stack = jnp.concatenate(
        [jnp.full((1, 1, 16), 10.0), jnp.full((1, 1, 16), 0.1)],
        axis=-1)  # one worker, one row: half big, half small entries
    theta = jnp.zeros((1, 32))
    out = es.flat_pseudograd_mean(stack, theta, jnp.ones((1,), bool),
                                  sync_dtype="float32", topk_frac=0.5)
    # the global top half is exactly the big-entry half
    np.testing.assert_allclose(np.asarray(out[0, :16]), 10.0)
    np.testing.assert_array_equal(np.asarray(out[0, 16:]), 0.0)
