"""Serving subsystem: slotted KV cache, continuous-batching engine, region
routing, traffic generation, and the fused-checkpoint serve path.

The load-bearing contracts:
  * per-slot flash_decode == oracle at the ragged occupancy patterns slot
    recycling actually produces (holes, wrapped rings, window interaction);
  * the jitted decode step is traced exactly once no matter how batch
    composition churns (admissions, completions, recycles);
  * slot recycling leaks nothing across requests, and every request samples
    from its own RNG stream;
  * `launch/serve.py::load_params` serves fused-mode checkpoints (flat
    fragment plane) bitwise-identically to the engine's own pytree view.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api, transformer
from repro.serve import (Request, RoutedCluster, ServeEngine, SlotManager,
                         TrafficSpec, generate)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("bench_tiny")
    return cfg, api.init_params(cfg, KEY)


def _rand(seed, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale).astype(
        dtype)


def _requests(n, *, vocab=512, seed=0, rps=8.0, pmin=3, pmax=14, gmin=2,
              gmax=20, rid0=0):
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rps))
        P = int(rng.integers(pmin, pmax + 1))
        out.append(Request(
            rid=rid0 + i,
            prompt=rng.integers(0, vocab, size=P).astype(np.int32),
            max_new_tokens=int(rng.integers(gmin, gmax + 1)), arrival_s=t))
    return out


# ---------------------------------------------------------------------------
# flash_decode under per-slot (ragged) occupancy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 16])
def test_flash_decode_per_slot_ragged_matches_ref(window):
    """Every lane at its own depth, with mid-cache holes (recycled slots) and
    a wrapped ring — kernel == oracle."""
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import flash_decode_ref
    B, H, KV, hd, C = 4, 4, 2, 32, 64
    q = _rand(1, (B, H, hd))
    kc = _rand(2, (B, C, KV, hd))
    vc = _rand(3, (B, C, KV, hd))
    ar = np.arange(C)
    rows = np.stack([
        np.where(ar <= 5, ar, -1),                       # freshly admitted
        np.where((ar <= 40) & (ar % 7 != 3), ar, -1),    # holes mid-cache
        np.where(ar >= 20, ar + 30, np.where(ar < 10, ar + C + 30, -1)),
        np.full(C, -1),                                  # empty slot
    ]).astype(np.int32)
    qpos = np.array([5, 40, C + 39, 0], np.int32)
    out = flash_decode(q, kc, vc, jnp.asarray(rows), jnp.asarray(qpos),
                      window=window, bc=32)
    ref = flash_decode_ref(q, kc, vc, jnp.asarray(rows), jnp.asarray(qpos),
                           window=window)
    # the empty slot attends to nothing: both paths give a uniform average,
    # but its output is meaningless — compare occupied lanes strictly
    np.testing.assert_allclose(np.asarray(out[:3]), np.asarray(ref[:3]),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_shared_positions_broadcast_equivalent():
    """Legacy (C,)/scalar positions == explicitly broadcast (B, C)/(B,)."""
    from repro.kernels.flash_decode.ops import flash_decode
    B, H, KV, hd, C = 2, 4, 2, 32, 64
    q = _rand(4, (B, H, hd))
    kc = _rand(5, (B, C, KV, hd))
    vc = _rand(6, (B, C, KV, hd))
    kv_pos = jnp.where(jnp.arange(C) <= 30, jnp.arange(C), -1)
    qpos = jnp.asarray(30, jnp.int32)
    a = flash_decode(q, kc, vc, kv_pos, qpos, bc=32)
    b = flash_decode(q, kc, vc, jnp.broadcast_to(kv_pos[None], (B, C)),
                     jnp.broadcast_to(qpos[None], (B,)), bc=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flash_decode_matches_ref_on_engine_occupancy(tiny):
    """attn_impl='flash' == 'ref' on slot-plane states the cache manager
    ACTUALLY produces — mid-churn, with recycled slots and ragged depths."""
    cfg, params = tiny
    eng = ServeEngine(cfg, params, n_slots=4, cache_len=48, max_prompt=14,
                      prefill_chunk=8, mode="continuous", temperature=0.9,
                      seed=0)
    reqs = _requests(10, vocab=cfg.vocab, seed=5)
    for r in reqs:
        eng.submit(r)
    checked = 0
    for _ in range(200):
        if not eng.has_work:
            break
        eng.tick()
        active = np.asarray(eng.state["active"])
        if active.any() and 0 < active.sum() < eng.n_slots:
            cache = {k: eng.state[k] for k in ("k", "v", "kv_pos", "pos")}
            lr, _ = transformer.decode_step_slotted(
                cfg, params, cache, eng.state["last_tok"],
                active=eng.state["active"], attn_impl="ref")
            lf, _ = transformer.decode_step_slotted(
                cfg, params, cache, eng.state["last_tok"],
                active=eng.state["active"], attn_impl="flash")
            rows = np.flatnonzero(active)
            np.testing.assert_allclose(np.asarray(lf)[rows],
                                       np.asarray(lr)[rows],
                                       rtol=2e-4, atol=2e-4)
            checked += 1
            if checked >= 3:
                break
    assert checked >= 1, "never hit a partially-occupied plane"


# ---------------------------------------------------------------------------
# engine: parity, trace-once, recycling, RNG streams
# ---------------------------------------------------------------------------


def test_slotted_greedy_matches_legacy_decode(tiny):
    """One request through the chunked slot plane == full prefill + lock-step
    decode_step, greedily (same math, different partitioning)."""
    cfg, params = tiny
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, size=13).astype(np.int32)
    G = 12
    eng = ServeEngine(cfg, params, n_slots=2, cache_len=64, max_prompt=16,
                      prefill_chunk=5, mode="continuous", temperature=0.0)
    recs = eng.run_trace([Request(rid=0, prompt=prompt, max_new_tokens=G)])
    got = recs[0].tokens

    logits, cache = transformer.prefill(cfg, params,
                                        {"tokens": jnp.asarray(prompt)[None]},
                                        cache_len=64)
    want = [int(jnp.argmax(logits[0]))]
    for _ in range(G - 1):
        logits, cache = api.decode_step(cfg, params, cache,
                                        jnp.asarray([want[-1]], jnp.int32))
        want.append(int(jnp.argmax(logits[0])))
    assert got == want


def test_decode_traced_once_across_churn(tiny):
    """Admissions, completions, and slot recycles never retrace the decode
    (or prefill) step — the zero-recompile contract."""
    cfg, params = tiny
    eng = ServeEngine(cfg, params, n_slots=3, cache_len=48, max_prompt=14,
                      prefill_chunk=8, mode="continuous", temperature=0.7,
                      seed=1)
    reqs = _requests(14, vocab=cfg.vocab, seed=2)
    recs = eng.run_trace(reqs)
    assert len(recs) == len(reqs)
    assert eng.n_decode_dispatches > len(reqs)      # plane churned plenty
    assert eng.decode_trace_count() == 1
    assert eng.prefill_trace_count() == 1


def test_slot_recycle_no_leakage(tiny):
    """A request decoded on a heavily-recycled slot produces exactly the
    tokens it produces on a fresh plane — stale K/V is invisible."""
    cfg, params = tiny
    target = Request(rid=999, prompt=np.arange(1, 11, dtype=np.int32),
                     max_new_tokens=10)
    fresh = ServeEngine(cfg, params, n_slots=1, cache_len=32, max_prompt=14,
                        prefill_chunk=8, temperature=0.0)
    want = fresh.run_trace([target])[0].tokens

    churned = ServeEngine(cfg, params, n_slots=1, cache_len=32, max_prompt=14,
                          prefill_chunk=8, temperature=0.0)
    churn = _requests(6, vocab=cfg.vocab, seed=9, gmin=3, gmax=12)
    late = dataclasses.replace(target, arrival_s=1e9)
    recs = churned.run_trace(churn + [late])
    got = next(r for r in recs if r.rid == 999).tokens
    assert got == want


def test_rng_streams_distinct_and_deterministic(tiny):
    """Same prompt, different request ids -> different samples; same engine
    seed + trace -> identical samples. The prompt key is never reused."""
    cfg, params = tiny
    prompt = np.arange(2, 12, dtype=np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=12) for i in (0, 1)]

    def run():
        eng = ServeEngine(cfg, params, n_slots=2, cache_len=32, max_prompt=12,
                          prefill_chunk=6, temperature=1.0, seed=7)
        return {r.rid: r.tokens for r in eng.run_trace(list(reqs))}

    a, b = run(), run()
    assert a == b                                  # deterministic replay
    assert a[0] != a[1]                            # per-request streams


def test_static_mode_completes_and_traces_once(tiny):
    cfg, params = tiny
    eng = ServeEngine(cfg, params, n_slots=3, cache_len=48, max_prompt=14,
                      prefill_chunk=8, mode="static", temperature=0.5, seed=3)
    reqs = _requests(8, vocab=cfg.vocab, seed=4)
    recs = eng.run_trace(reqs)
    assert len(recs) == len(reqs)
    assert eng.decode_trace_count() == 1


def test_engine_rejects_oversized_requests(tiny):
    cfg, params = tiny
    eng = ServeEngine(cfg, params, n_slots=1, cache_len=24, max_prompt=12,
                      prefill_chunk=6)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(Request(rid=0, prompt=np.zeros(10, np.int32),
                           max_new_tokens=100))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(Request(rid=1, prompt=np.zeros(13, np.int32),
                           max_new_tokens=2))


def test_slot_manager_invariants():
    sm = SlotManager(2)
    a, b = sm.acquire(10), sm.acquire(11)
    assert (a, b) == (0, 1) and sm.acquire(12) is None
    assert sm.release(0) == 10
    assert sm.acquire(13) == 0                     # lowest-free-first
    with pytest.raises(KeyError):
        sm.release(1 + 1)                          # never occupied
    sm.note_decode_tick(1)
    sm.note_decode_tick(2)
    assert sm.mean_occupancy == pytest.approx(3 / 4)


# ---------------------------------------------------------------------------
# router + traffic
# ---------------------------------------------------------------------------


def test_point_route_at_outage():
    """Point-to-point routing respects link dynamics: dark hops are routed
    around or reported unreachable, and src == dst is free."""
    from repro.core.network import RoutePlanner, apply_dynamics, generate_mesh
    topo = apply_dynamics(generate_mesh(4, "hub_spoke", seed=0),
                          "hub_failure:start=10:dur=5", seed=0)
    pl = RoutePlanner(topo)
    assert pl.point_route_at(3.0, 2, 2) == (0.0, ())
    cost, hops = pl.point_route_at(3.0, 1, 2)      # before the outage
    assert hops and hops[0][0] == 1 and hops[-1][1] == 2
    mid = pl.point_route_at(12.0, 1, 2)            # during: hub links dark
    assert mid is not None
    assert all(0 not in hop for hop in mid[1])     # routes around the hub
    assert pl.point_route_at(12.0, 0, 2) is None   # hub itself is stranded
    assert pl.point_latency_at(12.0, 0, 2, 1024) is None
    lat = pl.point_latency_at(3.0, 1, 2, 1024)
    assert lat is not None and lat > 0.0


def test_routed_cluster_zero_drops_through_outage(tiny):
    """Every admitted request completes through a hub outage: spokes fail
    over to the surviving replica, hub-origin requests are held + retried."""
    cfg, params = tiny
    from repro.core.network import apply_dynamics, generate_mesh
    topo = apply_dynamics(generate_mesh(4, "hub_spoke", seed=0),
                          "hub_failure:start=3:dur=6", seed=0)
    spec = TrafficSpec(horizon_s=10.0, base_rps=2.5, n_regions=4, seed=3,
                       prompt_len=(3, 12), gen_len=(3, 12), vocab=cfg.vocab)
    reqs = generate(spec)
    cluster = RoutedCluster(cfg, params, topo,
                            replica_regions=[1, 2], n_slots=2, cache_len=32,
                            max_prompt=12, prefill_chunk=6,
                            mode="continuous", temperature=0.4)
    recs = cluster.run(reqs)
    st = cluster.stats(recs)
    assert st.completed == len(reqs) and st.dropped == 0
    assert st.failovers + st.held > 0              # outage actually exercised
    for rec in recs:
        assert rec.done_s is not None and rec.ttft_s > 0
        assert rec.req_hop_s >= 0 and rec.resp_hop_s >= 0


def test_traffic_generator_deterministic():
    spec = TrafficSpec(horizon_s=8.0, base_rps=4.0, n_regions=3, seed=5,
                       burst_every_s=4.0, burst_dur_s=1.0)
    a, b = generate(spec), generate(spec)
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        assert (ra.arrival_s, ra.region, ra.max_new_tokens) == \
               (rb.arrival_s, rb.region, rb.max_new_tokens)
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    assert [r.arrival_s for r in a] == sorted(r.arrival_s for r in a)
    c = generate(dataclasses.replace(spec, seed=6))
    assert [r.arrival_s for r in c] != [r.arrival_s for r in a]


# ---------------------------------------------------------------------------
# serving from a fused-mode checkpoint (flat fragment plane)
# ---------------------------------------------------------------------------


def test_serve_from_fused_checkpoint(tmp_path):
    """load_params unpacks a fused checkpoint's flat theta_g plane into the
    per-leaf pytree bitwise — and the engine actually serves from it."""
    from repro.configs.base import CoCoDCConfig
    from repro.core.trainer import CrossRegionTrainer, TrainerConfig
    from repro.launch.serve import load_params

    mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                               compute_dtype="float32")
    tr = CrossRegionTrainer(
        mcfg,
        CoCoDCConfig(num_workers=2, local_steps=4, num_fragments=2,
                     overlap_depth=2, fused_updates=True),
        TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                      total_steps=8, warmup_steps=4, inner_lr=3e-3,
                      eval_batch=4, seed=0))
    tr.run(eval_every=8, log=lambda s: None)
    ck = os.path.join(tmp_path, "ck.msgpack")
    tr.save_checkpoint(ck)

    params = load_params(mcfg, ck)
    flat_got = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_want = jax.tree_util.tree_flatten_with_path(tr.engine.theta_g)[0]
    assert len(flat_got) == len(flat_want)
    for (pa, a), (pb, b) in zip(sorted(flat_got, key=lambda x: str(x[0])),
                                sorted(flat_want, key=lambda x: str(x[0]))):
        assert str(pa) == str(pb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    eng = ServeEngine(mcfg, params, n_slots=2, cache_len=24, max_prompt=8,
                      prefill_chunk=4, temperature=0.0)
    recs = eng.run_trace([Request(
        rid=0, prompt=np.arange(1, 7, dtype=np.int32), max_new_tokens=6)])
    assert len(recs[0].tokens) == 6

    with pytest.raises(ValueError, match="arch"):
        load_params(get_config("bench_tiny"), ck)
