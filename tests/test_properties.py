"""Hypothesis property tests on system invariants beyond the per-module suites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip, never collection-error
from hypothesis import given, settings, strategies as st

from repro.core.delay_comp import blend, compensate
from repro.core.outer_opt import init_state, nesterov_update
from repro.kernels.delay_comp.ref import delay_comp_ref
from repro.launch.sharding import recommended_profile


class _M:
    class _D:
        size = 256
    devices = _D()


# ---------------------------------------------------------------------------
# delay compensation algebraic properties
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000),
       tau=st.floats(1.0, 20.0),
       lam=st.floats(0.0, 2.0),
       H=st.floats(1.0, 200.0))
def test_compensate_fixed_point(seed, tau, lam, H):
    """If local == snapshot == global, compensation is the identity."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16,))
    out = delay_comp_ref(x, x, x, tau=tau, lam=lam, H=H)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000), tau=st.floats(1.0, 20.0))
def test_compensate_lam0_linear_in_drift(seed, tau):
    """lam=0: out = theta_g + (tl - tp) exactly, independent of tau."""
    k = jax.random.PRNGKey(seed)
    tl, tp, tg = (jax.random.normal(jax.random.fold_in(k, i), (8,))
                  for i in range(3))
    out = delay_comp_ref(tl, tp, tg, tau=tau, lam=0.0, H=10.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(tg + tl - tp),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), alpha=st.floats(0.0, 1.0))
def test_blend_convexity(seed, alpha):
    """Eq. 3 blending stays within the [local, global] interval elementwise."""
    k = jax.random.PRNGKey(seed)
    a = jax.random.normal(jax.random.fold_in(k, 0), (32,))
    b = jax.random.normal(jax.random.fold_in(k, 1), (32,))
    out = blend({"w": a}, {"w": b}, alpha=alpha)["w"]
    lo = jnp.minimum(a, b) - 1e-6
    hi = jnp.maximum(a, b) + 1e-6
    assert bool(jnp.all((out >= lo) & (out <= hi)))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), mu=st.floats(0.0, 0.99),
       lr=st.floats(0.01, 1.0))
def test_nesterov_zero_delta_is_noop(seed, mu, lr):
    theta = {"w": jax.random.normal(jax.random.PRNGKey(seed), (8,))}
    mom = init_state(theta)
    t1, m1 = nesterov_update(theta, mom, {"w": jnp.zeros(8)}, lr=lr, mu=mu)
    np.testing.assert_array_equal(np.asarray(t1["w"]), np.asarray(theta["w"]))
    np.testing.assert_array_equal(np.asarray(m1["w"]), 0.0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100), mu=st.floats(0.0, 0.95))
def test_nesterov_constant_delta_accumulates(seed, mu):
    """Momentum of a constant delta converges toward delta/(1-mu) scale."""
    theta = {"w": jnp.zeros(4)}
    mom = init_state(theta)
    delta = {"w": jnp.ones(4)}
    prev = 0.0
    for _ in range(50):
        theta, mom = nesterov_update(theta, mom, delta, lr=0.1, mu=mu)
        cur = float(theta["w"][0])
        assert cur > prev  # monotone ascent along a constant pseudo-gradient
        prev = cur


# ---------------------------------------------------------------------------
# decode ring buffer long-run property
# ---------------------------------------------------------------------------


def test_ring_buffer_never_exceeds_window():
    """Decoding far past the window keeps logits finite and cache bounded."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import api
    cfg = dataclasses.replace(get_config("recurrentgemma_9b").reduced(),
                              compute_dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    W = cfg.attn_window
    extra = 9
    cache = api.init_cache(cfg, 1, W)
    tok = jnp.zeros((1,), jnp.int32)
    step = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    for t in range(W + extra):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert cache["kv_pos"].shape[0] == W          # bounded
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == W + extra


# ---------------------------------------------------------------------------
# profile recommendation
# ---------------------------------------------------------------------------


def test_recommended_profile_boundaries():
    assert recommended_profile(int(0.6e9), _M()) == "dp"
    assert recommended_profile(int(405e9), _M()) == "2d"
    assert recommended_profile(int(3e9), _M()) == "2d"
