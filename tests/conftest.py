import os
import sys

# tests see the real (1-device) CPU platform; ONLY the dry-run forces 512 devices
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
