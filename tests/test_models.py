"""Per-architecture smoke tests (reduced configs: <=2-3 layers, d_model<=256,
<=4 experts) + decode/forward consistency + family-specific behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32):
    k = jax.random.fold_in(KEY, 1)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(k, 2), (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["prefix_emb"] = jax.random.normal(
            jax.random.fold_in(k, 3), (B, cfg.n_prefix_tokens, cfg.prefix_dim),
            jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(k, 3), (B, cfg.n_prefix_tokens, cfg.prefix_dim),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    """Deliverable (f): reduced variant, one forward pass, shape + NaN asserts."""
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg)
    h, aux = api.forward(cfg, params, batch, train=False, remat=False)
    B, S = batch["tokens"].shape
    exp_S = S + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert h.shape == (B, exp_S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_no_nan(arch):
    """Deliverable (f): one train step on CPU — loss finite, grads flow."""
    from repro.optim import adamw_init, adamw_update
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: api.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0.0
    opt = adamw_init(params)
    new_params, _ = adamw_update(grads, opt, params, 1e-3)
    # params actually moved
    moved = sum(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, KEY)
    B = 2
    cache = api.init_cache(cfg, B, 16)
    toks = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(cfg, params, cache, toks)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(cache["pos"]) == 3


def _f32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "rwkv6_3b", "recurrentgemma_9b",
                                  "granite_moe_3b_a800m"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the training forward's next-token logits
    (teacher forcing) — validates cache/ring-buffer/recurrent-state handling.
    MoE uses a short prompt so capacity (>=8/expert) can never drop tokens in the
    forward pass (decode batches are 1 token and never drop)."""
    cfg = _f32(get_config(arch).reduced())
    params = api.init_params(cfg, KEY)
    B, S = 1, 4 if cfg.moe is not None else 12
    toks = jax.random.randint(jax.random.fold_in(KEY, 9), (B, S), 0, cfg.vocab)
    # forward logits at the last position
    h, _ = api.forward(cfg, params, {"tokens": toks}, train=False, remat=False)
    from repro.models import transformer, rwkv6, rglru
    if cfg.family in ("dense", "moe"):
        head = transformer.lm_head_weight(cfg, params)
    else:
        head = params["lm_head"]
    ref_logits = h[:, -1].astype(jnp.float32) @ head.astype(jnp.float32)
    # decode step-by-step
    cache = api.init_cache(cfg, B, S + 4)
    for t in range(S):
        logits, cache = api.decode_step(cfg, params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_ring_buffer():
    """With window W, decoding past W positions must equal decoding with a full
    cache but masked attention — the ring buffer drops exactly the out-of-window
    entries."""
    cfg = _f32(get_config("llava_next_mistral_7b").reduced())
    W = cfg.attn_window
    assert W is not None
    params = api.init_params(cfg, KEY)
    B, S = 1, W + 8  # decode past the window
    toks = jax.random.randint(jax.random.fold_in(KEY, 5), (B, S), 0, cfg.vocab)
    # ring cache (length W) vs full cache (length S)
    cache_ring = api.init_cache(cfg, B, W)
    cache_full = api.init_cache(cfg, B, S)
    for t in range(S):
        lr, cache_ring = api.decode_step(cfg, params, cache_ring, toks[:, t])
        lf, cache_full = api.decode_step(cfg, params, cache_full, toks[:, t])
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf), rtol=2e-3, atol=2e-3)


def test_audio_decode_with_cross_attention():
    from repro.models import encdec
    cfg = _f32(get_config("seamless_m4t_large_v2").reduced())
    params = api.init_params(cfg, KEY)
    B, F, S = 1, 8, 6
    frames = jax.random.normal(jax.random.fold_in(KEY, 7), (B, F, cfg.prefix_dim))
    toks = jax.random.randint(jax.random.fold_in(KEY, 8), (B, S), 0, cfg.vocab)
    h, _ = api.forward(cfg, params, {"tokens": toks, "frames": frames},
                       train=False, remat=False)
    ref_logits = h[:, -1].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    memory = encdec.encode(cfg, params,
                           frames.astype(jnp.float32), train=False, remat=False)
    ck, cv = encdec.prepare_cross_cache(
        cfg, jax.tree.map(lambda a: a, params), memory)
    cache = encdec.init_cache(cfg, B, S + 2, n_frames=F)
    cache["cross_k"], cache["cross_v"] = ck, cv
    for t in range(S):
        logits, cache = api.decode_step(cfg, params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)


def test_moe_routes_to_multiple_experts():
    from repro.models.moe import moe_ffn
    from repro.models import moe as moe_lib
    from repro.configs.base import MoEConfig
    mcfg = MoEConfig(num_experts=4, top_k=2)
    D, F = 32, 64
    lp = jax.tree.map(lambda a: a[0],
                      moe_lib.init_moe_params(KEY, 1, D, F, mcfg, jnp.float32))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 16, D))
    out = moe_ffn(x, lp, mcfg)
    assert out.y.shape == x.shape
    assert jnp.isfinite(out.aux_loss)
    assert float(out.overflow_frac) < 0.5


def test_moe_identical_tokens_identical_outputs():
    """Permutation/consistency: same token vector -> same MoE output regardless of
    position (dispatch bookkeeping correctness)."""
    from repro.models.moe import moe_ffn
    from repro.models import moe as moe_lib
    from repro.configs.base import MoEConfig
    mcfg = MoEConfig(num_experts=4, top_k=2)
    D, F = 16, 32
    lp = jax.tree.map(lambda a: a[0],
                      moe_lib.init_moe_params(KEY, 1, D, F, mcfg, jnp.float32))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (D,))
    x = jnp.broadcast_to(v, (1, 8, D))
    out = moe_ffn(x, lp, mcfg, capacity_factor=8.0)  # big capacity: no drops
    y = np.asarray(out.y[0])
    for t in range(1, 8):
        np.testing.assert_allclose(y[t], y[0], rtol=1e-4, atol=1e-5)


def test_vlm_prefix_changes_text_logits():
    cfg = _f32(get_config("llava_next_mistral_7b").reduced())
    params = api.init_params(cfg, KEY)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.fold_in(KEY, 6), (B, S), 0, cfg.vocab)
    pe1 = jnp.zeros((B, cfg.n_prefix_tokens, cfg.prefix_dim))
    pe2 = jnp.ones((B, cfg.n_prefix_tokens, cfg.prefix_dim))
    h1, a1 = api.forward(cfg, params, {"tokens": toks, "prefix_emb": pe1},
                         train=False, remat=False)
    h2, _ = api.forward(cfg, params, {"tokens": toks, "prefix_emb": pe2},
                        train=False, remat=False)
    assert a1["n_prefix"] == cfg.n_prefix_tokens
    assert float(jnp.max(jnp.abs(h1[:, -1] - h2[:, -1]))) > 1e-4


def test_unroll_matches_scan():
    """The roofline probe path (unrolled layers) computes the same function."""
    for arch in ["qwen3_0_6b", "rwkv6_3b", "recurrentgemma_9b"]:
        cfg = _f32(get_config(arch).reduced())
        params = api.init_params(cfg, KEY)
        batch = make_batch(cfg, B=1, S=16)
        l1, _ = api.loss_fn(cfg, params, batch, remat=False)
        l2, _ = api.loss_fn(cfg, params, batch, remat=False, unroll=True)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_decode_flash_impl_matches_ref():
    """decode_step(attn_impl='flash') — the flash_decode Pallas kernel wired into
    the production decode path — matches the reference attention."""
    cfg = _f32(get_config("qwen3_0_6b").reduced())
    params = api.init_params(cfg, KEY)
    B = 2
    c1 = api.init_cache(cfg, B, 16)
    c2 = api.init_cache(cfg, B, 16)
    toks = jnp.ones((B,), jnp.int32)
    for _ in range(4):
        l1, c1 = api.decode_step(cfg, params, c1, toks)
        l2, c2 = api.decode_step(cfg, params, c2, toks, attn_impl="flash")
        toks = jnp.argmax(l1, -1).astype(jnp.int32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)
