"""Cross-region fault-tolerance + compression features (beyond-paper):
partial participation (offline datacenters) and top-k sparse sync."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CoCoDCConfig
from repro.configs.base import ModelConfig
from repro.core.trainer import CrossRegionTrainer, TrainerConfig

TINY = ModelConfig(name="ft-tiny", family="dense", n_layers=2, d_model=48,
                   n_heads=2, n_kv_heads=1, d_ff=96, vocab=128,
                   compute_dtype="float32")


def make(method="cocodc", M=3, **ccfg_kw):
    ccfg = CoCoDCConfig(num_workers=M, local_steps=8, num_fragments=2,
                        overlap_depth=2, **ccfg_kw)
    tcfg = TrainerConfig(method=method, local_batch=2, seq_len=16,
                         total_steps=32, warmup_steps=4, inner_lr=3e-3)
    return CrossRegionTrainer(TINY, ccfg, tcfg)


def test_offline_worker_not_updated_by_sync():
    tr = make()
    tr.engine.set_worker_availability(2, False)
    # snapshot worker 2 params, train past a full sync cycle
    for _ in range(12):
        tr.train_one_step()
    # worker 2 trained locally (params changed) but never got theta_g injected:
    # verify it and the consensus model diverge more than workers 0/1 do
    theta = tr.engine.theta_g
    dists = []
    for m in range(3):
        d = sum(float(jnp.sum(jnp.abs(l[m] - g)))
                for l, g in zip(jax.tree.leaves(tr.params_stack),
                                jax.tree.leaves(theta)))
        dists.append(d)
    assert dists[2] > dists[0]
    assert dists[2] > dists[1]
    assert tr.engine.n_syncs > 0


def test_offline_worker_excluded_from_average():
    """With worker 2 poisoned and offline, the consensus stays finite/clean."""
    tr = make()
    # poison worker 2's params
    tr.params_stack = jax.tree.map(
        lambda a: a.at[2].set(jnp.full_like(a[2], 1e9)), tr.params_stack)
    tr.engine.set_worker_availability(2, False)
    for _ in range(12):
        tr.params_stack = tr.engine.on_step_end(tr.step, tr.params_stack)
        tr.step += 1
    for leaf in jax.tree.leaves(tr.engine.theta_g):
        assert float(jnp.max(jnp.abs(leaf))) < 1e6  # poison never averaged in


def test_worker_reintegration():
    tr = make()
    tr.engine.set_worker_availability(1, False)
    for _ in range(10):
        tr.train_one_step()
    tr.engine.set_worker_availability(1, True)
    for _ in range(12):
        tr.train_one_step()
    assert np.isfinite(tr.evaluate()["nll"])


def test_topk_sparse_sync_bytes_and_convergence():
    res = {}
    for frac in (1.0, 0.1):
        tr = make(sync_topk_frac=frac)
        tr.run(steps=24, eval_every=24, log=lambda s: None)
        res[frac] = tr.engine.stats()["bytes_sent"]
        assert np.isfinite(tr.history[-1]["nll"])
    # values+indices at 10% density => ~20% of dense bytes (per-transfer floor)
    assert abs(res[0.1] - res[1.0] * 0.2) <= 64


def test_sparsify_keeps_topk():
    tr = make(sync_topk_frac=0.25)
    d = jnp.asarray([0.1, -5.0, 0.01, 3.0, -0.2, 0.0, 2.0, -0.05])
    out = tr.engine._sparsify(d)
    nz = np.nonzero(np.asarray(out))[0]
    assert set(nz) == {1, 3}  # top 25% of 8 = 2 largest magnitudes
