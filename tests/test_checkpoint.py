"""Checkpoint round-trips: arbitrary pytrees (incl. bf16 leaves) through
save_pytree/load_pytree, EngineState through the trainer_state wire format, and
full-run kill-and-resume trajectory exactness."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, restore_like, save_pytree
from repro.configs import get_config
from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core import engine_state as es
from repro.core.trainer import (CKPT_FORMAT, CrossRegionTrainer, TrainerConfig,
                                TrainerState)
from repro.models import api

KEY = jax.random.PRNGKey(0)

TINY = ModelConfig(name="ck-tiny", family="dense", n_layers=2, d_model=48,
                   n_heads=2, n_kv_heads=1, d_ff=96, vocab=128,
                   compute_dtype="float32")


def _trainer(method="cocodc", steps=24, loop="segment", seed=0):
    mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                               compute_dtype="float32")
    ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                        overlap_depth=2)
    tcfg = TrainerConfig(method=method, local_batch=2, seq_len=16,
                         total_steps=steps, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=seed, loop=loop)
    return CrossRegionTrainer(mcfg, ccfg, tcfg)


# ---------------------------------------------------------------------------
# pytree round-trips
# ---------------------------------------------------------------------------


def test_roundtrip_bf16_leaves(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) * 0.5,
            "b": {"c": jnp.ones((4,), jnp.float32),
                  "d": np.arange(3, dtype=np.int32)},
            "scalar": 7, "name": "x"}
    path = os.path.join(tmp_path, "t.msgpack")
    save_pytree(path, tree)
    loaded = load_pytree(path)
    assert jnp.asarray(loaded["a"]).dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(loaded["a"], np.float32), np.asarray(tree["a"], np.float32))
    np.testing.assert_array_equal(loaded["b"]["d"], tree["b"]["d"])
    assert loaded["scalar"] == 7 and loaded["name"] == "x"


def test_restore_like_retypes_and_casts(tmp_path):
    from repro.optim.adamw import AdamWState
    ref = AdamWState(mu={"w": jnp.zeros((2,), jnp.bfloat16)},
                     nu={"w": jnp.zeros((2,), jnp.float32)},
                     count=jnp.zeros((), jnp.int32))
    src = AdamWState(mu={"w": jnp.asarray([1.5, 2.5], jnp.bfloat16)},
                     nu={"w": jnp.asarray([3.0, 4.0], jnp.float32)},
                     count=jnp.asarray(5, jnp.int32))
    path = os.path.join(tmp_path, "o.msgpack")
    save_pytree(path, {"mu": src.mu, "nu": src.nu, "count": src.count})
    loaded = load_pytree(path)
    out = AdamWState(mu=restore_like(ref.mu, loaded["mu"]),
                     nu=restore_like(ref.nu, loaded["nu"]),
                     count=restore_like(ref.count, loaded["count"]))
    assert isinstance(out, AdamWState)
    assert out.mu["w"].dtype == jnp.bfloat16
    assert int(out.count) == 5
    np.testing.assert_array_equal(np.asarray(out.nu["w"]), [3.0, 4.0])


def test_restore_like_rejects_mismatched_structure():
    with pytest.raises(ValueError):
        restore_like({"a": jnp.zeros(2), "b": jnp.zeros(2)},
                     {"a": np.zeros(2)})


def test_engine_state_roundtrip(tmp_path):
    """EngineState (registered-dataclass pytree, incl. a bf16 theta_g leaf and
    a None inflight_snapshot subtree) survives the dict wire format."""
    params = api.init_params(TINY, KEY)
    stack = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (2,) + a.shape).copy(), params)
    ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2)
    state = es.init_state("streaming", ccfg, stack)     # snapshot is None
    # exercise a bf16 leaf through the f32 wire format
    state = dataclasses.replace(
        state, delta_norm=state.delta_norm.astype(jnp.bfloat16))
    path = os.path.join(tmp_path, "es.msgpack")
    save_pytree(path, es.state_to_dict(state))
    loaded = load_pytree(path)
    restored = es.state_from_dict(state, loaded)
    assert isinstance(restored, es.EngineState)
    assert restored.inflight_snapshot is None
    assert restored.delta_norm.dtype == jnp.bfloat16
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_trainer_state_is_pytree():
    tr = _trainer(steps=4)
    ts = tr.trainer_state()
    leaves, treedef = jax.tree.flatten(ts)
    rt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rt, TrainerState)
    assert rt.step == tr.step and rt.data_cursor == tr.step


# ---------------------------------------------------------------------------
# kill-and-resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["cocodc", "diloco"])
def test_kill_and_resume_matches_uninterrupted(tmp_path, method):
    """Acceptance: a run killed at a segment boundary and resumed from its
    checkpoint replays the uninterrupted run's trajectory exactly — eval NLLs,
    engine stats, and final params all bitwise-equal."""
    ck = os.path.join(tmp_path, "ck.msgpack")

    ref = _trainer(method)
    ref.run(eval_every=8, log=lambda s: None)

    interrupted = _trainer(method)
    interrupted.run(steps=12, eval_every=8, log=lambda s: None)   # "crash"
    interrupted.save_checkpoint(ck)

    resumed = _trainer(method).restore_checkpoint(ck)
    assert resumed.step == 12
    resumed.run(eval_every=8, log=lambda s: None)

    ra = {r["step"]: r for r in ref.history}
    rb = {r["step"]: r for r in resumed.history}
    # the interrupted run adds one extra eval at its stop step; every shared
    # eval step must agree exactly
    shared = sorted(set(ra) & set(rb))
    assert shared, "no common eval steps"
    for s in shared:
        assert ra[s]["nll"] == rb[s]["nll"]
        assert ra[s]["wall_clock_s"] == rb[s]["wall_clock_s"]

    sa, sb = ref.engine.stats(), resumed.engine.stats()
    for k in sa:
        assert sa[k] == sb[k], f"stats[{k}]: {sa[k]} vs {sb[k]}"
    for x, y in zip(jax.tree.leaves(ref.params_stack),
                    jax.tree.leaves(resumed.params_stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_mid_flight_transfers(tmp_path):
    """Checkpointing with fragments IN FLIGHT restores the pending schedule
    (deliveries land at the same steps with the same payloads)."""
    ck = os.path.join(tmp_path, "ck.msgpack")
    ref = _trainer("streaming")
    ref.run(eval_every=8, log=lambda s: None)

    tr = _trainer("streaming", loop="per_step")
    while not tr.engine.pending:         # stop with a transfer on the wire
        tr.train_one_step()
    stop = tr.step
    tr.save_checkpoint(ck)
    resumed = _trainer("streaming").restore_checkpoint(ck)
    assert [e.frag for e in resumed.engine.pending] == \
           [e.frag for e in tr.engine.pending]
    assert [e.deliver_at for e in resumed.engine.pending] == \
           [e.deliver_at for e in tr.engine.pending]
    assert resumed.step == stop
    resumed.run(eval_every=8, log=lambda s: None)
    ra = {r["step"]: r["nll"] for r in ref.history}
    rb = {r["step"]: r["nll"] for r in resumed.history}
    for s in sorted(set(ra) & set(rb)):
        assert ra[s] == rb[s]


def test_run_ckpt_every_saves_at_boundaries(tmp_path):
    ck = os.path.join(tmp_path, "auto.msgpack")
    tr = _trainer("cocodc", steps=16)
    tr.run(eval_every=8, log=lambda s: None, ckpt_path=ck, ckpt_every=8)
    assert os.path.exists(ck)
    st = load_pytree(ck)
    assert st["format"] == CKPT_FORMAT
    assert st["trainer_state"]["step"] == 16
    assert st["meta"]["method"] == "cocodc"


def test_restore_legacy_meta_respects_config_fragment_layout(tmp_path):
    """Pre-PR3 checkpoints have no fragment_strategy meta key: the implied
    default must come from the config that wrote them (strided_fragments),
    so a contiguous-fragment run's checkpoint still resumes (code-review
    finding) — while a genuinely mismatched strategy is still rejected."""
    ck = os.path.join(tmp_path, "legacy.msgpack")

    def contiguous_trainer():
        mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                                   compute_dtype="float32")
        ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                            overlap_depth=2, strided_fragments=False)
        tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                             total_steps=8, warmup_steps=4, inner_lr=3e-3,
                             eval_batch=4, seed=0)
        return CrossRegionTrainer(mcfg, ccfg, tcfg)

    tr = contiguous_trainer()
    tr.run(eval_every=8, log=lambda s: None)
    state = tr.checkpoint_state()
    assert state["meta"]["fragment_strategy"] == "contiguous"
    legacy = {**state, "meta": {k: v for k, v in state["meta"].items()
                                if k != "fragment_strategy"}}
    from repro.checkpoint import save_pytree
    save_pytree(ck, legacy)                       # simulate a pre-PR3 file

    resumed = contiguous_trainer().restore_checkpoint(ck)
    assert resumed.step == tr.step
    # a NEW checkpoint carries the key, so a genuine mismatch is rejected
    ck2 = os.path.join(tmp_path, "new.msgpack")
    save_pytree(ck2, state)
    with pytest.raises(ValueError, match="fragment_strategy"):
        _trainer("cocodc", steps=8).restore_checkpoint(ck2)  # strided trainer


def test_restore_rejects_wrong_method(tmp_path):
    ck = os.path.join(tmp_path, "m.msgpack")
    tr = _trainer("cocodc", steps=8)
    tr.run(eval_every=8, log=lambda s: None)
    tr.save_checkpoint(ck)
    with pytest.raises(ValueError, match="method"):
        _trainer("diloco").restore_checkpoint(ck)
