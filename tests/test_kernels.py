"""Per-kernel allclose validation against the pure-jnp oracles (interpret mode on
CPU), sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.delay_comp.ops import delay_comp, delay_comp_array
from repro.kernels.delay_comp.ref import delay_comp_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ops import lru_scan
from repro.kernels.rglru_scan.ref import lru_scan_ref
from repro.kernels.rwkv6_scan.ops import wkv_scan
from repro.models.rwkv6 import wkv_scan_ref

KEY = jax.random.PRNGKey(0)


def rand(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# delay_comp
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(7,), (128,), (33, 65), (4, 9, 17), (2048,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delay_comp_matches_ref(shape, dtype):
    tl, tp, tg = (rand(i, shape, dtype) for i in range(3))
    out = delay_comp_array(tl, tp, tg, tau=5.0, lam=0.5, H=100.0, impl="auto")
    ref = delay_comp_ref(tl, tp, tg, tau=5.0, lam=0.5, H=100.0)
    rtol, atol = (3e-2, 3e-2) if dtype == jnp.bfloat16 else (1e-5, 1e-6)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol, atol=atol)


@pytest.mark.parametrize("tau,lam,H,sign", [(1.0, 0.0, 1.0, 1.0),
                                            (5.0, 0.5, 100.0, 1.0),
                                            (3.0, 1.0, 10.0, -1.0)])
def test_delay_comp_param_sweep(tau, lam, H, sign):
    tl, tp, tg = (rand(i, (256,)) for i in range(3))
    out = delay_comp_array(tl, tp, tg, tau=tau, lam=lam, H=H, sign=sign, impl="auto")
    ref = delay_comp_ref(tl, tp, tg, tau=tau, lam=lam, H=H, sign=sign)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_delay_comp_pytree():
    tree = {"a": rand(0, (17,)), "b": [rand(1, (3, 5)), rand(2, (8, 8))]}
    out = delay_comp(tree, tree, tree, tau=5.0, lam=0.5, H=100.0)
    # theta_tl == theta_tp == theta_g  =>  g = 0  =>  out == theta_g
    jax.tree.map(lambda o, t: np.testing.assert_allclose(o, t, rtol=1e-6), out, tree)


def test_delay_comp_lam0_is_raw_drift():
    """lam=0: out = theta_g + (theta_tl - theta_tp) (invariant 2, DESIGN.md §7)."""
    tl, tp, tg = (rand(i, (64,)) for i in range(3))
    out = delay_comp_array(tl, tp, tg, tau=7.0, lam=0.0, H=100.0, impl="ref")
    np.testing.assert_allclose(out, tg + (tl - tp), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,KV,hd,window", [
    (1, 128, 4, 2, 64, None),
    (2, 256, 4, 4, 32, None),
    (1, 256, 4, 1, 64, 64),
    (1, 200, 2, 2, 64, None),      # non-multiple S (padding path)
    (1, 384, 8, 2, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, KV, hd, window, dtype):
    q = rand(1, (B, S, H, hd), dtype)
    k = rand(2, (B, S, KV, hd), dtype)
    v = rand(3, (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=window)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_first_token_attends_self():
    q = rand(1, (1, 128, 2, 32))
    k = rand(2, (1, 128, 2, 32))
    v = rand(3, (1, 128, 2, 32))
    out = flash_attention(q, k, v, causal=True)
    # position 0 can only attend itself -> output == v[0]
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,D,with_h0,bt,bd", [
    (2, 64, 32, False, 32, 32),
    (1, 300, 130, True, 64, 64),    # padding both axes
    (2, 512, 128, True, 128, 128),
    (1, 8, 8, False, 8, 8),
])
def test_lru_scan_matches_ref(B, T, D, with_h0, bt, bd):
    a = jax.nn.sigmoid(rand(1, (B, T, D)))
    b = rand(2, (B, T, D))
    h0 = rand(3, (B, D)) if with_h0 else None
    out = lru_scan(a, b, h0, bt=bt, bd=bd)
    ref = lru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_lru_scan_identity_coeff_is_cumsum():
    B, T, D = 1, 32, 16
    a = jnp.ones((B, T, D))
    b = rand(1, (B, T, D))
    out = lru_scan(a, b, bt=16, bd=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.cumsum(b, axis=1)),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,hd,with_s0,bt", [
    (1, 32, 2, 16, False, 16),
    (2, 100, 2, 32, True, 32),      # T padding
    (1, 128, 4, 64, True, 64),
])
def test_wkv_scan_matches_ref(B, T, H, hd, with_s0, bt):
    r = rand(1, (B, T, H, hd), scale=0.5)
    k = rand(2, (B, T, H, hd), scale=0.5)
    v = rand(3, (B, T, H, hd), scale=0.5)
    w = jax.nn.sigmoid(rand(4, (B, T, H, hd)))
    u = rand(5, (H, hd), scale=0.1)
    s0 = rand(6, (B, H, hd, hd)) if with_s0 else None
    o, sT = wkv_scan(r, k, v, w, u, s0, bt=bt)
    o_ref, s_ref = wkv_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref), rtol=1e-4, atol=1e-5)


def test_wkv_state_carry_equals_full_scan():
    """Chunked decode (carry sT) == one full scan: the O(1)-state decode path."""
    B, T, H, hd = 1, 64, 2, 16
    r, k, v = (rand(i, (B, T, H, hd), scale=0.5) for i in (1, 2, 3))
    w = jax.nn.sigmoid(rand(4, (B, T, H, hd)))
    u = rand(5, (H, hd), scale=0.1)
    o_full, s_full = wkv_scan(r, k, v, w, u, bt=32)
    half = T // 2
    o1, s1 = wkv_scan(r[:, :half], k[:, :half], v[:, :half], w[:, :half], u, bt=32)
    o2, s2 = wkv_scan(r[:, half:], k[:, half:], v[:, half:], w[:, half:], u, s1,
                      bt=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], axis=1)),
                               np.asarray(o_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused rms_norm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(7, 64), (2, 33, 128), (300, 256), (1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rms_norm_matches_ref(shape, dtype):
    from repro.kernels.rms_norm.ops import rms_norm
    from repro.kernels.rms_norm.ref import rms_norm_ref
    x = rand(1, shape, dtype)
    w = rand(2, (shape[-1],), dtype)
    out = rms_norm(x, w)
    ref = rms_norm_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash_decode (one-token attention over ring-buffer cache)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,hd,C,pos,window", [
    (2, 4, 2, 64, 128, 100, None),
    (1, 8, 2, 64, 256, 300, 64),     # ring wrapped + sliding window
    (2, 4, 4, 32, 100, 37, None),    # partially-filled cache + C padding
    (1, 2, 1, 64, 64, 63, 32),       # MQA
])
def test_flash_decode_matches_ref(B, H, KV, hd, C, pos, window):
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import flash_decode_ref
    q = rand(3, (B, H, hd))
    kc = rand(4, (B, C, KV, hd))
    vc = rand(5, (B, C, KV, hd))
    kv_pos = jnp.where(jnp.arange(C) <= pos, jnp.arange(C), -1)
    qpos = jnp.asarray(pos, jnp.int32)
    out = flash_decode(q, kc, vc, kv_pos, qpos, window=window, bc=64)
    ref = flash_decode_ref(q, kc, vc, kv_pos, qpos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_flash_decode_empty_slots_ignored():
    """Slots with kv_pos = -1 must not contribute regardless of their values."""
    from repro.kernels.flash_decode.ops import flash_decode
    B, H, KV, hd, C = 1, 2, 1, 32, 64
    q = rand(1, (B, H, hd))
    kc = rand(2, (B, C, KV, hd))
    vc = rand(3, (B, C, KV, hd))
    kv_pos = jnp.where(jnp.arange(C) < 8, jnp.arange(C), -1)
    qpos = jnp.asarray(7, jnp.int32)
    out1 = flash_decode(q, kc, vc, kv_pos, qpos, bc=32)
    # poison the masked slots
    kc2 = kc.at[:, 8:].set(1e9)
    vc2 = vc.at[:, 8:].set(-1e9)
    out2 = flash_decode(q, kc2, vc2, kv_pos, qpos, bc=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
