"""Fair-share traffic plane (PR 7): max-min water-filling, k-path multipath
splitting, the decomposed Eq. 9, and the fairshare engine path.

The water-filling tests pin `maxmin_rates` to hand-solved allocations and
(under hypothesis, when installed) to its two defining invariants —
feasibility (per-link weighted rate sum <= capacity) and max-min optimality
(every flow with positive rate crosses a saturated link). The engine tests
check fair-share-vs-serial parity when transfers never overlap, contention
sharing in the raw `FairShareSim`, and the mid-transfer kill-and-resume
bitwise contract with fairshare + multipath active.
"""
import dataclasses
import math
import os

import numpy as np
import pytest

from repro.api.spec import ExperimentSpec, NetworkSpec
from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core.adaptive import ResyncState, rederive_schedule
from repro.core.network import (FairShareSim, RoutePlanner, Topology,
                                generate_mesh, make_scenario, maxmin_rates)
from repro.core.trainer import CrossRegionTrainer, TrainerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                   n_heads=2, n_kv_heads=1, d_ff=128, vocab=128,
                   compute_dtype="float32")


# ---------------------------------------------------------------------------
# max-min water-filling: fixed hand-solved cases (always run)
# ---------------------------------------------------------------------------


def test_maxmin_equal_split():
    rates = maxmin_rates([{(0, 1): 1.0}, {(0, 1): 1.0}], {(0, 1): 1.0})
    assert rates == pytest.approx([0.5, 0.5])


def test_maxmin_asymmetric_bottlenecks():
    # B saturates l2 at level 0.4 and freezes; A keeps rising on l1 until
    # its leftover capacity 1 - (0.4 + 0.5*0.4) = 0.4 is gone -> 0.8.
    rates = maxmin_rates(
        [{(0, 1): 1.0}, {(0, 1): 0.5, (1, 2): 1.0}],
        {(0, 1): 1.0, (1, 2): 0.4})
    assert rates == pytest.approx([0.8, 0.4])


def test_maxmin_dark_link_gets_zero():
    rates = maxmin_rates(
        [{(0, 1): 1.0}, {(1, 2): 1.0}], {(0, 1): 0.0, (1, 2): 1.0})
    assert rates[0] == 0.0
    assert rates[1] == pytest.approx(1.0)


def test_maxmin_empty_flow_and_no_flows():
    assert maxmin_rates([], {}) == []
    assert maxmin_rates([{}], {(0, 1): 1.0}) == [0.0]


def test_maxmin_three_flows_shared_plus_private():
    # Two flows share l1 (saturates at level 0.5); the third rides l2 alone.
    rates = maxmin_rates(
        [{(0, 1): 1.0}, {(0, 1): 1.0}, {(1, 0): 1.0}],
        {(0, 1): 1.0, (1, 0): 2.0})
    assert rates == pytest.approx([0.5, 0.5, 2.0])


def _check_invariants(flow_links, caps, rates, tol=1e-7):
    """Feasibility + max-min optimality of a water-filling allocation."""
    usage = {}
    for links, r in zip(flow_links, rates):
        assert r >= 0.0
        for l, w in links.items():
            if w > 0.0:
                usage[l] = usage.get(l, 0.0) + w * r
    for l, u in usage.items():
        cap = caps.get(l, math.inf)
        assert u <= cap + tol * max(1.0, cap), f"link {l} oversubscribed"
    sat = {l for l, u in usage.items()
           if u >= caps.get(l, math.inf) - tol * max(1.0, caps.get(l, 1.0))}
    for links, r in zip(flow_links, rates):
        used = {l for l, w in links.items() if w > 0.0}
        if not used:
            continue
        if any(caps.get(l, 1.0) <= 0.0 for l in used):
            assert r == 0.0             # dark link -> no progress
        else:
            # max-min: a flow stops rising only at a saturated link
            assert used & sat, f"flow with rate {r} not bottlenecked"


def test_maxmin_invariants_fixed_cases():
    cases = [
        ([{(0, 1): 1.0}, {(0, 1): 1.0}], {(0, 1): 1.0}),
        ([{(0, 1): 1.0}, {(0, 1): 0.5, (1, 2): 1.0}],
         {(0, 1): 1.0, (1, 2): 0.4}),
        ([{(0, 1): 1.0, (1, 2): 0.25}, {(1, 2): 1.0}, {(0, 1): 0.5}],
         {(0, 1): 0.7, (1, 2): 1.3}),
        ([{(0, 1): 1.0}, {(1, 2): 1.0}], {(0, 1): 0.0, (1, 2): 1.0}),
    ]
    for flow_links, caps in cases:
        _check_invariants(flow_links, caps, maxmin_rates(flow_links, caps))


# ---------------------------------------------------------------------------
# max-min water-filling: property tests (hypothesis, when installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _links = st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)),
                      min_size=1, max_size=4).map(
        lambda ls: [l for l in ls if l[0] != l[1]])
    _flow = st.builds(
        lambda ls, ws: {l: w for l, w in zip(ls, ws)},
        _links, st.lists(st.floats(0.05, 1.0), min_size=4, max_size=4))
    _caps = st.dictionaries(
        st.tuples(st.integers(0, 3), st.integers(0, 3)),
        st.one_of(st.just(0.0), st.floats(0.1, 3.0)), max_size=12)

    @settings(max_examples=60, deadline=None)
    @given(flows=st.lists(_flow, min_size=1, max_size=5), caps=_caps)
    def test_maxmin_feasible_and_maxmin_optimal(flows, caps):
        # every used link needs a finite capacity for saturation to be
        # well-defined; default the rest to 1.0
        full = dict(caps)
        for f in flows:
            for l in f:
                full.setdefault(l, 1.0)
        _check_invariants(flows, full, maxmin_rates(flows, full))


# ---------------------------------------------------------------------------
# FairShareSim: contention sharing and single-flow arithmetic
# ---------------------------------------------------------------------------


def _flat_topology(m=2, bw=1e6):
    lat = np.zeros((m, m))
    b = np.full((m, m), float(bw))
    np.fill_diagonal(b, np.inf)
    return Topology(latency_s=lat, bandwidth_Bps=b)


def _spec(m, work, link=(0, 1)):
    sec = np.zeros((m, m))
    sec[link] = work
    byt = np.zeros((m, m))
    byt[link] = work * 1e6
    return {"links": {link: 1.0}, "lat": 0.0, "phases": 0, "work": work,
            "nominal": work, "sec": sec, "bytes": byt}


def test_fairshare_sim_single_flow_finishes_at_nominal():
    sim = FairShareSim(_flat_topology())
    sim.add_flow(0, _spec(2, 10.0), start=0.0, wire=1, jitter=1.0)
    assert sim.project() == {0: (0.0, pytest.approx(10.0))}


def test_fairshare_sim_two_flows_share_then_speed_up():
    """Two equal flows on one link run at rate 1/2 each; after the first
    finishes the survivor gets the full link back."""
    finished = {}
    sim = FairShareSim(_flat_topology(),
                       finish_fn=lambda f, t: finished.setdefault(f.id, t))
    sim.add_flow(0, _spec(2, 10.0), start=0.0, wire=1, jitter=1.0)
    sim.add_flow(1, _spec(2, 4.0), start=0.0, wire=1, jitter=1.0)
    proj = sim.project()
    # B: 4 units at rate 1/2 -> t=8. A: 8 units spent by t=8, remaining 6
    # at full rate -> t=14.
    assert proj[1] == (0.0, pytest.approx(8.0))
    assert proj[0] == (0.0, pytest.approx(14.0))
    sim.advance(20.0)
    assert finished == {0: pytest.approx(14.0), 1: pytest.approx(8.0)}
    assert sim.flows == []


def test_fairshare_sim_advance_is_associative():
    """Advancing in many small steps lands the same finishes as one jump —
    the per-step/segment loop parity the engine depends on."""
    fa, fb = {}, {}
    sim_a = FairShareSim(_flat_topology(),
                         finish_fn=lambda f, t: fa.setdefault(f.id, t))
    sim_b = FairShareSim(_flat_topology(),
                         finish_fn=lambda f, t: fb.setdefault(f.id, t))
    for sim in (sim_a, sim_b):
        sim.add_flow(0, _spec(2, 10.0), start=0.0, wire=1, jitter=1.0)
        sim.add_flow(1, _spec(2, 4.0), start=0.0, wire=1, jitter=1.0)
    sim_a.advance(16.0)
    for k in range(1, 33):
        sim_b.advance(k * 0.5)
    assert fa == fb


def test_fairshare_sim_state_roundtrip():
    sim = FairShareSim(_flat_topology())
    sim.add_flow(0, _spec(2, 10.0), start=0.0, wire=7, jitter=1.0)
    sim.advance(3.0)
    st_ = sim.state_dict()
    sim2 = FairShareSim(_flat_topology())
    sim2.load_state(st_)
    assert sim2.t == sim.t
    assert sim2.project() == sim.project()


# ---------------------------------------------------------------------------
# engine integration: parity without contention, bitwise kill-and-resume
# ---------------------------------------------------------------------------


def _trainer(channel_scheduler="serial", multipath_k=1, seed=0):
    mcfg = dataclasses.replace(TINY, name="fairshare-ck")
    routed = channel_scheduler == "fairshare" or multipath_k > 1
    ccfg = CoCoDCConfig(num_workers=4, local_steps=8, num_fragments=2,
                        overlap_depth=2,
                        routing="routed" if routed else "static",
                        hub_failover=routed,
                        channel_scheduler=channel_scheduler,
                        multipath_k=multipath_k)
    tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                         total_steps=24, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=seed)
    return CrossRegionTrainer(
        mcfg, ccfg, tcfg, network=make_scenario("asym4"),
        dynamics="diurnal:period=16:depth=0.7,jitter:frac=0.1",
        dynamics_seed=11)


def _blocking_trainer(channel_scheduler):
    """diloco blocks on every transfer, so nothing ever shares a link and
    the fair-share fluid model must reproduce the serial arithmetic."""
    mcfg = dataclasses.replace(TINY, name="fairshare-par")
    ccfg = CoCoDCConfig(num_workers=4, local_steps=8, num_fragments=2,
                        overlap_depth=2,
                        channel_scheduler=channel_scheduler)
    tcfg = TrainerConfig(method="diloco", local_batch=2, seq_len=16,
                         total_steps=16, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=0)
    # network=None -> the calibrated SYMMETRIC paper mesh: with equal links
    # the serial phase max and the fair-share lat + bandwidth-work split
    # select the same link, so the decompositions must agree numerically
    return CrossRegionTrainer(mcfg, ccfg, tcfg, network=None)


def test_fairshare_matches_serial_without_contention():
    a = _blocking_trainer("serial")
    b = _blocking_trainer("fairshare")
    a.run(eval_every=8, log=lambda s: None)
    b.run(eval_every=8, log=lambda s: None)
    sa, sb = a.engine.stats(), b.engine.stats()
    assert sa["n_syncs"] == sb["n_syncs"] > 0
    assert sb["comm_seconds"] == pytest.approx(sa["comm_seconds"], rel=1e-9)
    assert sb["wall_clock_s"] == pytest.approx(sa["wall_clock_s"], rel=1e-9)
    np.testing.assert_allclose(b.engine.link_seconds, a.engine.link_seconds,
                               rtol=1e-9)


def test_fairshare_sojourns_never_below_serial_service_time():
    """With overlapping cocodc transfers the fair-share sojourn includes the
    contention it creates; the log must be populated and positive, and
    multipath splits must actually occur with k=2 on the routed mesh."""
    tr = _trainer("fairshare", multipath_k=2)
    tr.run(eval_every=8, log=lambda s: None)
    st_ = tr.engine.stats()
    assert st_["n_syncs"] > 0
    assert len(tr.engine._transfer_log) == int(st_["n_syncs"])
    assert st_["transfer_mean_s"] > 0
    assert st_["transfer_p95_s"] >= st_["transfer_p50_s"] > 0
    assert st_["multipath_splits"] > 0
    assert st_["max_link_busy_fraction"] > 0
    for rec in tr.engine.link_stats()["links"].values():
        assert math.isfinite(rec["busy_fraction"])
        assert rec["busy_fraction"] >= 0.0


def test_fairshare_multipath_kill_and_resume_bitwise(tmp_path):
    """Mid-transfer checkpoint/resume with fairshare + multipath active must
    reproduce the uninterrupted trajectory bitwise — the FairShareSim flow
    table and the sojourn log serialize exactly."""
    ck = os.path.join(tmp_path, "fs.msgpack")

    ref = _trainer("fairshare", multipath_k=2)
    ref.run(eval_every=8, log=lambda s: None)

    tr = _trainer("fairshare", multipath_k=2)
    tr.run(steps=6, eval_every=8, log=lambda s: None)
    while not tr.engine.pending and tr.step < 20:
        tr.run(steps=tr.step + 1, eval_every=8, log=lambda s: None)
    assert tr.engine.pending, "no mid-transfer state to checkpoint"
    assert tr.engine._fairshare.flows, "no in-flight fair-share flow"
    tr.save_checkpoint(ck)

    resumed = _trainer("fairshare", multipath_k=2).restore_checkpoint(ck)
    assert resumed.engine._fairshare.t == tr.engine._fairshare.t
    assert [e.finish_time for e in resumed.engine.pending] == \
        [e.finish_time for e in tr.engine.pending]
    resumed.run(eval_every=8, log=lambda s: None)

    ra = {r["step"]: r for r in ref.history}
    rb = {r["step"]: r for r in resumed.history}
    shared = sorted(set(ra) & set(rb))
    assert shared
    for s in shared:
        assert ra[s]["nll"] == rb[s]["nll"]
        assert ra[s]["wall_clock_s"] == rb[s]["wall_clock_s"]
    sa, sb = ref.engine.stats(), resumed.engine.stats()
    for k in sa:
        assert sa[k] == sb[k], f"stats[{k}]: {sa[k]} vs {sb[k]}"
    assert ref.engine._transfer_log == resumed.engine._transfer_log
    np.testing.assert_array_equal(ref.engine.link_seconds,
                                  resumed.engine.link_seconds)


# ---------------------------------------------------------------------------
# k edge-disjoint multipath routes
# ---------------------------------------------------------------------------


def test_multiroutes_disjoint_and_normalized():
    topo = generate_mesh(8, "random_geo", seed=0)
    rp = RoutePlanner(topo, multipath_k=2, ref_bytes=1 << 20)
    eff = rp.effective_bandwidth(0.0)
    participants = tuple(range(8))
    groups = rp.multiroutes_at(eff, participants, [(0, 5), (3, 1)])
    for group in groups:
        assert 1 <= len(group) <= 2
        assert sum(share for _, share in group) == pytest.approx(1.0)
        seen = set()
        for hops, share in group:
            assert share > 0.0
            assert not (set(hops) & seen), "subflow paths share an edge"
            seen |= set(hops)


def test_multipath_plan_conserves_bytes():
    topo = generate_mesh(8, "random_geo", seed=0)
    single = RoutePlanner(topo, multipath_k=1, ref_bytes=1 << 20)
    multi = RoutePlanner(topo, multipath_k=2, ref_bytes=1 << 20)
    p1, p2 = single.plan_at(0.0), multi.plan_at(0.0)
    assert not p1.is_split
    nbytes = 1 << 22
    b1 = topo.plan_link_bytes(p1, nbytes).sum()
    b2 = topo.plan_link_bytes(p2, nbytes).sum()
    if p2.is_split:
        # split payloads may traverse longer detours, so total bytes on the
        # wire can only grow; per-logical shares still sum to the payload
        assert b2 >= b1 * (1 - 1e-9)
    else:
        assert b2 == pytest.approx(b1)


def _bare_engine(ccfg):
    import jax
    import jax.numpy as jnp

    from repro.core.fragments import make_fragmenter
    from repro.core.protocol import ProtocolEngine
    from repro.models import api
    params = api.init_params(TINY, jax.random.PRNGKey(0))
    stack = jax.tree.map(
        lambda a: jnp.broadcast_to(
            a[None], (ccfg.num_workers,) + a.shape).copy(), params)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, ccfg.num_fragments)
    return ProtocolEngine("cocodc", ccfg, frag, make_scenario("asym4"), stack)


def test_engine_rejects_bad_traffic_plane_configs():
    with pytest.raises(ValueError, match="routed"):
        _bare_engine(CoCoDCConfig(num_workers=4, multipath_k=2,
                                  routing="static"))
    with pytest.raises(ValueError, match="multipath_k"):
        _bare_engine(CoCoDCConfig(num_workers=4, multipath_k=0))
    with pytest.raises(ValueError, match="channel_scheduler"):
        _bare_engine(CoCoDCConfig(num_workers=4,
                                  channel_scheduler="lottery"))


# ---------------------------------------------------------------------------
# decomposed Eq. 9 (latency/bandwidth split of measured durations)
# ---------------------------------------------------------------------------


def test_decomposed_t_s_recovers_slope():
    rs = ResyncState(window=8)
    for b in (100.0, 200.0, 300.0):
        rs.observe(2.0 + b / 100.0, b)          # T = 2 + b/100
    assert rs.decomposed_t_s(100.0) == pytest.approx(1.0, rel=1e-6)
    # latency never leaks into the bandwidth cost
    assert rs.decomposed_t_s(0.0) == pytest.approx(0.0, abs=1e-12)


def test_decomposed_t_s_degenerate_falls_back_to_anchor():
    rs = ResyncState(window=8)
    for _ in range(3):
        rs.observe(3.0, 100.0)                  # zero byte spread
    # intercept anchored at lat_s=2: slope = (3-2)/100
    assert rs.decomposed_t_s(100.0, lat_s=2.0) == pytest.approx(1.0)


def test_decomposed_t_s_unsized_window_is_none():
    rs = ResyncState(window=8)
    rs.observe(3.0)                             # pre-v6 window: no sizes
    assert rs.decomposed_t_s(100.0) is None
    # rederive falls back to (fallback - lat), floored
    n, h = rederive_schedule(rs, K=2, H=100, t_c=1.0, gamma=0.4,
                             fallback_t_s=5.0, decompose=True,
                             ref_bytes=100.0, lat_s=2.0)
    assert n == max(2, math.floor(0.4 * 100 * 1.0 / 3.0))
    assert h == max(1, 100 // n)


def test_rederive_default_path_unchanged():
    rs = ResyncState(window=8)
    rs.observe(4.0, 100.0)
    n_plain, h_plain = rederive_schedule(rs, K=2, H=100, t_c=1.0, gamma=0.4,
                                         fallback_t_s=5.0)
    assert n_plain == max(2, math.floor(0.4 * 100 * 1.0 / 4.0))
    assert h_plain == max(1, 100 // n_plain)


# ---------------------------------------------------------------------------
# construction-time validation (satellite: no silent max(1, ...) rewrite)
# ---------------------------------------------------------------------------


def test_topology_rejects_nonpositive_concurrent_collectives():
    m = 2
    lat = np.zeros((m, m))
    bw = np.full((m, m), 1e6)
    np.fill_diagonal(bw, np.inf)
    with pytest.raises(ValueError, match="concurrent_collectives"):
        Topology(latency_s=lat, bandwidth_Bps=bw, concurrent_collectives=0)


def test_network_spec_validation():
    base = ExperimentSpec()
    bad_sched = dataclasses.replace(
        base, network=NetworkSpec(channel_scheduler="lottery"))
    with pytest.raises(ValueError, match="channel_scheduler"):
        bad_sched.validate()
    bad_k = dataclasses.replace(base, network=NetworkSpec(multipath_k=0))
    with pytest.raises(ValueError, match="multipath_k"):
        bad_k.validate()
    bad_static = dataclasses.replace(
        base, network=NetworkSpec(multipath_k=2, routing="static"))
    with pytest.raises(ValueError, match="routed"):
        bad_static.validate()
    bad_cc = dataclasses.replace(
        base, network=NetworkSpec(concurrent_collectives=0))
    with pytest.raises(ValueError, match="concurrent_collectives"):
        bad_cc.validate()
    bad_fs = dataclasses.replace(
        base, network=NetworkSpec(topology="asym4", concurrent_collectives=2,
                                  channel_scheduler="fairshare"))
    with pytest.raises(ValueError, match="fairshare"):
        bad_fs.validate()
