"""Tests for the beyond-paper performance features: DP sharding profile, MoE
Megatron overrides, bf16 WAN sync compression."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import CoCoDCConfig, get_config
from repro.launch import sharding as shd
from repro.launch.steps import abstract_params


class FakeMesh:
    axis_names = ("data", "model")

    class _D:
        shape = (16, 16)
    devices = _D()


def test_dp_profile_replicates_params():
    cfg = get_config("qwen3_0_6b")
    sds = abstract_params(cfg)
    specs = shd.param_specs(sds, FakeMesh(), profile="dp")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert s == P()


def test_dp_profile_batch_uses_both_axes():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    specs = shd.batch_specs(batch, FakeMesh(), profile="dp")
    assert specs["tokens"][0] == ("data", "model")
    # non-divisible batch falls back to data-only
    batch2 = {"tokens": jax.ShapeDtypeStruct((32, 128), jnp.int32)}
    specs2 = shd.batch_specs(batch2, FakeMesh(), profile="dp")
    assert specs2["tokens"][0] == "data"


def test_override_rules_take_precedence():
    cfg = get_config("dbrx_132b")
    sds = abstract_params(cfg)
    overrides = [
        (r".*moe/w_(gate|up)$", [P(None, "model", None, "data")]),
        (r".*moe/w_down$", [P(None, "model", "data", None)]),
    ]
    specs = shd.param_specs(sds, FakeMesh(), overrides=overrides)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        p = "/".join(str(getattr(x, "key", x)) for x in path)
        if p.endswith("moe/w_gate"):
            assert spec == P(None, "model", None, "data")
        if p.endswith("moe/w_down"):
            assert spec == P(None, "model", "data", None)


def test_bf16_sync_halves_accounted_bytes():
    from repro.configs.base import ModelConfig
    from repro.core.trainer import CrossRegionTrainer, TrainerConfig
    tiny = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=1, d_ff=64, vocab=64,
                       compute_dtype="float32")
    res = {}
    for dt in ("float32", "bfloat16"):
        ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                            overlap_depth=2, sync_dtype=dt)
        tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                             total_steps=16, warmup_steps=4)
        tr = CrossRegionTrainer(tiny, ccfg, tcfg)
        tr.run(steps=16, eval_every=16, log=lambda s: None)
        res[dt] = tr.engine.stats()["bytes_sent"]
        assert np.isfinite(tr.history[-1]["nll"])
    assert res["bfloat16"] == res["float32"] / 2


def test_bf16_sync_converges():
    """bf16 pseudo-gradient compression must not break training."""
    from repro.configs.base import ModelConfig
    from repro.core.trainer import CrossRegionTrainer, TrainerConfig
    tiny = ModelConfig(name="t", family="dense", n_layers=2, d_model=48,
                       n_heads=2, n_kv_heads=1, d_ff=96, vocab=128,
                       compute_dtype="float32")
    ccfg = CoCoDCConfig(num_workers=2, local_steps=10, num_fragments=2,
                        overlap_depth=2, sync_dtype="bfloat16")
    tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=24,
                         total_steps=40, warmup_steps=5, inner_lr=3e-3)
    tr = CrossRegionTrainer(tiny, ccfg, tcfg)
    tr.run(eval_every=20, log=lambda s: None)
    assert tr.history[-1]["nll"] < tr.history[0]["nll"] + 0.1
