"""Flat fragment plane + fused outer-update kernels (kernels/outer_update).

Covers the PR-8 acceptance contract:
  * FlatView pack/unpack are exact inverses for every fragment strategy;
  * the Pallas kernels track their pure-jnp oracles (allclose at the repo's
    kernel tolerance — jit-vs-interpret FMA contraction is ~1 ulp);
  * the fused deliver transition performs O(1) Pallas dispatches per fragment
    (counted in the traced jaxpr) vs O(leaves) for the per-leaf kernel path;
  * fused_updates=on reproduces the per-leaf engine bitwise on f32 configs,
    and fused_impl="pallas" tracks fused_impl="ref" to kernel tolerance;
  * kill/resume with fused_updates=on replays bitwise; a cross-mode resume
    (fused checkpoint into a per-leaf trainer or vice versa) is rejected;
  * an overlapped method without a fused_delivery mode is rejected by both
    the engine and spec validation.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import CoCoDCConfig
from repro.core import engine_state as es
from repro.core import methods as methods_lib
from repro.core.fragments import Fragmenter, make_fragmenter
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.kernels.outer_update import ops as ou_ops
from repro.kernels.outer_update.ref import deliver_ref, nesterov_ref
from repro.models import api as model_api

from test_engine_state import TINY, engine_for, make_stack, perturb

KEY = jax.random.PRNGKey(7)


def _params(cfg=None):
    return model_api.init_params(cfg or TINY, KEY)


# ---------------------------------------------------------------------------
# FlatView round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", Fragmenter.STRATEGIES)
def test_flatview_pack_unpack_roundtrip(strategy):
    params = _params()
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(TINY, shape, 3, strategy=strategy)
    flat = frag.flat
    for p in range(3):
        buf = flat.pack(params, p)
        assert buf.shape == (flat.rows(p), flat.LANES)
        restored = flat.unpack(params, p, buf)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     params, restored)
        # trailing pad is zero (flat_pseudograd_mean / codec rely on it)
        pad = flat.rows(p) * flat.LANES - flat.elems(p)
        if pad:
            assert float(jnp.max(jnp.abs(buf.reshape(-1)[-pad:]))) == 0.0


@pytest.mark.parametrize("strategy", Fragmenter.STRATEGIES)
def test_flatview_full_and_stack_roundtrip(strategy):
    params = _params()
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(TINY, shape, 2, strategy=strategy)
    flat = frag.flat

    # full-model plane: unpack into a zeros template reproduces the tree
    buf = flat.pack_full(params)
    assert buf.shape == (flat.total_rows, flat.LANES)
    tmpl = jax.tree.map(jnp.zeros_like, params)
    restored = flat.unpack_full(tmpl, buf)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 params, restored)

    # worker axis: (M, rows, LANES) per fragment and full
    stack = make_stack(M=3)
    for p in range(2):
        sbuf = flat.pack_stack(stack, p)
        assert sbuf.shape == (3, flat.rows(p), flat.LANES)
        rs = flat.unpack_stack(stack, p, sbuf)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     stack, rs)
    fbuf = flat.pack_full(stack, worker_axis=True)
    rs = flat.unpack_full(jax.tree.map(jnp.zeros_like, stack), fbuf,
                          worker_axis=True)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), stack, rs)


def test_flatview_offsets_are_static_and_disjoint():
    params = _params()
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(TINY, shape, 3)
    flat = frag.flat
    total_elems = sum(l.size for l in jax.tree.leaves(params))
    assert sum(flat.elems(p) for p in range(3)) == total_elems
    spans = [flat.row_span(p) for p in range(3)]
    assert spans[0][0] == 0 and spans[-1][1] == flat.total_rows
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 == b0              # contiguous, fragment-major, disjoint
        assert isinstance(a0, int) and isinstance(a1, int)


# ---------------------------------------------------------------------------
# kernel vs oracle parity (interpret mode on CPU)
# ---------------------------------------------------------------------------


def _rand(shape, i):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)


def test_nesterov_kernel_matches_ref():
    rows = 7
    t, m, d = (_rand((rows, ou_ops.LANES), i) for i in range(3))
    rg, rm = nesterov_ref(t, m, d, lr=0.7, mu=0.9)
    kg, km = ou_ops.outer_nesterov(t, m, d, lr=0.7, mu=0.9, impl="pallas")
    np.testing.assert_allclose(np.asarray(kg), np.asarray(rg),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(km), np.asarray(rm),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ou_ops.DELIVER_MODES)
def test_deliver_kernel_matches_ref(mode):
    M, rows = 3, 5
    local = _rand((M, rows, ou_ops.LANES), 10)
    snap = _rand((M, rows, ou_ops.LANES), 11)
    g = _rand((rows, ou_ops.LANES), 12)
    avail = jnp.asarray([True, False, True])
    kw = dict(alpha=0.3, tau=3.0, lam=0.5, H=10.0, sign=1.0)
    ref = deliver_ref(local, snap, g, avail, mode=mode, **kw)
    out = ou_ops.fused_deliver(local, snap, g, avail, mode=mode,
                               impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    # offline worker 1 keeps its local params exactly, both impls
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(local[1]))


def test_deliver_rejects_unknown_mode():
    x = jnp.zeros((1, 1, ou_ops.LANES))
    with pytest.raises(ValueError, match="mode"):
        ou_ops.fused_deliver(x, x, x[0], jnp.ones((1,)), mode="nope")


# ---------------------------------------------------------------------------
# dispatch count: O(1) Pallas calls per fused transition vs O(leaves)
# ---------------------------------------------------------------------------


# the canonical jaxpr walker lives in the static-analysis subsystem now —
# one implementation, shared by these tests and `python -m repro.analysis`
from repro.analysis.jaxpr_audit import count_pallas_calls as _count_pallas_calls  # noqa: E402


def _deliver_jaxpr(ccfg, *, dc_impl="ref", fused_impl="auto"):
    stack = make_stack(M=ccfg.num_workers)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, ccfg.num_fragments)
    fns = es.make_engine_fns("cocodc", ccfg, frag, dc_impl=dc_impl,
                             use_jit=True, fused_impl=fused_impl)
    state = es.init_state("cocodc", ccfg, stack, frag=frag)
    jaxpr = jax.make_jaxpr(lambda st, s: fns.deliver(st, 5, s, 0))(
        state, stack)
    return jaxpr.jaxpr


def test_fused_deliver_is_constant_dispatch_count():
    """The acceptance assertion: the fused deliver lowers to exactly TWO
    Pallas dispatches (one Nesterov, one deliver) independent of the model's
    leaf count, where the per-leaf kernel path pays one delay-comp dispatch
    PER LEAF in the fragment."""
    kw = dict(num_workers=2, local_steps=10, num_fragments=2, overlap_depth=2)
    per_leaf = _count_pallas_calls(
        _deliver_jaxpr(CoCoDCConfig(**kw), dc_impl="kernel"))
    fused = _count_pallas_calls(
        _deliver_jaxpr(CoCoDCConfig(fused_updates=True, **kw),
                       fused_impl="pallas"))
    assert fused == 2
    # the per-leaf path dispatches once per fragment leaf — strictly more,
    # and growing with the model's leaf count
    n_leaves_in_frag = len(
        [c for c in make_fragmenter(
            TINY, jax.eval_shape(lambda: _params()), 2).flat._by_path[0]])
    assert per_leaf == n_leaves_in_frag > fused


def test_fused_deliver_dispatches_do_not_grow_with_depth():
    deep = dataclasses.replace(TINY, n_layers=8)
    stack = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (2,) + a.shape).copy(),
        model_api.init_params(deep, KEY))
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(deep, shape, 2)
    ccfg = CoCoDCConfig(num_workers=2, local_steps=10, num_fragments=2,
                        overlap_depth=2, fused_updates=True)
    fns = es.make_engine_fns("cocodc", ccfg, frag, use_jit=True,
                             fused_impl="pallas")
    state = es.init_state("cocodc", ccfg, stack, frag=frag)
    jaxpr = jax.make_jaxpr(lambda st, s: fns.deliver(st, 5, s, 0))(
        state, stack)
    assert _count_pallas_calls(jaxpr.jaxpr) == 2


# ---------------------------------------------------------------------------
# fused engine == per-leaf engine (f32, codec off) — bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["streaming", "cocodc", "diloco"])
def test_fused_engine_bitwise_matches_per_leaf(method):
    """Same schedule, same arithmetic order: the flat plane only changes the
    LAYOUT, so codec-off f32 configs agree bit-for-bit with the per-leaf
    engine — a stronger pin than the fused-vs-own-oracle requirement."""
    eng_a, stack_a = engine_for(method, M=2, H=10, K=2, tau=2)
    eng_b, stack_b = engine_for(method, M=2, H=10, K=2, tau=2,
                                fused_updates=True)
    for t in range(30):
        stack_a = perturb(stack_a, scale=0.01)
        stack_b = jax.tree.map(lambda a: a.copy(), stack_a)
        stack_a = eng_a.on_step_end(t, stack_a)
        stack_b = eng_b.on_step_end(t, stack_b)
    for la, lb in zip(jax.tree.leaves(stack_a), jax.tree.leaves(stack_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for name in ("theta_g", "momentum"):
        for la, lb in zip(jax.tree.leaves(getattr(eng_a, name)),
                          jax.tree.leaves(getattr(eng_b, name))):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert eng_a.stats()["bytes_sent"] == eng_b.stats()["bytes_sent"]


def test_fused_transitions_pallas_tracks_ref():
    """fused_impl="pallas" (interpret on CPU) tracks fused_impl="ref" to the
    repo's kernel tolerance across a full initiate->deliver cycle."""
    ccfg = CoCoDCConfig(num_workers=2, local_steps=10, num_fragments=2,
                        overlap_depth=2, fused_updates=True)
    stack0 = make_stack(M=2)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack0))
    frag = make_fragmenter(TINY, shape, 2)
    outs = {}
    for impl in ("ref", "pallas"):
        fns = es.make_engine_fns("cocodc", ccfg, frag, use_jit=True,
                                 fused_impl=impl)
        state = es.init_state("cocodc", ccfg, stack0, frag=frag)
        stack = perturb(stack0, scale=0.05)
        state = fns.initiate(state, 0, stack, 0)
        stack = perturb(stack, scale=0.01)
        state, stack = fns.deliver(state, 4, stack, 0)
        outs[impl] = (state, stack)
    for a, b in zip(jax.tree.leaves(outs["ref"]),
                    jax.tree.leaves(outs["pallas"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# engine/spec rejection of methods with no fused delivery mode
# ---------------------------------------------------------------------------


def test_fused_rejects_overlapped_method_without_delivery_mode():
    @methods_lib.register_method
    class _Overlap(methods_lib.SyncMethod):       # noqa: F811
        name = "_test_overlap_nofused"
        overlapped = True

    try:
        ccfg = CoCoDCConfig(num_workers=2, local_steps=10, num_fragments=2,
                            overlap_depth=2, fused_updates=True)
        stack = make_stack(M=2)
        shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
        frag = make_fragmenter(TINY, shape, 2)
        with pytest.raises(ValueError, match="fused_delivery"):
            es.make_engine_fns("_test_overlap_nofused", ccfg, frag)

        from repro.api.spec import (ExperimentSpec, MethodExtensions,
                                    MethodSpec)
        spec = ExperimentSpec(method=MethodSpec(
            name="_test_overlap_nofused",
            extensions=MethodExtensions(fused_updates=True)))
        with pytest.raises(ValueError, match="fused"):
            spec.validate()
    finally:
        methods_lib.unregister_method("_test_overlap_nofused")


def test_init_state_fused_requires_fragmenter():
    ccfg = CoCoDCConfig(num_workers=2, local_steps=10, num_fragments=2,
                        overlap_depth=2, fused_updates=True)
    with pytest.raises(ValueError, match="Fragmenter"):
        es.init_state("cocodc", ccfg, make_stack(M=2))


# ---------------------------------------------------------------------------
# kill/resume with fused_updates=on — bitwise replay, cross-mode rejection
# ---------------------------------------------------------------------------


def _trainer(steps=24, loop="segment", **ccfg_kw):
    mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                               compute_dtype="float32")
    ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                        overlap_depth=2, **ccfg_kw)
    tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                         total_steps=steps, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=0, loop=loop)
    return CrossRegionTrainer(mcfg, ccfg, tcfg)


def test_resume_mid_flight_with_fused_updates(tmp_path):
    """Kill/resume with fused_updates=on and a transfer on the wire replays
    the uninterrupted run bitwise — the flat in-flight/snapshot/theta
    buffers round-trip through the checkpoint."""
    ck = os.path.join(tmp_path, "ck.msgpack")
    ref = _trainer(fused_updates=True)
    ref.run(eval_every=8, log=lambda s: None)

    tr = _trainer(fused_updates=True, loop="per_step")
    while not tr.engine.pending:          # stop with a transfer on the wire
        tr.train_one_step()
    tr.save_checkpoint(ck)
    resumed = _trainer(fused_updates=True).restore_checkpoint(ck)
    np.testing.assert_array_equal(np.asarray(resumed.engine.state.theta_g),
                                  np.asarray(tr.engine.state.theta_g))
    resumed.run(eval_every=8, log=lambda s: None)
    ra = {r["step"]: r["nll"] for r in ref.history}
    rb = {r["step"]: r["nll"] for r in resumed.history}
    assert set(rb) and all(ra[s] == rb[s] for s in sorted(set(ra) & set(rb)))
    sr, ss = ref.engine.stats(), resumed.engine.stats()
    assert sr["bytes_sent"] == ss["bytes_sent"]


def test_fused_mismatch_rejected_on_resume(tmp_path):
    """The flat plane changes engine-state SHAPES, so a cross-mode resume is
    rejected up front by the trajectory-meta check (schema v5)."""
    ck = os.path.join(tmp_path, "ck.msgpack")
    tr = _trainer(steps=8, fused_updates=True)
    tr.run(eval_every=8, log=lambda s: None)
    tr.save_checkpoint(ck)
    with pytest.raises(ValueError, match="fused_updates"):
        _trainer(steps=8).restore_checkpoint(ck)
    ck2 = os.path.join(tmp_path, "ck2.msgpack")
    tr2 = _trainer(steps=8)
    tr2.run(eval_every=8, log=lambda s: None)
    tr2.save_checkpoint(ck2)
    with pytest.raises(ValueError, match="fused_updates"):
        _trainer(steps=8, fused_updates=True).restore_checkpoint(ck2)
