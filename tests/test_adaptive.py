"""Adaptive transmission (Algorithm 2, Eqs. 9-12) properties."""
import math

import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip, never collection-error
from hypothesis import given, settings, strategies as st

from repro.core.adaptive import (AdaptiveState, select_fragment, sync_interval,
                                 target_syncs, update_rate)


def test_eq9_paper_numbers():
    """Paper §IV: gamma=0.4, H=100, T_s = 5*T_c, K=4 -> N=8 syncs per round."""
    assert target_syncs(K=4, H=100, t_c=1.0, t_s=5.0, gamma=0.4) == 8
    assert sync_interval(100, 8) == 12


def test_eq9_floor_at_K():
    """N = max(K, ...) guarantees at least one sync per fragment per round."""
    assert target_syncs(K=4, H=100, t_c=1.0, t_s=50.0, gamma=0.4) == 4
    assert target_syncs(K=4, H=100, t_c=1.0, t_s=1e9, gamma=0.4) == 4


def test_state_defaults_are_per_instance():
    """The K/H-derived defaults come from default_factory + __post_init__
    fill-in: instances never share a mutable default, and explicit lists are
    taken as-is."""
    a = AdaptiveState(K=3, H=10)
    b = AdaptiveState(K=3, H=10)
    assert a.last_sync == [-10] * 3 and a.rate == [math.inf] * 3
    a.last_sync[0] = 99
    a.rate[0] = 1.0
    assert b.last_sync[0] == -10 and b.rate[0] == math.inf
    c = AdaptiveState(K=2, H=5, last_sync=[1, 2], rate=[0.5, 0.25])
    assert c.last_sync == [1, 2] and c.rate == [0.5, 0.25]


def test_initial_priority_is_unsynced():
    st8 = AdaptiveState(K=4, H=100)
    # before any sync completes, rates are +inf and last_sync=-H => anti-starvation
    # fires for fragment 0 first (deterministic)
    assert select_fragment(st8, t_current=0) == 0


def test_argmax_rate_selection():
    s = AdaptiveState(K=3, H=100)
    for p, norm in [(0, 1.0), (1, 5.0), (2, 2.0)]:
        update_rate(s, p, norm, t_complete=10)
    assert select_fragment(s, t_current=20) == 1
    update_rate(s, 2, 100.0, t_complete=30)
    assert select_fragment(s, t_current=40) == 2


def test_anti_starvation_beats_rate():
    s = AdaptiveState(K=3, H=50)
    update_rate(s, 0, 1.0, t_complete=10)
    update_rate(s, 1, 100.0, t_complete=60)
    update_rate(s, 2, 50.0, t_complete=60)
    # fragment 0 idle >= H=50 steps at t=60 -> selected despite lowest rate
    assert select_fragment(s, t_current=60) == 0


def test_in_flight_exclusion():
    s = AdaptiveState(K=3, H=100)
    for p in range(3):
        update_rate(s, p, float(3 - p), t_complete=10)
    assert select_fragment(s, 20, in_flight={0}) == 1


@settings(max_examples=30, deadline=None)
@given(K=st.integers(2, 8), H=st.integers(8, 200), seed=st.integers(0, 1000))
def test_determinism_across_workers(K, H, seed):
    """Two engines fed identical shared history pick identical fragments — the
    paper's zero-coordination claim."""
    import random
    rng = random.Random(seed)
    s1 = AdaptiveState(K=K, H=H)
    s2 = AdaptiveState(K=K, H=H)
    t = 0
    for _ in range(50):
        t += rng.randint(1, 5)
        p1 = select_fragment(s1, t)
        p2 = select_fragment(s2, t)
        assert p1 == p2
        norm = rng.random() * 10
        update_rate(s1, p1, norm, t)
        update_rate(s2, p2, norm, t)


@settings(max_examples=20, deadline=None)
@given(K=st.integers(2, 6), H=st.integers(10, 60))
def test_starvation_bound(K, H):
    """Simulated schedule: no fragment's sync interval ever exceeds H + h steps
    (invariant 4, DESIGN.md §7)."""
    s = AdaptiveState(K=K, H=H)
    N = max(K, 2 * K)
    h = sync_interval(H, N)
    t = 0
    last = {p: 0 for p in range(K)}
    for it in range(400):
        t += h
        p = select_fragment(s, t)
        assert t - last[p] <= H + h, (p, t, last[p])
        # adversarial rates: fragment 0 always looks hottest
        update_rate(s, p, 1000.0 if p == 0 else 0.001, t)
        last[p] = t
