"""Property tests: fragmentation is a disjoint exact cover; extract/insert is an
identity; fragment bytes are balanced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, never collection-error
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.core.fragments import Fragmenter, make_fragmenter
from repro.models import api


def tiny_cfg(n_layers=6):
    return ModelConfig(name="t", family="dense", n_layers=n_layers, d_model=32,
                       n_heads=2, n_kv_heads=1, d_ff=64, vocab=64)


def make_params(cfg):
    return api.init_params(cfg, jax.random.PRNGKey(0))


@settings(max_examples=20, deadline=None)
@given(K=st.integers(1, 6), L=st.integers(2, 8), strided=st.booleans())
def test_cover_is_disjoint_and_exact(K, L, strided):
    cfg = tiny_cfg(L)
    params = make_params(cfg)
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(cfg, shape, K, strided=strided)

    # zeroing every fragment zeroes the whole tree (exact cover)
    tree = params
    for p in range(K):
        fp = frag.extract(tree, p)
        zeros = jax.tree.map(lambda a: None if a is None else jnp.zeros_like(a),
                             fp, is_leaf=lambda x: x is None)
        tree = frag.insert(tree, p, zeros)
    for leaf in jax.tree.leaves(tree):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0

    # total fragment bytes == total param bytes (disjoint: no double counting)
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    assert sum(frag.fragment_bytes(p) for p in range(K)) == total


@settings(max_examples=15, deadline=None)
@given(K=st.integers(1, 5), seed=st.integers(0, 100))
def test_extract_insert_roundtrip(K, seed):
    cfg = tiny_cfg()
    params = make_params(cfg)
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(cfg, shape, K)
    p = seed % K
    fp = frag.extract(params, p)
    restored = frag.insert(params, p, fp)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), params, restored)


def test_insert_modifies_only_fragment():
    cfg = tiny_cfg()
    params = make_params(cfg)
    shape = jax.eval_shape(lambda: params)
    K = 3
    frag = make_fragmenter(cfg, shape, K)
    fp = frag.extract(params, 1)
    bumped = jax.tree.map(lambda a: None if a is None else a + 1.0, fp,
                          is_leaf=lambda x: x is None)
    new = frag.insert(params, 1, bumped)
    # fragment 1 changed, fragments 0/2 untouched
    f1_new = frag.extract(new, 1)
    jax.tree.map(lambda a, b: (None if a is None else
                               np.testing.assert_allclose(a, b + 1.0, rtol=1e-6)),
                 f1_new, fp, is_leaf=lambda x: x is None)
    for other in (0, 2):
        a = frag.extract(params, other)
        b = frag.extract(new, other)
        jax.tree.map(lambda x, y: (None if x is None
                                   else np.testing.assert_array_equal(x, y)),
                     a, b, is_leaf=lambda x: x is None)


def test_worker_axis_extraction():
    cfg = tiny_cfg()
    params = make_params(cfg)
    M = 3
    stack = jax.tree.map(lambda a: jnp.stack([a + i for i in range(M)]), params)
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(cfg, shape, 2)
    fp = frag.extract(stack, 0, worker_axis=True)
    for leaf in jax.tree.leaves(fp):
        assert leaf.shape[0] == M
    restored = frag.insert(stack, 0, fp, worker_axis=True)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), stack, restored)


def test_balanced_bytes():
    """Layered leaves are strided across fragments; whole leaves balance greedily —
    largest/smallest fragment ratio stays bounded."""
    cfg = tiny_cfg(8)
    shape = jax.eval_shape(lambda: make_params(cfg))
    K = 4
    frag = make_fragmenter(cfg, shape, K)
    sizes = [frag.fragment_bytes(p) for p in range(K)]
    assert max(sizes) <= 3 * min(sizes)


@settings(max_examples=15, deadline=None)
@given(K=st.integers(1, 6), L=st.integers(2, 12))
def test_skewed_cover_is_disjoint_and_exact(K, L):
    """strategy="skewed" is still a disjoint exact cover."""
    cfg = tiny_cfg(L)
    params = make_params(cfg)
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(cfg, shape, K, strategy="skewed")
    tree = params
    for p in range(K):
        fp = frag.extract(tree, p)
        zeros = jax.tree.map(lambda a: None if a is None else jnp.zeros_like(a),
                             fp, is_leaf=lambda x: x is None)
        tree = frag.insert(tree, p, zeros)
    for leaf in jax.tree.leaves(tree):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    assert sum(frag.fragment_bytes(p) for p in range(K)) == total


def test_skewed_bytes_actually_skew():
    """Geometric byte shares: fragment 0 is the heaviest, sizes decrease, and
    every fragment keeps >= 1 layer when depth allows — so per-fragment WAN
    costs differ enough for Algorithm-2 pricing to flip selections."""
    cfg = tiny_cfg(12)
    shape = jax.eval_shape(lambda: make_params(cfg))
    K = 4
    skew = make_fragmenter(cfg, shape, K, strategy="skewed")
    flat = make_fragmenter(cfg, shape, K)           # strided baseline
    sk = [skew.fragment_bytes(p) for p in range(K)]
    fl = [flat.fragment_bytes(p) for p in range(K)]
    assert sk[0] == max(sk) and sk[0] > sk[K - 1]
    assert all(s > 0 for s in sk)
    # meaningfully more spread than the balanced baseline
    assert (max(sk) / min(sk)) > 1.5 * (max(fl) / min(fl))
    # layered rows are consecutive, every fragment owns at least one layer
    for pl in skew._plans.values():
        if pl.is_layered:
            assert all(len(r) >= 1 for r in pl.rows)
            for r in pl.rows:
                assert list(r) == list(range(r[0], r[0] + len(r)))


def test_fragment_strategy_validation():
    cfg = tiny_cfg()
    shape = jax.eval_shape(lambda: make_params(cfg))
    with pytest.raises(ValueError, match="strategy"):
        make_fragmenter(cfg, shape, 2, strategy="zigzag")
    # legacy flag still selects the old patterns
    assert make_fragmenter(cfg, shape, 2, strided=True).strategy == "strided"
    assert make_fragmenter(cfg, shape, 2, strided=False).strategy == "contiguous"


@pytest.mark.parametrize("arch_family", ["moe", "hybrid", "audio"])
def test_fragmenter_nondense_families(arch_family):
    from repro.configs import get_config
    arch = {"moe": "granite_moe_3b_a800m", "hybrid": "recurrentgemma_9b",
            "audio": "seamless_m4t_large_v2"}[arch_family]
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(cfg, shape, 4)
    tree = params
    for p in range(4):
        fp = frag.extract(tree, p)
        zeros = jax.tree.map(lambda a: None if a is None else jnp.zeros_like(a),
                             fp, is_leaf=lambda x: x is None)
        tree = frag.insert(tree, p, zeros)
    for leaf in jax.tree.leaves(tree):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0
