"""Declarative experiment API: spec JSON round-trips, cross-field validation,
the sync-method registry, legacy-flags-vs-spec bitwise parity, spec_hash
resume validation, and the --print-spec -> --spec -> resume CLI loop."""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.api import (ExperimentSpec, MethodExtensions, MethodSpec, ModelRef,
                       NetworkSpec, RunSpec, SyncMethod, build_experiment,
                       get_method, register_method, registered_methods,
                       unregister_method)
from repro.core.protocol import (SCHEDULER_SCHEMA_VERSION,
                                 upgrade_scheduler_state)
from repro.launch.train import main as train_main
from repro.launch.train import make_parser, spec_from_args


def tiny_spec(**run_kw) -> ExperimentSpec:
    run = dict(steps=12, local_batch=2, seq_len=16, inner_lr=3e-3,
               warmup_steps=2, eval_batch=4, eval_every=6, noniid_frac=0.25)
    run.update(run_kw)
    return ExperimentSpec(
        model=ModelRef(arch="bench_tiny"),
        method=MethodSpec(name="cocodc", num_workers=2, local_steps=6,
                          num_fragments=2, overlap_depth=2),
        run=RunSpec(**run))


# ---------------------------------------------------------------------------
# serialization round-trips
# ---------------------------------------------------------------------------


def test_spec_dict_roundtrip_identity():
    spec = tiny_spec()
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_spec_file_roundtrip_identity(tmp_path):
    spec = dataclasses.replace(
        tiny_spec(), name="rt", note="round-trip",
        network=NetworkSpec(mesh="ring", mesh_seed=3,
                            dynamics="diurnal:period=24:depth=0.5",
                            bw_scale="auto"))
    path = spec.save(os.path.join(tmp_path, "s.json"))
    rt = ExperimentSpec.from_json_file(path)
    assert rt == spec
    assert rt.spec_hash == spec.spec_hash


def test_spec_json_number_coercion_keeps_hash_stable():
    """A JSON integer in a float field (e.g. "mixing_alpha": 1) must coerce
    to float so the canonical form — and the hash — is stable."""
    spec = tiny_spec()
    d = spec.to_dict()
    d["method"]["mixing_alpha"] = 1
    a = ExperimentSpec.from_dict(d)
    d["method"]["mixing_alpha"] = 1.0
    b = ExperimentSpec.from_dict(d)
    assert a == b and a.spec_hash == b.spec_hash
    assert isinstance(a.method.mixing_alpha, float)


def test_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown top-level"):
        ExperimentSpec.from_dict({"modle": {}})
    with pytest.raises(ValueError, match="unknown spec field"):
        ExperimentSpec.from_dict({"run": {"stepz": 10}})
    with pytest.raises(ValueError, match="method.extensions"):
        ExperimentSpec.from_dict(
            {"method": {"extensions": {"link_prcing": True}}})


# ---------------------------------------------------------------------------
# cross-field validation
# ---------------------------------------------------------------------------


def test_validate_mesh_topology_exclusive():
    spec = dataclasses.replace(
        tiny_spec(), network=NetworkSpec(mesh="ring", topology="asym4"))
    with pytest.raises(ValueError, match="mutually exclusive"):
        spec.validate()


def test_validate_routed_needs_explicit_network():
    spec = dataclasses.replace(tiny_spec(), network=NetworkSpec(routing="routed"))
    with pytest.raises(ValueError, match="routed"):
        spec.validate()
    # with a mesh it passes
    dataclasses.replace(
        tiny_spec(), network=NetworkSpec(mesh="ring", routing="routed")).validate()


def test_validate_hub_failover_needs_routed():
    spec = dataclasses.replace(tiny_spec(),
                               network=NetworkSpec(mesh="ring",
                                                   hub_failover=True))
    with pytest.raises(ValueError, match="hub_failover"):
        spec.validate()


def test_validate_adaptive_resync_needs_cocodc():
    spec = dataclasses.replace(
        tiny_spec(),
        method=dataclasses.replace(
            tiny_spec().method, name="diloco",
            extensions=MethodExtensions(adaptive_resync=True)))
    with pytest.raises(ValueError, match="adaptive_resync"):
        spec.validate()


def test_validate_unknown_method_lists_registered():
    spec = dataclasses.replace(
        tiny_spec(), method=dataclasses.replace(tiny_spec().method,
                                                name="quantum_sgd"))
    with pytest.raises(ValueError, match="registered methods"):
        spec.validate()
    with pytest.raises(ValueError, match="cocodc"):
        spec.validate()


def test_validate_unknown_arch_and_scenarios():
    with pytest.raises(ValueError, match="unknown arch"):
        dataclasses.replace(tiny_spec(), model=ModelRef(arch="gpt9")).validate()
    with pytest.raises(ValueError, match="unknown mesh"):
        dataclasses.replace(tiny_spec(),
                            network=NetworkSpec(mesh="torus")).validate()
    with pytest.raises(ValueError, match="unknown topology"):
        dataclasses.replace(tiny_spec(),
                            network=NetworkSpec(topology="moon")).validate()


# ---------------------------------------------------------------------------
# sync-method registry
# ---------------------------------------------------------------------------


def test_get_method_error_lists_registered():
    with pytest.raises(ValueError) as e:
        get_method("nope")
    for name in ("diloco", "streaming", "cocodc", "local"):
        assert name in str(e.value)
    assert set(registered_methods()) >= {"diloco", "streaming", "cocodc",
                                         "local"}


def test_custom_method_registers_and_runs():
    """A new strategy registered via @register_method is selectable by name
    end-to-end (spec -> build_experiment -> ProtocolEngine) with no core
    edits — here: streaming with a double-rate cadence."""
    @register_method
    class EagerStreaming(type(get_method("streaming"))):
        name = "eager_streaming"

        def sync_interval(self, eng):
            return max(1, eng.h_stream // 2)

        def initiate_due(self, eng, t, params_stack):
            h = self.sync_interval(eng)
            if t % h == 0:
                p = (t // h) % eng.K
                if all(ev.frag != p for ev in eng.pending):
                    eng._initiate(t, params_stack, p)

    try:
        assert "eager_streaming" in registered_methods()
        spec = dataclasses.replace(
            tiny_spec(steps=8),
            method=dataclasses.replace(tiny_spec().method,
                                       name="eager_streaming"))
        tr = build_experiment(spec)
        hist = tr.run(eval_every=8, log=lambda s: None)
        assert np.isfinite(hist[-1]["nll"])
        assert tr.engine.n_syncs > 0
    finally:
        unregister_method("eager_streaming")
    with pytest.raises(ValueError, match="eager_streaming"):
        get_method("eager_streaming")


def test_unknown_method_raises_in_engine():
    """The former bare `assert method in (...)` is now a registry lookup with
    an actionable error, surfaced through the trainer stack too."""
    spec = tiny_spec()
    bad = dataclasses.replace(spec.method, name="not_a_method")
    with pytest.raises(ValueError, match="registered methods"):
        build_experiment(dataclasses.replace(spec, method=bad))


# ---------------------------------------------------------------------------
# flags vs spec parity
# ---------------------------------------------------------------------------

FLAGS = ["--arch", "bench_tiny", "--method", "cocodc", "--workers", "2",
         "--H", "6", "--fragments", "2", "--tau", "2", "--steps", "12",
         "--local-batch", "2", "--seq-len", "16", "--lr", "0.003",
         "--eval-every", "6"]


def _history_and_params(tr):
    tr.run(eval_every=6, log=lambda s: None)
    return tr.history, jax.tree.leaves(tr.params_stack)


def test_flags_and_spec_produce_bitwise_identical_trajectories():
    """Acceptance: the same flags and the equivalent spec construct trainers
    with identical short trajectories (eval history and final params
    bitwise-equal)."""
    args = make_parser().parse_args(FLAGS)
    spec_flags = spec_from_args(args)
    spec_manual = dataclasses.replace(tiny_spec(), run=dataclasses.replace(
        tiny_spec().run, warmup_steps=None, eval_batch=16))
    assert spec_flags == spec_manual
    assert spec_flags.spec_hash == spec_manual.spec_hash

    h_a, p_a = _history_and_params(build_experiment(spec_flags))
    h_b, p_b = _history_and_params(build_experiment(spec_manual))
    assert len(h_a) == len(h_b) > 0
    for ra, rb in zip(h_a, h_b):
        assert ra["nll"] == rb["nll"]
        assert ra["train_loss"] == rb["train_loss"]
        assert ra["wall_clock_s"] == rb["wall_clock_s"]
    for x, y in zip(p_a, p_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# spec_hash + resume validation
# ---------------------------------------------------------------------------


def test_spec_hash_ignores_volatile_fields_only():
    base = tiny_spec()
    # eval/checkpoint cadence and the bitwise-pinned execution knobs do not
    # change the trajectory -> same hash
    same = dataclasses.replace(
        base, name="other", run=dataclasses.replace(
            base.run, eval_every=3, ckpt_every=4, loop="per_step",
            engine_impl="host", eval_batch=2, max_segment=32))
    assert same.spec_hash == base.spec_hash
    # any trajectory-determining field changes it
    for variant in (
            dataclasses.replace(base, run=dataclasses.replace(base.run, seed=1)),
            dataclasses.replace(base, run=dataclasses.replace(base.run, steps=13)),
            dataclasses.replace(base, method=dataclasses.replace(
                base.method, local_steps=7)),
            dataclasses.replace(base, network=NetworkSpec(mesh="ring")),
            dataclasses.replace(base, model=ModelRef(arch="bench_tiny",
                                                     reduced=True))):
        assert variant.spec_hash != base.spec_hash, variant


def test_spec_hash_resume_rejects_mismatched_spec(tmp_path):
    ck = os.path.join(tmp_path, "ck.msgpack")
    tr = build_experiment(tiny_spec(steps=6))
    tr.run(eval_every=6, log=lambda s: None)
    tr.save_checkpoint(ck)

    # identical spec resumes cleanly
    build_experiment(tiny_spec(steps=6)).restore_checkpoint(ck)

    # a different seed is rejected, naming the differing field
    other = tiny_spec(steps=6, seed=1)
    with pytest.raises(ValueError, match=r"run\.seed"):
        build_experiment(other).restore_checkpoint(ck)

    # a spec-less (directly constructed) trainer still validates per-key
    from repro.core.trainer import CrossRegionTrainer
    from repro.api import resolve_model
    spec = tiny_spec(steps=6)
    direct = CrossRegionTrainer(resolve_model(spec),
                                spec.method.to_cocodc(spec.network),
                                spec.run.to_trainer_config("diloco"))
    with pytest.raises(ValueError, match="method"):
        direct.restore_checkpoint(ck)


def test_checkpoint_meta_carries_spec(tmp_path):
    ck = os.path.join(tmp_path, "ck.msgpack")
    spec = tiny_spec(steps=6)
    tr = build_experiment(spec)
    tr.run(eval_every=6, log=lambda s: None)
    tr.save_checkpoint(ck)
    from repro.checkpoint import load_pytree
    meta = load_pytree(ck)["meta"]
    assert meta["spec_hash"] == spec.spec_hash
    assert ExperimentSpec.from_dict(meta["spec"]) == spec
    assert meta["schema_version"] >= 2


# ---------------------------------------------------------------------------
# versioned scheduler-state schema (one upgrade path)
# ---------------------------------------------------------------------------


def test_upgrade_scheduler_state_from_v1():
    v1 = {"pending": [[0, 1, 3, 4.0, 0]], "seq": 1, "comm_seconds": 4.0,
          "bytes_sent": 100, "n_syncs": 1, "channel_free": [4.0],
          "worker_available": [True, True],
          "link_bytes": np.zeros((2, 2)), "link_seconds": np.zeros((2, 2))}
    up = upgrade_scheduler_state(v1)
    assert up["schema_version"] == SCHEDULER_SCHEMA_VERSION
    # duration, wire, and transfer id appended (unknown wire = 0, tid = -1)
    assert up["pending"] == [[0, 1, 3, 4.0, 0, 0.0, 0, -1]]
    assert up["dyn_seq"] == 0 and up["n_retries"] == 0
    assert up["routing"]["plan_time"] == -1.0
    assert up["routing"]["plan_dark"] == []
    assert up["resync"]["N"] is None                    # keep engine-derived
    assert up["resync"]["measured_bytes"] == []
    assert up["multipath_splits"] == 0 and up["transfer_log"] == []
    assert up["fairshare"] is None
    # current-version state passes through unchanged
    v4 = dict(up, dyn_seq=7, routing=dict(up["routing"], reroutes=2))
    up2 = upgrade_scheduler_state(v4)
    assert up2["dyn_seq"] == 7 and up2["routing"]["reroutes"] == 2


# ---------------------------------------------------------------------------
# CLI: --print-spec -> --spec -> resume reproduces the run bitwise
# ---------------------------------------------------------------------------


def test_cli_print_spec_spec_resume_bitwise(tmp_path, capsys):
    """Acceptance: a spec saved with --print-spec, fed back via --spec, and
    resumed from its checkpoint reproduces the original flags-run bitwise."""
    flags = FLAGS + ["--seed", "3"]
    ref_hist = os.path.join(tmp_path, "ref.json")
    assert train_main(flags + ["--history-out", ref_hist]) == 0
    capsys.readouterr()

    assert train_main(flags + ["--print-spec"]) == 0
    spec_path = os.path.join(tmp_path, "spec.json")
    with open(spec_path, "w") as f:
        f.write(capsys.readouterr().out)

    ck = os.path.join(tmp_path, "ck.msgpack")
    assert train_main(["--spec", spec_path, "--stop-at", "6",
                       "--ckpt", ck]) == 0
    res_hist = os.path.join(tmp_path, "res.json")
    assert train_main(["--spec", spec_path, "--resume", ck,
                       "--history-out", res_hist]) == 0

    ref = {r["step"]: r for r in json.load(open(ref_hist))["history"]}
    res = {r["step"]: r for r in json.load(open(res_hist))["history"]}
    shared = sorted(set(ref) & set(res))
    assert shared, "no common eval steps"
    for s in shared:
        assert ref[s]["nll"] == res[s]["nll"]
        assert ref[s]["wall_clock_s"] == res[s]["wall_clock_s"]
