"""Delta wire codec: oracle/kernel bitwise parity, round-trip error bounds,
error-feedback bias cancellation, engine threading (bytes accounting, residual
state), checkpoint upgrade paths, and the bitwise codec="none" pin against the
pre-codec (PR 5) trajectories."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import CoCoDCConfig
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.kernels.delta_codec import ops as codec_ops
from repro.kernels.delta_codec import ref as ref_lib
from repro.kernels.delta_codec.ops import CODEC_BITS, wire_bytes

KEY = jax.random.PRNGKey(0)

CODECS = ("int8", "int4")
SHAPES = ((7,), (300,), (33, 65), (2048,), (5, 1000))


def rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.fold_in(KEY, seed), shape,
                             jnp.float32) * scale


def _block_scales(x, block, levels):
    """Per-block absmax/levels over the padded flat layout — the max per-
    element reconstruction half-step."""
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-len(flat)) % block
    flat = np.pad(flat, (0, pad))
    absmax = np.abs(flat.reshape(-1, block)).max(axis=1)
    return absmax * np.float32(1.0 / levels)


# ---------------------------------------------------------------------------
# oracle <-> kernel bitwise parity, wire format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("block", [256, 512])
@pytest.mark.parametrize("shape", SHAPES)
def test_ref_pallas_bitwise_parity(codec, block, shape):
    """The fused kernel (interpret mode on CPU) and the pure-jnp oracle agree
    BITWISE on packed codes, scales, and the round-tripped payload."""
    x = rand(shape, seed=hash((codec, block, shape)) % 1000)
    pr, sr = codec_ops.encode_array(x, codec=codec, block=block, impl="ref")
    pk, sk = codec_ops.encode_array(x, codec=codec, block=block, impl="pallas")
    np.testing.assert_array_equal(np.asarray(pr), np.asarray(pk))
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(sk))
    rt_r = codec_ops.codec_roundtrip_array(x, codec=codec, block=block,
                                           impl="ref")
    rt_k = codec_ops.codec_roundtrip_array(x, codec=codec, block=block,
                                           impl="pallas")
    np.testing.assert_array_equal(np.asarray(rt_r), np.asarray(rt_k))


def test_pallas_rejects_unaligned_block():
    x = rand((128,))
    with pytest.raises(ValueError, match="block"):
        codec_ops.encode_array(x, codec="int8", block=10, impl="pallas")
    # auto silently falls back to the oracle for the same block
    codec_ops.codec_roundtrip_array(x, codec="int8", block=10, impl="auto")


def test_int4_pack_unpack_exact():
    """Halves-packing is lossless on the code ints, including negatives."""
    codes = jnp.arange(-7, 8, dtype=jnp.int8)
    codes = jnp.tile(codes, 36)[: 512].reshape(2, 256)
    packed = ref_lib.pack_ref(codes, bits=4)
    assert packed.shape == (2, 128)
    np.testing.assert_array_equal(np.asarray(ref_lib.unpack_ref(packed, bits=4)),
                                  np.asarray(codes))


@pytest.mark.parametrize("codec", CODECS)
def test_roundtrip_error_bounded_by_half_step(codec):
    """Per element, |x - decode(encode(x))| <= block_absmax/levels/2: absmax
    scaling never clips, so the only loss is rounding to the nearest level."""
    levels = {"int8": 127, "int4": 7}[codec]
    for seed, shape in enumerate(SHAPES):
        x = rand(shape, seed=seed, scale=3.0)
        rt = codec_ops.codec_roundtrip_array(x, codec=codec, block=256)
        err = np.abs(np.asarray(x) - np.asarray(rt)).reshape(-1)
        half = np.repeat(_block_scales(x, 256, levels) * 0.5, 256)[: err.size]
        assert (err <= half + 1e-7).all()


def test_zero_block_roundtrips_to_exact_zero():
    x = jnp.zeros((512,), jnp.float32)
    packed, scales = codec_ops.encode_array(x, codec="int8", block=256)
    assert not np.asarray(packed).any() and not np.asarray(scales).any()
    rt = codec_ops.codec_roundtrip_array(x, codec="int8", block=256)
    assert not np.asarray(rt).any()


def test_wire_bytes_formula_and_ratios():
    """codes + one f32 scale per block; int8/int4 at block=256 clear the
    3.5x / 7x compression floors that the sweep frontier enforces."""
    assert wire_bytes(256, codec="int8", block=256) == 256 + 4
    assert wire_bytes(256, codec="int4", block=256) == 128 + 4
    assert wire_bytes(257, codec="int8", block=256) == 257 + 8
    assert wire_bytes(1, codec="int4", block=256) == 1 + 4
    n = 1 << 20
    assert n * 4 / wire_bytes(n, codec="int8", block=256) > 3.5
    assert n * 4 / wire_bytes(n, codec="int4", block=256) > 7.0


# ---------------------------------------------------------------------------
# error feedback: cumulative quantization bias -> ~0
# ---------------------------------------------------------------------------


def _ef_bias(d, rounds, codec, ef):
    """Mean cumulative bias per round of repeatedly shipping the SAME delta
    through the codec, with/without the EF residual fold-in."""
    e = jnp.zeros_like(d)
    acc = jnp.zeros_like(d)
    for _ in range(rounds):
        din = d + e if ef else d
        q = codec_ops.codec_roundtrip_array(din, codec=codec, block=256)
        if ef:
            e = din - q
        acc = acc + (q - d)
    return float(jnp.abs(acc).mean()) / rounds


@pytest.mark.parametrize("codec", CODECS)
def test_error_feedback_cancels_cumulative_bias(codec):
    """Without EF the per-round rounding bias accumulates linearly; with EF
    the residual re-enters the next round and the time-averaged payload
    converges to the true delta (EF-SGD)."""
    d = rand((4096,), seed=7, scale=0.05)
    with_ef = _ef_bias(d, 24, codec, ef=True)
    without = _ef_bias(d, 24, codec, ef=False)
    levels = {"int8": 127, "int4": 7}[codec]
    step = float(_block_scales(d, 256, levels).mean())
    # EF: bounded by ~one quantization step spread over the window
    assert with_ef < 2.0 * step / 24 + 1e-9
    # and at least an order of magnitude below the open-loop bias (unless the
    # open-loop path happens to be unbiased already, which it is not here)
    assert with_ef < without / 10


# ---------------------------------------------------------------------------
# hypothesis property tests (optional dev dep — fixed cases above always run)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 1000), n=st.integers(1, 2000),
           scale=st.floats(1e-4, 1e3), codec=st.sampled_from(CODECS))
    def test_roundtrip_error_bound_property(seed, n, scale, codec):
        levels = {"int8": 127, "int4": 7}[codec]
        x = rand((n,), seed=seed, scale=scale)
        rt = codec_ops.codec_roundtrip_array(x, codec=codec, block=256)
        err = np.abs(np.asarray(x) - np.asarray(rt))
        half = np.repeat(_block_scales(x, 256, levels) * 0.5, 256)[: n]
        assert (err <= half * (1 + 1e-6) + 1e-9).all()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 200), codec=st.sampled_from(CODECS))
    def test_error_feedback_bias_property(seed, codec):
        d = rand((1024,), seed=seed, scale=0.1)
        levels = {"int8": 127, "int4": 7}[codec]
        step = float(_block_scales(d, 256, levels).mean())
        assert _ef_bias(d, 16, codec, ef=True) < 2.0 * step / 16 + 1e-9
except ImportError:
    pass


# ---------------------------------------------------------------------------
# engine threading: bytes accounting + residual state
# ---------------------------------------------------------------------------


def _engine(codec="none", **kw):
    from test_engine_state import engine_for, perturb
    eng, stack = engine_for("cocodc", H=6, K=2, tau=2, wire_codec=codec, **kw)
    return eng, perturb(stack)


def test_codec_off_keeps_residual_out_of_state():
    """codec="none" must not grow the EngineState pytree (checkpoint layout
    and the traced program stay identical to the pre-codec engine)."""
    from repro.core import engine_state as es
    eng, _ = _engine("none")
    assert eng.state.wire_residual is None
    d = es.state_to_dict(eng.state)
    assert d["wire_residual"] is None
    assert es.state_from_dict(eng.state, d).wire_residual is None


@pytest.mark.parametrize("codec,floor", [("int8", 3.5), ("int4", 7.0)])
def test_engine_codec_shrinks_wire(codec, floor):
    """The scheduler's bytes/transfer accounting sees the compressed payload:
    raw/wire ratio clears the codec's floor and per-transfer time shrinks."""
    eng_n, s = _engine("none")
    eng_c, _ = _engine(codec)
    sn, sc = s, s
    for t in range(30):
        sn = eng_n.on_step_end(t, sn)
        sc = eng_c.on_step_end(t, sc)
    stn, stc = eng_n.stats(), eng_c.stats()
    assert stn["compression_ratio"] == 1.0
    assert stc["compression_ratio"] > floor
    assert stc["mean_transfer_s"] < stn["mean_transfer_s"]
    assert stc["wire_bytes_total"] < stc["wire_bytes_raw"]
    # residual buffers engaged and non-trivial after real initiations
    assert any(float(np.abs(np.asarray(l)).max()) > 0
               for l in jax.tree.leaves(eng_c.state.wire_residual))


def test_engine_codec_ef_off_has_no_residual():
    eng, s = _engine("int8", codec_error_feedback=False)
    for t in range(12):
        s = eng.on_step_end(t, s)
    assert eng.state.wire_residual is None
    assert eng.stats()["compression_ratio"] > 3.5


def test_pre_codec_engine_dict_restores_with_zero_residual():
    """A serialized EngineState written before the codec existed has no
    `wire_residual` entry: restoring into a codec-enabled engine restarts
    error feedback from the ref state's zero residual."""
    from repro.core import engine_state as es
    eng, s = _engine("int8")
    for t in range(12):
        s = eng.on_step_end(t, s)
    d = es.state_to_dict(eng.state)
    assert "wire_residual" in d
    d.pop("wire_residual")
    ref, _ = _engine("int8")            # freshly-initialized engine's state
    ref = ref.state
    restored = es.state_from_dict(ref, d)
    for l in jax.tree.leaves(restored.wire_residual):
        assert not np.asarray(l).any()
    # present key round-trips exactly
    full = es.state_from_dict(ref, es.state_to_dict(eng.state))
    for a, b in zip(jax.tree.leaves(full.wire_residual),
                    jax.tree.leaves(eng.state.wire_residual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scheduler_state_v4_upgrades_wire_bytes_raw():
    """Pre-codec scheduler dicts (schema <= 4) carry no wire_bytes_raw: the
    upgrade path seeds it from bytes_sent (ratio resumes at 1.0)."""
    from repro.core.protocol import (SCHEDULER_SCHEMA_VERSION,
                                     upgrade_scheduler_state)
    eng, s = _engine("none")
    for t in range(12):
        s = eng.on_step_end(t, s)
    st = eng.scheduler_state()
    assert st["schema_version"] == SCHEDULER_SCHEMA_VERSION
    legacy = {k: v for k, v in st.items() if k != "wire_bytes_raw"}
    legacy["schema_version"] = 4
    up = upgrade_scheduler_state(legacy)
    assert up["wire_bytes_raw"] == st["bytes_sent"]
    assert up["schema_version"] == SCHEDULER_SCHEMA_VERSION
    eng.restore_scheduler(up)
    assert eng.stats()["compression_ratio"] == 1.0


# ---------------------------------------------------------------------------
# trainer: kill/resume with an active codec, spec plumbing
# ---------------------------------------------------------------------------


def _trainer(method="cocodc", steps=24, loop="segment", **ccfg_kw):
    mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                               compute_dtype="float32")
    ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                        overlap_depth=2, **ccfg_kw)
    tcfg = TrainerConfig(method=method, local_batch=2, seq_len=16,
                         total_steps=steps, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=0, loop=loop)
    return CrossRegionTrainer(mcfg, ccfg, tcfg)


def test_resume_mid_flight_with_active_codec(tmp_path):
    """Kill/resume with compressed fragments on the wire AND a live EF
    residual replays the reference run bitwise — the residual pytree and the
    wire_bytes_raw tally are part of the checkpoint."""
    ck = os.path.join(tmp_path, "ck.msgpack")
    ref = _trainer(wire_codec="int8")
    ref.run(eval_every=8, log=lambda s: None)

    tr = _trainer(wire_codec="int8", loop="per_step")
    while not tr.engine.pending:          # stop with a transfer on the wire
        tr.train_one_step()
    tr.save_checkpoint(ck)
    resumed = _trainer(wire_codec="int8").restore_checkpoint(ck)
    for a, b in zip(jax.tree.leaves(resumed.engine.state.wire_residual),
                    jax.tree.leaves(tr.engine.state.wire_residual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    resumed.run(eval_every=8, log=lambda s: None)
    ra = {r["step"]: r["nll"] for r in ref.history}
    rb = {r["step"]: r["nll"] for r in resumed.history}
    assert set(rb) and all(ra[s] == rb[s] for s in sorted(set(ra) & set(rb)))
    sr, ss = ref.engine.stats(), resumed.engine.stats()
    assert sr["wire_bytes_raw"] == ss["wire_bytes_raw"]
    assert sr["bytes_sent"] == ss["bytes_sent"]
    assert sr["compression_ratio"] == ss["compression_ratio"] > 3.5


def test_codec_mismatch_rejected_on_resume(tmp_path):
    ck = os.path.join(tmp_path, "ck.msgpack")
    tr = _trainer(wire_codec="int8", steps=8)
    tr.run(eval_every=8, log=lambda s: None)
    tr.save_checkpoint(ck)
    with pytest.raises(ValueError, match="wire_codec"):
        _trainer(wire_codec="none", steps=8).restore_checkpoint(ck)


def test_spec_validates_codec_fields():
    from repro.api.spec import ExperimentSpec, MethodExtensions, MethodSpec

    def spec(**ext):
        return ExperimentSpec(method=MethodSpec(
            extensions=MethodExtensions(**ext)))

    spec(wire_codec="int8", codec_block=512).validate()
    with pytest.raises(ValueError, match="wire_codec"):
        spec(wire_codec="zstd").validate()
    with pytest.raises(ValueError, match="codec_block"):
        spec(wire_codec="int4", codec_block=9).validate()
    with pytest.raises(ValueError, match="codec_block"):
        spec(codec_block=0).validate()
    # knobs reach the protocol config
    from repro.api.spec import NetworkSpec
    cc = spec(wire_codec="int4", codec_block=512,
              codec_error_feedback=False).method.to_cocodc(NetworkSpec())
    assert (cc.wire_codec, cc.codec_block, cc.codec_error_feedback) == \
        ("int4", 512, False)


def test_stale_spec_hash_recomputed_from_stored_spec(tmp_path):
    """A checkpoint whose stored hash predates newer spec fields still
    resumes: the identity check re-hashes the SAVED spec dict with current
    code (defaults filled) before rejecting."""
    from repro.api import build_experiment
    from repro.api.spec import ExperimentSpec, ModelRef, RunSpec

    spec = ExperimentSpec(model=ModelRef(arch="paper_150m", reduced=True),
                          run=RunSpec(steps=8, seed=0)).validate()
    tr = build_experiment(spec)
    tr.run(eval_every=8, log=lambda s: None)
    ck = os.path.join(tmp_path, "ck.msgpack")
    tr.save_checkpoint(ck)
    from repro.checkpoint import load_pytree, save_pytree
    st = load_pytree(ck)
    assert st["meta"]["spec_hash"] == spec.spec_hash
    st["meta"]["spec_hash"] = "0" * 16          # hash from an older field set
    save_pytree(ck, st)
    build_experiment(spec).restore_checkpoint(ck)   # accepted via re-hash
    # a genuinely different spec still fails
    other = dataclasses.replace(spec, run=RunSpec(steps=8, seed=1)).validate()
    with pytest.raises(ValueError, match="spec"):
        build_experiment(other).restore_checkpoint(ck)


# ---------------------------------------------------------------------------
# the bitwise pin: wire_codec="none" reproduces the PR 5 trajectories
# ---------------------------------------------------------------------------

# Captured from the pre-codec tree (commit 24a7470) with _trainer() above:
# eval history [step, train_loss, nll], scheduler tallies, and f64 sums of
# the consensus model / worker stacks. Any drift here means the codec="none"
# path is no longer the bitwise-identical program it claims to be.
PR5_GOLDENS = {
    "diloco": {
        "history": [[8, 7.018250465393066, 6.6325154304504395],
                    [16, 6.345962047576904, 6.632944583892822],
                    [24, 6.365350723266602, 6.648122549057007]],
        "bytes_sent": 17316864.0, "n_syncs": 3.0, "wall_clock_s": 35.4,
        "theta_g_sum": 1197.9878458976746, "params_sum": 2395.9756712913513,
    },
    "streaming": {
        "history": [[8, 6.976778030395508, 6.653458833694458],
                    [16, 6.449089050292969, 6.618683815002441],
                    [24, 6.346522331237793, 6.56982946395874]],
        "bytes_sent": 17316864.0, "n_syncs": 6.0, "wall_clock_s": 24.0,
        "theta_g_sum": 1194.069115638733, "params_sum": 2381.7145833969116,
    },
    "cocodc": {
        "history": [[8, 6.994054794311523, 6.6532673835754395],
                    [16, 6.42584228515625, 6.615197420120239],
                    [24, 6.247212886810303, 6.607685804367065]],
        "bytes_sent": 17316864.0, "n_syncs": 6.0, "wall_clock_s": 24.0,
        "theta_g_sum": 1177.3517136573792, "params_sum": 2361.255774974823,
    },
    "local": {
        "history": [[8, 7.018250465393066, 6.685399770736694],
                    [16, 6.438072204589844, 6.685399770736694],
                    [24, 6.43746280670166, 6.685399770736694]],
        "bytes_sent": 0.0, "n_syncs": 0.0, "wall_clock_s": 24.0,
        "theta_g_sum": 1182.6093229055405, "params_sum": 2370.9805886745453,
    },
}


@pytest.mark.parametrize("method", sorted(PR5_GOLDENS))
def test_codec_none_pins_pr5_trajectory(method):
    tr = _trainer(method)       # wire_codec defaults to "none"
    tr.run(eval_every=8, log=lambda s: None)
    g = PR5_GOLDENS[method]
    got = [[r["step"], float(r["train_loss"]), float(r["nll"])]
           for r in tr.history]
    assert got == g["history"]
    st = tr.engine.stats()
    assert st["bytes_sent"] == g["bytes_sent"]
    assert st["n_syncs"] == g["n_syncs"]
    assert st["wall_clock_s"] == g["wall_clock_s"]
    assert st["wire_bytes_raw"] == g["bytes_sent"]     # raw == wire, no codec
    theta_sum = float(sum(np.float64(np.asarray(l).sum())
                          for l in jax.tree.leaves(tr.engine.theta_g)))
    params_sum = float(sum(np.float64(np.asarray(l).sum())
                           for l in jax.tree.leaves(tr.params_stack)))
    assert theta_sum == g["theta_g_sum"]
    assert params_sum == g["params_sum"]
