"""Routed collective planner: multi-hop routes, hub failover, network-aware
adaptive transmission, and the determinism/serialization contracts.

The planner's core claim mirrors Algorithm 2's: `RoutePlanner.plan_at(t)` is a
pure function of wall-time against the shared dynamics clock, so every region
elects the same hub and computes identical routes with zero coordination —
and a mid-outage kill/resume re-derives the active plan from the serialized
plan time alone (bitwise trajectory, pinned below).
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core import adaptive as adaptive_lib
from repro.core.fragments import make_fragmenter
from repro.core.network import (LinkDynamics, LinkEvent, RoutePlanner,
                                Topology, apply_dynamics, generate_mesh,
                                make_scenario)
from repro.core.protocol import ProtocolEngine
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.models import api

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

KEY = jax.random.PRNGKey(0)
TINY = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                   n_heads=2, n_kv_heads=1, d_ff=128, vocab=128,
                   compute_dtype="float32")


def engine_for(method, network, M=4, H=8, K=2, tau=2, **ccfg_kw):
    ccfg = CoCoDCConfig(num_workers=M, local_steps=H, num_fragments=K,
                        overlap_depth=tau, **ccfg_kw)
    params = api.init_params(TINY, KEY)
    stack = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, K)
    return ProtocolEngine(method, ccfg, frag, network, stack,
                          engine_impl="host"), stack, frag


def scaled_hub_mesh(n=8, seed=0, bw_steps=4.0, frag_bytes=500_000):
    """Generated hub_spoke mesh scaled so one fragment collective spends
    ~bw_steps compute steps in bandwidth (dynamics actually bite)."""
    base = generate_mesh(n, "hub_spoke", seed=seed)
    bw_part = base.allreduce_time(frag_bytes) - base.allreduce_time(0)
    return dataclasses.replace(
        base, bandwidth_Bps=base.bandwidth_Bps * (bw_part / bw_steps))


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [
    generate_mesh(6, "ring", seed=1),
    generate_mesh(6, "hub_spoke", seed=1),
    make_scenario("asym4"),
], ids=["ring6", "hub6", "asym4"])
def test_healthy_plan_matches_static_formulas(topo):
    """On a healthy network the plan is single-hop direct routes and its cost
    model reproduces the fixed formulas EXACTLY (same arithmetic)."""
    plan = RoutePlanner(topo, ref_bytes=500_000).plan_at(0.0)
    assert not plan.is_multi_hop
    assert plan.participants == tuple(range(topo.num_workers))
    assert plan.hub == topo.hub
    assert set(plan.logical) == set(topo._links())
    for nbytes in (0, 1_000_000, 31_337_000):
        assert topo.plan_allreduce_time(plan, nbytes) == \
            topo.allreduce_time(nbytes)
        np.testing.assert_array_equal(topo.plan_link_bytes(plan, nbytes),
                                      topo.link_bytes(nbytes))
        np.testing.assert_array_equal(topo.plan_link_seconds(plan, nbytes),
                                      topo.link_seconds(nbytes))
    assert topo.plan_n_latency_phases(plan) == topo.n_latency_phases
    # static topology: the plan never expires
    assert plan.valid_until == float("inf")


def test_multi_hop_routes_around_degraded_link():
    """A dark direct link with a healthy 2-hop detour: the planner routes the
    logical link through the intermediate region and the plan's cost uses the
    detour's links."""
    m = 3
    lat = np.full((m, m), 0.01)
    np.fill_diagonal(lat, 0.0)
    bw = np.full((m, m), 1e6)
    np.fill_diagonal(bw, np.inf)
    topo = Topology(latency_s=lat, bandwidth_Bps=bw).with_dynamics(
        LinkDynamics(events=(
            LinkEvent(0.0, 100.0, 0, 1, bandwidth_factor=0.0),)))
    plan = RoutePlanner(topo, ref_bytes=1_000_000).plan_at(1.0)
    by_logical = dict(zip(plan.logical, plan.routes))
    assert by_logical[(0, 1)] == ((0, 2), (2, 1))    # detour around the dark
    assert by_logical[(1, 2)] == ((1, 2),)           # healthy links stay
    assert by_logical[(2, 0)] == ((2, 0),)           # direct
    assert plan.is_multi_hop
    # the detour's transfer never waits on the dark link
    finish, nominal, retries = topo.plan_transfer_time(plan, 1_000_000, 1.0)
    assert retries == 0
    assert finish == 1.0 + nominal
    # whereas the fixed-route path parks until recovery at t=100
    finish_static, _, _ = topo.transfer_time(1_000_000, 1.0)
    assert finish_static > 100.0


def test_degraded_but_usable_direct_link_can_reroute():
    """Routing weighs EFFECTIVE bandwidth: a 10x-degraded (not dark) direct
    link loses to a healthy detour when the payload is bandwidth-bound."""
    m = 3
    lat = np.full((m, m), 1e-4)
    np.fill_diagonal(lat, 0.0)
    bw = np.full((m, m), 1e6)
    np.fill_diagonal(bw, np.inf)
    topo = Topology(latency_s=lat, bandwidth_Bps=bw).with_dynamics(
        LinkDynamics(events=(
            LinkEvent(0.0, 100.0, 0, 1, bandwidth_factor=0.1,
                      symmetric=False),)))
    plan = RoutePlanner(topo, ref_bytes=1_000_000).plan_at(1.0)
    assert dict(zip(plan.logical, plan.routes))[(0, 1)] == ((0, 2), (2, 1))


def test_hub_failover_elects_and_restores():
    topo = apply_dynamics(generate_mesh(8, "hub_spoke", seed=0),
                          "hub_failure:start=24:dur=16", seed=0)
    pl = RoutePlanner(topo, hub_failover=True, ref_bytes=500_000)
    before, during, after = pl.plan_at(0.0), pl.plan_at(30.0), pl.plan_at(41.0)
    assert before.hub == topo.hub and before.participants == tuple(range(8))
    assert during.hub != topo.hub
    assert topo.hub not in during.participants
    assert len(during.participants) == 7
    # the stand-in hub is the best-connected surviving region, deterministic
    assert during.hub == pl.elect_hub(30.0)
    assert after.hub == topo.hub and after.participants == tuple(range(8))
    # validity windows track the outage edges
    assert before.valid_until == 24.0
    assert during.valid_until == 40.0
    # without failover the declared hub stays and the plan keeps its links
    pl_no = RoutePlanner(topo, hub_failover=False, ref_bytes=500_000)
    assert pl_no.plan_at(30.0).hub == topo.hub
    assert pl_no.plan_at(30.0).participants == tuple(range(8))


def test_total_blackout_falls_back_to_stall():
    """Every region dark -> the plan keeps everyone on direct routes (the
    transfer waits for recovery like the static path; completion may not be
    conjured out of a dead network)."""
    m = 2
    lat = np.zeros((m, m))
    bw = np.full((m, m), 1e6)
    np.fill_diagonal(bw, np.inf)
    topo = Topology(latency_s=lat, bandwidth_Bps=bw).with_dynamics(
        LinkDynamics(events=(
            LinkEvent(0.0, 50.0, 0, 1, bandwidth_factor=0.0),)))
    plan = RoutePlanner(topo, hub_failover=True, ref_bytes=1000).plan_at(1.0)
    assert plan.participants == (0, 1)
    finish, _, retries = topo.plan_transfer_time(plan, 1_000_000, 1.0)
    assert finish > 50.0 and retries == 1


# ---------------------------------------------------------------------------
# planner determinism (the zero-coordination claim)
# ---------------------------------------------------------------------------


def _region_planner(profile, n, seed, spec):
    """One region's independently constructed planner: same shared mesh seed
    and dynamics spec, fresh objects (nothing shared in memory)."""
    topo = generate_mesh(n, profile, seed=seed)
    topo = apply_dynamics(topo, spec, seed=seed)
    return RoutePlanner(topo, hub_failover=True, ref_bytes=250_000)


def _check_planner_determinism(profile, n, seed, times):
    """Every region, given the same shared history (mesh seed) and dynamics
    clock (query times), elects the same hub and computes identical routes —
    the zero-coordination claim extended to routing."""
    spec = "diurnal:period=48:depth=0.6,hub_failure:start=40:dur=24"
    a = _region_planner(profile, n, seed, spec)
    b = _region_planner(profile, n, seed, spec)
    for t in times:
        pa, pb = a.plan_at(t), b.plan_at(t)
        assert pa.hub == pb.hub
        assert pa.participants == pb.participants
        assert pa.routes == pb.routes
        assert pa.valid_until == pb.valid_until
        assert pa.route_key() == pb.route_key()


try:                                                   # optional dev dep
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(profile=st.sampled_from(["hub_spoke", "random_geo", "ring"]),
           n=st.integers(3, 8), seed=st.integers(0, 50),
           times=st.lists(st.floats(0.0, 200.0, allow_nan=False),
                          min_size=1, max_size=6))
    def test_planner_determinism_across_regions(profile, n, seed, times):
        _check_planner_determinism(profile, n, seed, times)
except ImportError:
    pass


@pytest.mark.parametrize("profile", ["hub_spoke", "random_geo", "ring"])
@pytest.mark.parametrize("seed", [0, 3])
def test_planner_determinism_fixed_cases(profile, seed):
    """Deterministic pinned cases of the property above (always run, even
    without hypothesis): query times straddle the trough, the outage, and
    recovery."""
    _check_planner_determinism(profile, 8, seed,
                               [0.0, 12.5, 41.0, 55.0, 64.0, 199.0])


# ---------------------------------------------------------------------------
# engine under the routed planner
# ---------------------------------------------------------------------------


def _hub_failure_net(n=8, start=12, dur=16):
    return apply_dynamics(scaled_hub_mesh(n), f"hub_failure:start={start}:"
                                              f"dur={dur}", seed=0)


def test_engine_routed_beats_static_on_hub_failure():
    """The acceptance behavior at engine scale: with failover the hub-outage
    window completes (deliveries during the window, strictly lower stall
    fraction) and the election sequence is failover -> restore."""
    net = _hub_failure_net()
    e_static, stack_s, _ = engine_for("cocodc", net, M=8, H=24, K=4)
    e_routed, stack_r, _ = engine_for("cocodc", net, M=8, H=24, K=4,
                                      routing="routed", hub_failover=True)
    for t in range(48):
        stack_s = e_static.on_step_end(t, stack_s)
        stack_r = e_routed.on_step_end(t, stack_r)
    ss, sr = e_static.stats(), e_routed.stats()
    assert sr["stall_fraction"] < ss["stall_fraction"]
    assert sr["reroutes"] >= 2           # outage reroute + recovery restore
    assert sr["hub_elections"] == 2      # stand-in elected, declared restored
    assert ss["reroutes"] == 0 and ss["hub_elections"] == 0
    # availability returns to full once the hub recovers
    assert all(e_routed.worker_available)
    # the routed run keeps syncing THROUGH the window instead of queueing
    # behind the stalled collective
    assert sr["n_syncs"] >= ss["n_syncs"]


def test_failover_preserves_user_disabled_workers():
    """The planner records each dark region's availability as it found it and
    restores it VERBATIM on recovery — it never re-enables a worker the user
    took offline (maintenance), whether or not that worker also went dark."""
    net = _hub_failure_net()
    eng, stack, _ = engine_for("cocodc", net, M=8, H=24, K=4,
                               routing="routed", hub_failover=True)
    eng.set_worker_availability(0, False)    # user had taken the hub offline
    eng.set_worker_availability(2, False)    # ... and a spoke
    for t in range(48):
        stack = eng.on_step_end(t, stack)
    # the outage came and went: planner bookkeeping restored, user's not
    assert not eng.worker_available[0]
    assert not eng.worker_available[2]
    assert all(eng.worker_available[r] for r in (1, 3, 4, 5, 6, 7))
    mask = np.asarray(eng.state.worker_available)
    assert list(mask) == [bool(x) for x in eng.worker_available]
    assert eng._plan_dark == {}              # nothing left marked dark


def test_routed_static_network_matches_fixed_routes():
    """On a static topology the routed engine reproduces the fixed-route
    delivery schedule exactly (healthy plans are direct routes)."""
    net = make_scenario("asym4")
    e_fixed, stack_f, _ = engine_for("streaming", net, M=4)
    e_routed, stack_r, _ = engine_for("streaming", net, M=4,
                                      routing="routed")
    for t in range(24):
        stack_f = e_fixed.on_step_end(t, stack_f)
        stack_r = e_routed.on_step_end(t, stack_r)
    assert [(-e.seq, e.frag, e.deliver_at, e.finish_time)
            for e in e_fixed.pending] == \
        [(-e.seq, e.frag, e.deliver_at, e.finish_time)
         for e in e_routed.pending]
    sf, sr = e_fixed.stats(), e_routed.stats()
    for k in ("wall_clock_s", "comm_seconds", "bytes_sent", "n_syncs"):
        assert sf[k] == sr[k], k
    np.testing.assert_array_equal(e_fixed.link_bytes, e_routed.link_bytes)
    np.testing.assert_array_equal(e_fixed.link_seconds, e_routed.link_seconds)


def test_routing_config_validation():
    net = make_scenario("asym4")
    with pytest.raises(ValueError, match="hub_failover"):
        engine_for("cocodc", net, M=4, hub_failover=True)
    with pytest.raises(ValueError, match="routing"):
        engine_for("cocodc", net, M=4, routing="quantum")


def test_link_pricing_costs_refresh_from_plan():
    """During the outage the Algorithm-2 cost vector prices fragments against
    the failover plan, not the startup topology."""
    net = _hub_failure_net()
    eng, stack, _ = engine_for("cocodc", net, M=8, H=24, K=4,
                               routing="routed", hub_failover=True,
                               link_pricing=True)
    startup = list(eng._frag_cost)
    for t in range(20):                      # into the outage window
        stack = eng.on_step_end(t, stack)
    assert eng._frag_cost != startup
    # cost vector equals the active plan's pricing exactly
    assert eng._frag_cost == eng._plan_frag_cost(eng._plan)


# ---------------------------------------------------------------------------
# Eq. 9/10 re-derivation from measured transfers
# ---------------------------------------------------------------------------


def test_resync_state_window_and_estimate():
    rs = adaptive_lib.ResyncState(window=3)
    assert rs.t_s_estimate is None
    for v in (2.0, 4.0, 6.0, 8.0):
        rs.observe(v)
    assert rs.measured == [4.0, 6.0, 8.0]            # bounded window
    assert rs.t_s_estimate == 6.0
    n, h = adaptive_lib.rederive_schedule(rs, K=4, H=100, t_c=1.0, gamma=0.4,
                                          fallback_t_s=5.0)
    assert n == adaptive_lib.target_syncs(4, 100, 1.0, 6.0, 0.4)
    assert h == adaptive_lib.sync_interval(100, n)
    # empty window falls back to the startup estimate (paper numbers)
    n0, h0 = adaptive_lib.rederive_schedule(
        adaptive_lib.ResyncState(), K=4, H=100, t_c=1.0, gamma=0.4,
        fallback_t_s=5.0)
    assert (n0, h0) == (8, 12)


def test_engine_rederives_N_when_network_slows():
    """A persistent degradation doubles the measured T_s; after one outer
    round Eq. 9's N (and the initiation interval h) adapt to it."""
    base = Topology.uniform(4, latency_s=0.01, bandwidth_Bps=1.0)
    _, _, frag = engine_for("cocodc", base, M=4)
    # calibrate so one fragment costs ~2 steps at full rate -> N = 4 = K, and
    # gamma*H*t_c/t_s is large enough that halving the bandwidth changes N
    ccfg_bw = base.allreduce_time(frag.fragment_bytes(0)) / 2.0
    net = dataclasses.replace(base, bandwidth_Bps=base.bandwidth_Bps * ccfg_bw)
    slow = apply_dynamics(net, "degrade:start=0:dur=1000000:factor=0.25:"
                               "link=0-1", seed=0)
    eng, stack, _ = engine_for("cocodc", slow, M=4, H=16, K=2,
                               adaptive_resync=True)
    n_start, h_start = eng.N, eng.h_cocodc
    for t in range(32):                               # two outer rounds
        stack = eng.on_step_end(t, stack)
    assert eng._resync is not None and eng._resync.measured
    # the measured T_s exceeds the startup estimate -> fewer target syncs
    assert eng._resync.t_s_estimate > eng._t_s_startup
    assert eng.N <= n_start and eng.h_cocodc >= h_start
    assert (eng.N, eng.h_cocodc) != (n_start, h_start)
    # without the flag nothing moves
    eng2, stack2, _ = engine_for("cocodc", slow, M=4, H=16, K=2)
    for t in range(32):
        stack2 = eng2.on_step_end(t, stack2)
    assert (eng2.N, eng2.h_cocodc) == (eng2.N, eng2.h_cocodc)
    assert eng2._resync is None


# ---------------------------------------------------------------------------
# serialization: scheduler round-trip + mid-outage kill/resume
# ---------------------------------------------------------------------------


def test_scheduler_state_roundtrips_planner_and_resync():
    net = _hub_failure_net()
    eng, stack, _ = engine_for("cocodc", net, M=8, H=24, K=4,
                               routing="routed", hub_failover=True,
                               adaptive_resync=True)
    for t in range(20):                     # into the outage: plan is live
        stack = eng.on_step_end(t, stack)
    assert eng._plan is not None
    st = eng.scheduler_state()
    eng2, _, _ = engine_for("cocodc", net, M=8, H=24, K=4,
                            routing="routed", hub_failover=True,
                            adaptive_resync=True)
    eng2.restore_scheduler(st)
    assert eng2.reroutes == eng.reroutes
    assert eng2.hub_elections == eng.hub_elections
    assert eng2._plan_time == eng._plan_time
    assert eng2._plan.route_key() == eng._plan.route_key()
    assert eng2._plan_dark == eng._plan_dark
    assert eng2._frag_cost == eng._frag_cost
    assert eng2._resync.measured == eng._resync.measured
    assert (eng2.N, eng2.h_cocodc) == (eng.N, eng.h_cocodc)
    assert [e.duration for e in eng2.pending] == \
        [e.duration for e in eng.pending]
    # legacy checkpoints (pre-routing: 5-element pending rows, no new keys)
    legacy = {k: v for k, v in st.items() if k not in ("routing", "resync")}
    legacy["pending"] = [r[:5] for r in st["pending"]]
    eng3, _, _ = engine_for("cocodc", net, M=8, H=24, K=4)
    eng3.restore_scheduler(legacy)
    assert eng3.reroutes == 0 and eng3._plan is None
    assert [e.seq for e in eng3.pending] == [e.seq for e in eng.pending]


def _routed_trainer(seed=0):
    mcfg = dataclasses.replace(TINY, name="routed-ck")
    ccfg = CoCoDCConfig(num_workers=4, local_steps=8, num_fragments=2,
                        overlap_depth=2, routing="routed", hub_failover=True,
                        adaptive_resync=True)
    tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                         total_steps=24, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=seed)
    net = apply_dynamics(scaled_hub_mesh(4, bw_steps=3.0),
                         "hub_failure:start=6:dur=8", seed=7)
    return CrossRegionTrainer(mcfg, ccfg, tcfg, network=net)


def test_mid_outage_kill_and_resume_bitwise(tmp_path):
    """Kill the run INSIDE the hub-outage window (failover hub active,
    fragment in flight), resume, and require the bitwise trajectory, stats,
    and hub-election history of the uninterrupted run — the planner state
    must re-derive from the serialized plan time."""
    ck = os.path.join(tmp_path, "routed.msgpack")

    ref = _routed_trainer()
    ref.run(eval_every=8, log=lambda s: None)
    assert ref.engine.hub_elections >= 2      # failover AND restore happened

    tr = _routed_trainer()
    tr.run(steps=8, eval_every=8, log=lambda s: None)   # inside [6, 14)
    while not tr.engine.pending and tr.step < 13:
        tr.run(steps=tr.step + 1, eval_every=8, log=lambda s: None)
    assert tr.engine.pending, "no mid-outage in-flight state to checkpoint"
    assert tr.engine.hub_elections >= 1       # the stand-in hub is active
    tr.save_checkpoint(ck)

    resumed = _routed_trainer().restore_checkpoint(ck)
    assert resumed.engine.hub_elections == tr.engine.hub_elections
    assert resumed.engine._plan.route_key() == tr.engine._plan.route_key()
    resumed.run(eval_every=8, log=lambda s: None)

    ra = {r["step"]: r for r in ref.history}
    rb = {r["step"]: r for r in resumed.history}
    shared = sorted(set(ra) & set(rb))
    assert shared
    for s in shared:
        assert ra[s]["nll"] == rb[s]["nll"]
        assert ra[s]["wall_clock_s"] == rb[s]["wall_clock_s"]
        assert ra[s]["stall_seconds"] == rb[s]["stall_seconds"]
        assert ra[s]["reroutes"] == rb[s]["reroutes"]
        assert ra[s]["hub_elections"] == rb[s]["hub_elections"]
    sa, sb = ref.engine.stats(), resumed.engine.stats()
    for k in sa:
        assert sa[k] == sb[k], f"stats[{k}]: {sa[k]} vs {sb[k]}"
    np.testing.assert_array_equal(ref.engine.link_bytes,
                                  resumed.engine.link_bytes)
    np.testing.assert_array_equal(ref.engine.link_seconds,
                                  resumed.engine.link_seconds)
    for x, y in zip(jax.tree.leaves(ref.params_stack),
                    jax.tree.leaves(resumed.params_stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_segment_loop_matches_per_step_with_resync():
    """Eq. 9 re-derivation happens at outer-round boundaries, which the
    segment loop only visits if they are protocol events — pinned here with
    eval boundaries deliberately MISALIGNED with H so a fused-away round
    boundary would diverge from the per-step loop."""
    def build(loop):
        mcfg = dataclasses.replace(TINY, name="resync-loop")
        ccfg = CoCoDCConfig(num_workers=4, local_steps=6, num_fragments=2,
                            overlap_depth=2, routing="routed",
                            hub_failover=True, adaptive_resync=True)
        tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                             total_steps=20, warmup_steps=4, inner_lr=3e-3,
                             eval_batch=4, seed=0, loop=loop)
        net = apply_dynamics(scaled_hub_mesh(4, bw_steps=3.0),
                             "hub_failure:start=5:dur=7", seed=7)
        tr = CrossRegionTrainer(mcfg, ccfg, tcfg, network=net)
        tr.run(eval_every=7, log=lambda s: None)
        return tr

    seg, per = build("segment"), build("per_step")
    assert seg.engine._resync.measured      # the re-derivation input exists
    assert [(r["step"], r["nll"]) for r in seg.history] == \
        [(r["step"], r["nll"]) for r in per.history]
    ss, sp = seg.engine.stats(), per.engine.stats()
    for k in ss:
        assert ss[k] == sp[k], f"stats[{k}]: {ss[k]} vs {sp[k]}"
    assert (seg.engine.N, seg.engine.h_cocodc) == \
        (per.engine.N, per.engine.h_cocodc)
    for x, y in zip(jax.tree.leaves(seg.params_stack),
                    jax.tree.leaves(per.params_stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_validates_routing_meta(tmp_path):
    """A routed checkpoint refuses to resume into a static-route trainer (the
    plan schedule derives from the routing config)."""
    ck = os.path.join(tmp_path, "meta.msgpack")
    tr = _routed_trainer()
    tr.run(steps=4, eval_every=8, log=lambda s: None)
    tr.save_checkpoint(ck)
    mcfg = dataclasses.replace(TINY, name="routed-ck")
    ccfg = CoCoDCConfig(num_workers=4, local_steps=8, num_fragments=2,
                        overlap_depth=2)                   # routing: static
    tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                         total_steps=24, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=0)
    other = CrossRegionTrainer(
        mcfg, ccfg, tcfg,
        network=apply_dynamics(scaled_hub_mesh(4, bw_steps=3.0),
                               "hub_failure:start=6:dur=8", seed=7))
    with pytest.raises(ValueError, match="routing"):
        other.restore_checkpoint(ck)
