"""Substrate tests: optimizer, schedule, data pipeline, checkpoint io."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, never collection-error
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_pytree, save_pytree
from repro.data.pipeline import MarkovCorpus, make_worker_streams, stacked_batch
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.adamw import global_norm

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------- optimizer


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, opt = adamw_update(grads, opt, params, 0.1, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.array([1.0])}
    opt = adamw_init(params)
    zero_grads = {"w": jnp.zeros(1)}
    p1, _ = adamw_update(zero_grads, opt, params, 0.1, weight_decay=0.5)
    assert float(p1["w"][0]) < 1.0


def test_adamw_clip():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p1, o1 = adamw_update(huge, opt, params, 1e-3, clip_norm=1.0)
    assert bool(jnp.all(jnp.isfinite(p1["w"])))
    assert float(global_norm(o1.mu)) <= 0.11  # clipped grad norm 1 * (1-b1)


def test_adamw_bf16_moments():
    params = {"w": jnp.ones(8)}
    opt = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert opt.mu["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones(8) * 0.1}
    p1, o1 = adamw_update(grads, opt, params, 1e-2)
    assert o1.mu["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p1["w"])))


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, base_lr=1.0, warmup_steps=100, total_steps=1000))
    lr_mid = float(warmup_cosine(100, base_lr=1.0, warmup_steps=100,
                                 total_steps=1000))
    lr_end = float(warmup_cosine(1000, base_lr=1.0, warmup_steps=100,
                                 total_steps=1000))
    assert lr0 == 0.0
    assert lr_mid == pytest.approx(1.0, rel=1e-3)
    assert lr_end == pytest.approx(0.1, rel=1e-3)  # final_frac
    # monotone warmup
    for s in range(0, 100, 10):
        assert float(warmup_cosine(s, base_lr=1.0, warmup_steps=100,
                                   total_steps=1000)) <= lr_mid + 1e-6


# ----------------------------------------------------------------- data


def test_data_deterministic():
    c = MarkovCorpus(vocab=128, seed=3, worker_id=1)
    b1 = c.batch(42, 4, 16)
    b2 = c.batch(42, 4, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))


def test_data_labels_shifted():
    c = MarkovCorpus(vocab=128, seed=3, worker_id=0)
    b = c.batch(0, 2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_data_noniid_across_workers():
    streams = make_worker_streams(3, vocab=256, seed=0, noniid_frac=0.5)
    t0 = np.asarray(streams[0].succ)
    t1 = np.asarray(streams[1].succ)
    assert (t0 != t1).any()          # different transition structure
    # both workers rewire independently: shared backbone ~= (1-frac)^2 = 25%
    assert (t0 == t1).mean() > 0.2


def test_data_learnable_structure():
    """Markov data is compressible: successor entropy << uniform."""
    c = MarkovCorpus(vocab=256, seed=0, worker_id=0)
    b = c.batch(0, 8, 64)
    toks = np.asarray(b["tokens"])
    # every next-token is one of the `branch` successors of the current token
    succ = np.asarray(c.succ)
    ok = 0
    tot = 0
    for row in toks:
        for a, b2 in zip(row[:-1], row[1:]):
            tot += 1
            ok += int(b2 in succ[a])
    assert ok / tot > 0.95


def test_stacked_batch_shapes():
    streams = make_worker_streams(3, vocab=64)
    sb = stacked_batch(streams, 0, 4, 8)
    assert sb["tokens"].shape == (3, 4, 8)


# ----------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": np.random.randn(4, 3).astype(np.float32),
                   "b": jnp.asarray(np.random.randn(7), jnp.bfloat16)},
        "step": 123,
        "nested": [np.arange(5, dtype=np.int64), {"x": 1.5}],
    }
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_pytree(path, tree)
    out = load_pytree(path)
    np.testing.assert_allclose(out["params"]["w"], tree["params"]["w"])
    np.testing.assert_allclose(np.asarray(out["params"]["b"], np.float32),
                               np.asarray(tree["params"]["b"], np.float32))
    assert out["step"] == 123
    np.testing.assert_array_equal(out["nested"][0], tree["nested"][0])
    assert out["nested"][1]["x"] == 1.5


@settings(max_examples=10, deadline=None)
@given(shape=st.tuples(st.integers(1, 5), st.integers(1, 5)),
       seed=st.integers(0, 100))
def test_checkpoint_roundtrip_property(shape, seed):
    import tempfile
    arr = np.random.RandomState(seed).randn(*shape).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, f"c{seed}.msgpack")
        save_pytree(path, {"a": arr})
        np.testing.assert_array_equal(load_pytree(path)["a"], arr)
