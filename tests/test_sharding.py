"""Sharding-rule unit tests: candidate specs respect divisibility, never shard the
layer axis of stacked leaves, and cover every leaf of every assigned arch."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding as shd
from repro.launch.steps import abstract_params

AXES = {"pod": 2, "data": 16, "model": 16}


def all_specs(arch):
    cfg = get_config(arch)
    sds = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    out = []
    for path, leaf in flat:
        p = "/".join(str(getattr(x, "key", x)) for x in path)
        out.append((p, leaf.shape, shd.spec_for_leaf(p, leaf.shape, AXES)))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_divisible(arch):
    for path, shape, spec in all_specs(arch):
        for dim, names in zip(shape, spec):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            total = 1
            for n in names:
                total *= AXES[n]
            assert dim % total == 0, (path, shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_layer_axis_never_sharded(arch):
    """Fragment extraction slices dim 0 of stacked leaves — it must stay
    replicated (the multi-pod sync-step regression)."""
    for path, shape, spec in all_specs(arch):
        root = path.split("/")[0]
        if root in ("layers", "encoder", "decoder", "rem", "groups"):
            if len(spec) > 0:
                assert spec[0] is None, (path, shape, spec)


def test_big_matmuls_are_2d_sharded():
    """The FLOP-carrying weights must actually shard (not silently replicate)."""
    for path, shape, spec in all_specs("llama3_405b"):
        if path.endswith(("attn/wq", "mlp/w_gate", "mlp/w_down")):
            sharded_axes = [n for names in spec if names is not None
                            for n in (names if isinstance(names, tuple)
                                      else (names,))]
            assert "model" in sharded_axes and "data" in sharded_axes, (path, spec)


def test_moe_experts_sharded_expert_parallel():
    for path, shape, spec in all_specs("dbrx_132b"):
        if path.endswith("moe/w_gate"):
            # (L, E=16, D, F): experts over `model`
            assert spec[1] == "model", (path, shape, spec)


def test_granite_odd_experts_fall_back():
    """40 experts % 16 != 0: the expert axis falls back, d_ff carries `model`."""
    for path, shape, spec in all_specs("granite_moe_3b_a800m"):
        if path.endswith("moe/w_gate"):
            assert spec[1] is None, (path, shape, spec)
            assert "model" in [a for names in spec if names
                               for a in (names if isinstance(names, tuple)
                                         else (names,))]


def test_embed_not_vocab_sharded():
    """Vocab-sharded embedding gathers trigger GSPMD involuntary full remat
    (cross-pod reshard); the table shards d_model only."""
    for arch in ("command_r_35b", "qwen3_0_6b"):
        for path, shape, spec in all_specs(arch):
            if path == "embed":
                assert spec[0] is None, (arch, spec)


def test_stack_spec_prepends_pod():
    tree = {"a": P("data", "model"), "b": P()}
    out = shd.stack_spec(tree)
    assert out["a"] == P("pod", "data", "model")
    assert out["b"] == P("pod")
