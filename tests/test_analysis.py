"""Static-analysis tests: every checker class is proven SHARP on a seeded
violation (wrong dispatch count, impure ref.py, missing impl="auto",
unaligned BlockSpec, banned primitive, broken donation, f64 widening,
retrace churn), and the repo head is pinned clean against the full budget
registry (4 methods x fused on/off, serve, segment scan, donation)."""
import re
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import budgets
from repro.analysis.jaxpr_audit import (AuditError, audit_donation,
                                        audit_engine, audit_segment,
                                        audit_serve, check_banned_primitives,
                                        check_donation, check_no_f64,
                                        check_pallas_budget,
                                        count_donation_annotations,
                                        count_lowered_args,
                                        count_pallas_calls)
from repro.analysis.kernel_lint import (_lint_blockspecs, _lint_ops_contract,
                                        _lint_ref_purity, lint_kernel_family,
                                        lint_purity, run_kernel_lint)
from repro.analysis.retrace import RetraceError, RetraceSentinel


# ---------------------------------------------------------------------------
# jaxpr checks: seeded violations
# ---------------------------------------------------------------------------


def test_pallas_budget_trips_on_wrong_count():
    jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones((4,))).jaxpr
    assert count_pallas_calls(jaxpr) == 0
    with pytest.raises(AuditError, match="budget declares exactly 1"):
        check_pallas_budget(jaxpr, 1, "fixture")


def test_pallas_budget_counts_real_kernel():
    from repro.kernels.rms_norm.ops import rms_norm
    x = jnp.ones((2, 8, 64))
    w = jnp.ones((64,))
    jaxpr = jax.make_jaxpr(
        lambda x, w: rms_norm(x, w, impl="pallas"))(x, w).jaxpr
    check_pallas_budget(jaxpr, 1, "rms_norm pallas")         # passes
    with pytest.raises(AuditError):
        check_pallas_budget(jaxpr, 0, "rms_norm pallas")
    # and the ref dial stays kernel-free
    jaxpr_ref = jax.make_jaxpr(
        lambda x, w: rms_norm(x, w, impl="ref"))(x, w).jaxpr
    check_pallas_budget(jaxpr_ref, 0, "rms_norm ref")


def test_banned_primitive_trips_on_debug_print():
    def f(x):
        jax.debug.print("x={x}", x=x)
        return x + 1.0
    jaxpr = jax.make_jaxpr(f)(jnp.ones((3,))).jaxpr
    with pytest.raises(AuditError, match="debug_callback"):
        check_banned_primitives(jaxpr, "fixture")
    # a clean program passes
    check_banned_primitives(
        jax.make_jaxpr(lambda x: x + 1.0)(jnp.ones((3,))).jaxpr, "fixture")


def test_f64_check_trips_under_x64():
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(jnp.ones((3,))).jaxpr
        with pytest.raises(AuditError, match="float64"):
            check_no_f64(jaxpr, "fixture")
    check_no_f64(jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones((3,))).jaxpr,
                 "fixture")


def test_donation_check_trips_without_donate_argnums():
    text = jax.jit(lambda x: x + 1.0).lower(jnp.ones((4,))).as_text()
    assert count_donation_annotations(text) == 0
    with pytest.raises(AuditError, match="donated-buffer annotations"):
        check_donation(text, 1, "fixture", total_input_leaves=1)


def test_donation_check_passes_when_wired():
    text = jax.jit(lambda x: x + 1.0,
                   donate_argnums=(0,)).lower(jnp.ones((4,))).as_text()
    assert count_donation_annotations(text) == 1
    check_donation(text, 1, "fixture", total_input_leaves=1)


def test_count_lowered_args_reads_main_only():
    # %arg numbering restarts inside private helper functions — the public
    # entry signature is the only one that bounds jit's dropped-arg count
    text = textwrap.dedent("""\
        module @jit_f {
          func.func public @main(%arg0: tensor<4xf32>, %arg1: tensor<4xf32>)
              -> (tensor<4xf32>) {
            %0 = call @helper(%arg0, %arg1, %arg1) : ...
            return %0 : tensor<4xf32>
          }
          func.func private @helper(%arg0: tensor<4xf32>,
              %arg1: tensor<4xf32>, %arg2: tensor<4xf32>) -> tensor<4xf32> {
          }
        }
    """)
    assert count_lowered_args(text) == 2


# ---------------------------------------------------------------------------
# kernel-contract linter: seeded violations
# ---------------------------------------------------------------------------


def _write(tmp_path, name, src):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return p


def test_ref_purity_trips_on_pallas_import(tmp_path):
    p = _write(tmp_path, "ref.py", """\
        from jax.experimental import pallas as pl
        import jax.numpy as jnp

        def oracle(x):
            return pl.load(x, ())
    """)
    out = _lint_ref_purity(p)
    assert any("pure jnp" in v for v in out)


def test_ops_contract_trips_without_impl_dial(tmp_path):
    p = _write(tmp_path, "ops.py", """\
        import jax.numpy as jnp

        def my_op(x, *, block=128):
            return x * 2
    """)
    out = _lint_ops_contract(p)
    assert any('impl="auto"' in v for v in out)
    assert any("is_cpu" in v for v in out)
    assert any("ref oracle" in v for v in out)


def test_ops_contract_passes_on_conforming_module(tmp_path):
    p = _write(tmp_path, "ops.py", """\
        from repro.kernels import is_cpu
        from repro.kernels.fake.ref import my_op_ref

        def my_op(x, *, impl: str = "auto"):
            if impl == "ref":
                return my_op_ref(x)
            interpret = is_cpu()
            return x
    """)
    assert _lint_ops_contract(p) == []


def test_blockspec_lint_trips_on_unaligned_last_dim(tmp_path):
    p = _write(tmp_path, "kern.py", """\
        from jax.experimental import pallas as pl

        def build(x):
            return pl.BlockSpec((8, 100), lambda i: (i, 0))
    """)
    out = _lint_blockspecs(p, budgets.KernelContract())
    assert any("not lane-aligned" in v for v in out)


def test_blockspec_lint_trips_on_undeclared_dim(tmp_path):
    p = _write(tmp_path, "kern.py", """\
        from jax.experimental import pallas as pl

        def build(x, bq):
            return pl.BlockSpec((bq, 128), lambda i: (i, 0))
    """)
    out = _lint_blockspecs(p, budgets.KernelContract())
    assert any("not statically resolvable" in v for v in out)
    # declaring the bound resolves it
    ok = _lint_blockspecs(p, budgets.KernelContract(dim_bounds={"bq": 128}))
    assert ok == []


def test_blockspec_lint_trips_on_vmem_blowout(tmp_path):
    p = _write(tmp_path, "kern.py", """\
        from jax.experimental import pallas as pl

        def build(x):
            return pl.BlockSpec((4096, 1024), lambda i: (i, 0))
    """)
    out = _lint_blockspecs(p, budgets.KernelContract())   # 16 MiB > 8 MiB
    assert any("VMEM footprint" in v for v in out)


def test_family_lint_end_to_end(tmp_path):
    fam = tmp_path / "famx"
    fam.mkdir()
    (fam / "__init__.py").write_text("")
    _write(fam, "ref.py", """\
        import jax.experimental.pallas as pl

        def famx_ref(x):
            return x
    """)
    # no ops.py at all
    out = lint_kernel_family(fam, budgets.KernelContract())
    assert any("pure jnp" in v for v in out)
    assert any("missing ops.py" in v for v in out)


# ---------------------------------------------------------------------------
# repo head pinned clean against the full registry
# ---------------------------------------------------------------------------


def test_kernel_lint_clean_on_repo_head():
    assert run_kernel_lint() == []


def test_purity_lint_clean_on_repo_head():
    assert lint_purity() == []


def test_engine_dispatch_budgets_hold():
    """The full table: 4 methods x fused on/off x impl modes, each traced
    transition at its exact pallas_call budget, callback- and f64-free."""
    assert audit_engine() == []


def test_engine_audit_flags_unbudgeted_method():
    only = {("local", False, "ref"): {"diloco_round": 0}}
    errors = audit_engine(budgets=only)
    assert any("declares no dispatch budget" in e and "cocodc" in e
               for e in errors)


def test_register_dispatch_budget_validates_and_registers():
    with pytest.raises(ValueError, match="unknown transition"):
        budgets.register_dispatch_budget(
            "tmpm", fused=False, impl="ref", budget={"teleport": 0})
    key = ("tmpm", False, "ref")
    try:
        budgets.register_dispatch_budget(
            "tmpm", fused=False, impl="ref", budget={"deliver": 0})
        assert budgets.ENGINE_DISPATCH_BUDGETS[key] == {"deliver": 0}
        assert "tmpm" in budgets.budgeted_methods()
    finally:
        budgets.ENGINE_DISPATCH_BUDGETS.pop(key, None)


def test_segment_scan_audit_clean():
    assert audit_segment() == []


def test_serve_audit_clean():
    assert audit_serve() == []


def test_donation_audit_clean():
    assert audit_donation() == []


# ---------------------------------------------------------------------------
# retrace sentinel
# ---------------------------------------------------------------------------


def test_retrace_sentinel_trips_on_shape_churn():
    f = RetraceSentinel(jax.jit(lambda x: x * 2.0), name="fixture")
    f(jnp.ones((2,)))
    f(jnp.ones((2,)))                      # same shape: no new trace
    assert f.trace_count == 1
    with pytest.raises(RetraceError, match="fixture"):
        f(jnp.ones((3,)))                  # second trace > budget of 1


def test_retrace_sentinel_rejects_plain_functions():
    with pytest.raises(TypeError, match="_cache_size"):
        RetraceSentinel(lambda x: x, name="fixture")


def test_segment_runner_trace_budget_is_log2():
    from repro.core.trainer import SegmentRunner
    runner = SegmentRunner(lambda p, o, b, lr: (p, o, 0.0), max_segment=64)
    assert runner._fn.max_traces == 7      # 64.bit_length(): chunks 64..1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_section_runs_clean(capsys):
    from repro.analysis.__main__ import main
    assert main(["--section", "kernel-contracts", "--section",
                 "purity"]) == 0
    out = capsys.readouterr().out
    assert "PASS" in out and "FAIL" not in out
