"""Protocol-engine invariants (DESIGN.md §7) + delay-compensation equations."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core import delay_comp as dc_lib
from repro.core.fragments import make_fragmenter
from repro.core.network import NetworkModel, paper_network
from repro.core.outer_opt import nesterov_update, init_state
from repro.core.protocol import ProtocolEngine
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.models import api

KEY = jax.random.PRNGKey(0)

TINY = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=64, n_heads=2,
                   n_kv_heads=1, d_ff=128, vocab=128, compute_dtype="float32")


def make_stack(M=2, cfg=TINY):
    params = api.init_params(cfg, KEY)
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(),
                        params)


def engine_for(method, M=2, H=10, K=2, tau=2, **ccfg_kw):
    ccfg = CoCoDCConfig(num_workers=M, local_steps=H, num_fragments=K,
                        overlap_depth=tau, **ccfg_kw)
    stack = make_stack(M)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, K)
    net = paper_network(M, fragment_bytes=frag.total_bytes // K, tau=tau)
    return ProtocolEngine(method, ccfg, frag, net, stack), stack


def perturb(stack, scale=0.01):
    leaves, treedef = jax.tree.flatten(stack)
    out = []
    for i, l in enumerate(leaves):
        noise = jax.random.normal(jax.random.fold_in(KEY, 100 + i), l.shape) * scale
        out.append(l + noise.astype(l.dtype))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# Eq-level tests
# ---------------------------------------------------------------------------


def test_eq4_to_eq8_chain():
    """Direct check of Algorithm 1 arithmetic on a vector fragment."""
    tau, lam, H = 4.0, 0.5, 20.0
    tl = jnp.array([1.0, 2.0, 3.0])
    tp = jnp.array([0.5, 1.5, 2.0])
    tg = jnp.array([0.6, 1.4, 2.2])
    g = (tl - tp) / tau
    expected = tg + (g + lam * g * g * (tg - tp) / H) * tau
    out = dc_lib.compensate({"w": tl}, {"w": tp}, {"w": tg}, tau=tau, lam=lam, H=H)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(expected), rtol=1e-6)


def test_eq4_literal_sign_config():
    """eq4_sign=-1 reproduces the literal printed Eq. (4) (DESIGN.md §5)."""
    tau = 2.0
    tl, tp, tg = (jnp.array([x]) for x in (3.0, 1.0, 1.0))
    out = dc_lib.compensate({"w": tl}, {"w": tp}, {"w": tg}, tau=tau, lam=0.0,
                            H=10.0, sign=-1.0)
    # g = (tp - tl)/tau = -1; out = tg + g*tau = 1 - 2 = -1
    np.testing.assert_allclose(np.asarray(out["w"]), [-1.0], rtol=1e-6)


def test_compensate_tau_noop_when_converged():
    """If the worker didn't move during overlap (tl == tp), out == theta_g exactly
    (invariant 2)."""
    t = jnp.array([1.0, -2.0, 3.0])
    tg = jnp.array([0.9, -1.8, 3.3])
    out = dc_lib.compensate({"w": t}, {"w": t}, {"w": tg}, tau=5.0, lam=0.5, H=10.0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tg), rtol=1e-6)


def test_blend_eq3():
    local = jnp.array([2.0])
    glob = jnp.array([4.0])
    out = dc_lib.blend({"w": local}, {"w": glob}, alpha=0.25)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5])


def test_nesterov_outer_step():
    theta = {"w": jnp.zeros(3)}
    mom = init_state(theta)
    delta = {"w": jnp.ones(3)}
    theta1, mom1 = nesterov_update(theta, mom, delta, lr=0.7, mu=0.9)
    # m = 1; step = lr*(delta + mu*m) = 0.7*1.9
    np.testing.assert_allclose(np.asarray(theta1["w"]), 0.7 * 1.9 * np.ones(3),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# engine-level invariants
# ---------------------------------------------------------------------------


def test_diloco_workers_reset_to_global():
    eng, stack = engine_for("diloco", H=5)
    stack = perturb(stack)
    for t in range(5):
        stack = eng.on_step_end(t, stack)
    # after the H-boundary sync, every worker equals theta_g (invariant: DiLoCo
    # restarts from the updated global model)
    for leaf_s, leaf_g in zip(jax.tree.leaves(stack), jax.tree.leaves(eng.theta_g)):
        for m in range(2):
            np.testing.assert_allclose(np.asarray(leaf_s[m]), np.asarray(leaf_g),
                                       rtol=1e-6)
    assert eng.n_syncs == 1


def test_diloco_blocking_wallclock_exceeds_streaming():
    steps = 20
    e_d, s_d = engine_for("diloco", H=10)
    e_s, s_s = engine_for("streaming", H=10)
    s_d, s_s = perturb(s_d), perturb(s_s)
    for t in range(steps):
        s_d = e_d.on_step_end(t, s_d)
        s_s = e_s.on_step_end(t, s_s)
    assert e_d.wall_clock > e_s.wall_clock  # overlap hides comm


def test_theta_g_constant_between_syncs():
    eng, stack = engine_for("cocodc", H=10, K=2, tau=2)
    stack = perturb(stack)
    g0 = jax.tree.leaves(eng.theta_g)[0].copy()
    stack = eng.on_step_end(0, stack)       # initiation only (delivery at t=2)
    g1 = jax.tree.leaves(eng.theta_g)[0]
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    stack = eng.on_step_end(1, stack)
    stack = eng.on_step_end(2, stack)       # delivery -> outer update
    assert eng.n_syncs >= 1


def test_cocodc_delivery_applies_compensation():
    eng, stack = engine_for("cocodc", H=10, K=2, tau=2)
    stack = perturb(stack)
    before = jax.tree.leaves(stack)[0].copy()
    for t in range(4):
        stack = eng.on_step_end(t, stack)
    after = jax.tree.leaves(stack)[0]
    assert float(jnp.max(jnp.abs(before - after))) > 0  # fragment got rewritten


def test_streaming_blend_moves_toward_global():
    eng, stack = engine_for("streaming", H=10, K=2, tau=2, mixing_alpha=1.0)
    stack = perturb(stack, scale=0.1)
    for t in range(4):
        stack = eng.on_step_end(t, stack)
    # alpha=1: the delivered fragment equals theta_g on every worker
    p = eng.in_flight[0].frag if eng.in_flight else 0
    # fragment 0 was initiated at t=0, delivered at t=2
    f_stack = eng.frag.extract(stack, 0, worker_axis=True)
    f_g = eng.frag.extract(eng.theta_g, 0)
    for ls, lg in zip(jax.tree.leaves(f_stack), jax.tree.leaves(f_g)):
        np.testing.assert_allclose(np.asarray(ls[0]), np.asarray(lg), rtol=1e-5)


def test_m1_single_worker_consistency():
    """M=1: the all-reduce is an identity; engine still runs (invariant 5)."""
    eng, stack = engine_for("cocodc", M=1, H=6, K=2, tau=1)
    stack = perturb(stack)
    for t in range(8):
        stack = eng.on_step_end(t, stack)
    assert eng.n_syncs > 0
    for leaf in jax.tree.leaves(stack):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_overlap_ratio_bounded():
    eng, stack = engine_for("cocodc", H=8, K=2, tau=2)
    stack = perturb(stack)
    for t in range(16):
        stack = eng.on_step_end(t, stack)
    st = eng.stats()
    assert 0.0 <= st["overlap_ratio"] <= 1.0
    assert st["bytes_sent"] > 0


def test_network_model_ring_allreduce():
    net = NetworkModel(num_workers=4, latency_s=0.1, bandwidth_Bps=1e9)
    t = net.allreduce_time(1_000_000_000)
    # 2*(M-1)*lat + 2*(M-1)/M * bytes/bw = 0.6 + 1.5 = 2.1
    assert abs(t - 2.1) < 1e-6
    assert net.allreduce_time(0) == pytest.approx(0.6)
    assert NetworkModel(num_workers=1).allreduce_time(123) == 0.0


def test_paper_network_calibration():
    """paper_network: T_s(fragment) == tau * T_c by construction."""
    net = paper_network(4, fragment_bytes=10_000_000, tau=5)
    assert net.t_s(10_000_000) == pytest.approx(5.0, rel=1e-6)
