"""Data-pipeline determinism: generation is a pure function of
(worker_id, step) — identical across corpus instances, across segment
boundaries, and between the per-step and segment-prefetch paths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import (MarkovCorpus, make_worker_streams,
                                 stacked_batch, stacked_segment)


def test_batch_pure_function_of_worker_and_step():
    a = MarkovCorpus(vocab=64, seed=3, worker_id=1)
    b = MarkovCorpus(vocab=64, seed=3, worker_id=1)   # fresh instance
    for step in (0, 7, 1000):
        ba, bb = a.batch(step, 4, 8), b.batch(step, 4, 8)
        np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                      np.asarray(bb["tokens"]))
        np.testing.assert_array_equal(np.asarray(ba["labels"]),
                                      np.asarray(bb["labels"]))


def test_batch_differs_across_workers_and_steps():
    a = MarkovCorpus(vocab=64, seed=3, worker_id=0)
    b = MarkovCorpus(vocab=64, seed=3, worker_id=1)
    assert not np.array_equal(np.asarray(a.batch(5, 4, 16)["tokens"]),
                              np.asarray(b.batch(5, 4, 16)["tokens"]))
    assert not np.array_equal(np.asarray(a.batch(5, 4, 16)["tokens"]),
                              np.asarray(a.batch(6, 4, 16)["tokens"]))


def test_labels_shift_tokens():
    c = MarkovCorpus(vocab=64, seed=0, worker_id=0)
    b = c.batch(3, 2, 8)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_segment_matches_per_step_batches():
    """segment(t0, n)[i] == batch(t0 + i), leaf-for-leaf — the vmapped segment
    generator is invariant to batching over the step axis."""
    c = MarkovCorpus(vocab=64, seed=5, worker_id=2)
    seg = c.segment(10, 6, 3, 12)
    assert seg["tokens"].shape == (6, 3, 12)
    for i in range(6):
        b = c.batch(10 + i, 3, 12)
        np.testing.assert_array_equal(np.asarray(seg["tokens"][i]),
                                      np.asarray(b["tokens"]))
        np.testing.assert_array_equal(np.asarray(seg["labels"][i]),
                                      np.asarray(b["labels"]))


def test_segment_invariant_to_boundaries():
    """Splitting a range into segments never changes the data: one (t0, 8)
    segment == a (t0, 3) + (t0+3, 5) split == fresh-instance replay."""
    a = MarkovCorpus(vocab=128, seed=1, worker_id=0)
    whole = a.segment(4, 8, 2, 10)
    first = a.segment(4, 3, 2, 10)
    second = MarkovCorpus(vocab=128, seed=1, worker_id=0).segment(7, 5, 2, 10)
    recombined = jax.tree.map(
        lambda x, y: jnp.concatenate([x, y]), first, second)
    for k in ("tokens", "labels"):
        np.testing.assert_array_equal(np.asarray(whole[k]),
                                      np.asarray(recombined[k]))


def test_stacked_segment_shape_and_parity():
    streams = make_worker_streams(3, 64, seed=0)
    seg = stacked_segment(streams, 10, 5, 2, 6)
    assert seg["tokens"].shape == (5, 3, 2, 6)         # (n, M, B, S)
    for i in range(5):
        sb = stacked_batch(streams, 10 + i, 2, 6)
        np.testing.assert_array_equal(np.asarray(seg["tokens"][i]),
                                      np.asarray(sb["tokens"]))


def test_eval_stream_unaffected_by_worker_rewiring():
    """worker_id=-1 (held-out stream) ignores the non-IID rewiring knob."""
    a = MarkovCorpus(vocab=64, seed=0, worker_id=-1, noniid_frac=0.0)
    b = MarkovCorpus(vocab=64, seed=0, worker_id=-1, noniid_frac=0.9)
    np.testing.assert_array_equal(np.asarray(a.batch(1, 2, 8)["tokens"]),
                                  np.asarray(b.batch(1, 2, 8)["tokens"]))
