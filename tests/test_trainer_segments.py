"""Segment-scanned execution engine: event-driven scheduling, golden-trajectory
parity between the scanned path and the per-step path (all four methods), and
the fused multi-step transition in launch/steps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core.fragments import make_fragmenter
from repro.core.network import paper_network
from repro.core.protocol import ProtocolEngine
from repro.core.trainer import CrossRegionTrainer, SegmentRunner, TrainerConfig
from repro.launch import steps as steps_lib
from repro.models import api

KEY = jax.random.PRNGKey(0)

TINY = ModelConfig(name="seg-tiny", family="dense", n_layers=4, d_model=64,
                   n_heads=2, n_kv_heads=1, d_ff=128, vocab=128,
                   compute_dtype="float32")


def make_stack(M=2, cfg=TINY):
    params = api.init_params(cfg, KEY)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)


def engine_for(method, M=2, H=10, K=2, tau=2, **ccfg_kw):
    ccfg = CoCoDCConfig(num_workers=M, local_steps=H, num_fragments=K,
                        overlap_depth=tau, **ccfg_kw)
    stack = make_stack(M)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, K)
    net = paper_network(M, fragment_bytes=frag.total_bytes // K, tau=tau)
    return ProtocolEngine(method, ccfg, frag, net, stack), stack


def perturb(stack, scale=0.01):
    leaves, treedef = jax.tree.flatten(stack)
    out = []
    for i, l in enumerate(leaves):
        noise = jax.random.normal(jax.random.fold_in(KEY, 100 + i),
                                  l.shape) * scale
        out.append(l + noise.astype(l.dtype))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# event-driven protocol API
# ---------------------------------------------------------------------------


def test_next_event_local_is_none():
    eng, _ = engine_for("local")
    assert eng.next_event_step(0) is None
    assert eng.next_event_step(123) is None


def test_next_event_diloco_round_boundary():
    eng, _ = engine_for("diloco", H=10)
    assert eng.next_event_step(0) == 9
    assert eng.next_event_step(9) == 9
    assert eng.next_event_step(10) == 19


def test_next_event_streaming_initiation_and_delivery():
    eng, stack = engine_for("streaming", H=10, K=2, tau=2)
    # h_stream = H // K = 5: initiation slots at 0, 5, 10, ...
    assert eng.next_event_step(0) == 0
    stack = eng.on_step_end(0, perturb(stack))       # initiates fragment 0
    assert eng.pending, "initiation expected at t=0"
    deliver = eng.pending[0].deliver_at
    # the pending delivery comes before the next initiation slot
    assert eng.next_event_step(1) == min(deliver, 5)


def test_next_event_is_conservative():
    """Between t and next_event_step(t), on_step_end must be a pure wall-clock
    tick: no syncs, no initiations, no deliveries."""
    eng, stack = engine_for("cocodc", H=12, K=2, tau=3)
    stack = perturb(stack)
    t = 0
    for _ in range(6):
        ne = eng.next_event_step(t)
        for q in range(t, ne):       # quiet steps
            before = (eng.n_syncs, len(eng.pending))
            stack = eng.on_step_end(q, stack)
            assert (eng.n_syncs, len(eng.pending)) == before
        stack = eng.on_step_end(ne, stack)
        t = ne + 1
    assert eng.n_syncs > 0


def test_advance_steps_matches_stepwise_wallclock():
    e1, s1 = engine_for("cocodc", H=8)
    e2, _ = engine_for("cocodc", H=8)
    for t in range(5):
        e1.wall_clock += e1.topology.t_c
    e2.advance_steps(5)
    assert e1.wall_clock == e2.wall_clock


# ---------------------------------------------------------------------------
# golden-trajectory parity: scanned segments == per-step dispatches
# ---------------------------------------------------------------------------


def _trainer(method, loop, steps=24, ckpt=None):
    mcfg = dataclasses.replace(get_config("paper_150m").reduced(),
                               compute_dtype="float32")
    ccfg = CoCoDCConfig(num_workers=2, local_steps=8, num_fragments=2,
                        overlap_depth=2)
    tcfg = TrainerConfig(method=method, local_batch=2, seq_len=16,
                         total_steps=steps, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, loop=loop)
    tr = CrossRegionTrainer(mcfg, ccfg, tcfg)
    tr.run(eval_every=8, log=lambda s: None)
    return tr


@pytest.mark.parametrize("method", ["diloco", "streaming", "cocodc", "local"])
def test_golden_trajectory_segment_matches_per_step(method):
    """Acceptance: the scanned execution engine reproduces the per-step path
    BITWISE at paper_150m toy scale — identical eval history, engine stats, and
    final worker params, for every method."""
    tr_ps = _trainer(method, "per_step")
    tr_seg = _trainer(method, "segment")

    s_ps, s_seg = tr_ps.engine.stats(), tr_seg.engine.stats()
    for k in s_ps:
        assert s_ps[k] == s_seg[k], f"stats[{k}]: {s_ps[k]} vs {s_seg[k]}"

    assert len(tr_ps.history) == len(tr_seg.history) > 0
    for a, b in zip(tr_ps.history, tr_seg.history):
        assert a["step"] == b["step"]
        assert a["train_loss"] == b["train_loss"]
        assert a["nll"] == b["nll"]

    for x, y in zip(jax.tree.leaves(tr_ps.params_stack),
                    jax.tree.leaves(tr_seg.params_stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(tr_ps.engine.theta_g),
                    jax.tree.leaves(tr_seg.engine.theta_g)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_golden_trajectory_audio_frames_parity():
    """The audio stub frontend (per-step frame embeddings) rides through the
    scanned segment identically to the per-step path — _augment_segment must
    stack exactly the frames _augment would generate per step."""
    audio = ModelConfig(name="audio-seg", family="audio", n_layers=2,
                        d_model=48, n_heads=2, n_kv_heads=1, d_ff=96, vocab=96,
                        n_enc_layers=2, n_prefix_tokens=4, prefix_dim=16,
                        compute_dtype="float32")

    def make(loop):
        ccfg = CoCoDCConfig(num_workers=2, local_steps=6, num_fragments=2,
                            overlap_depth=2)
        tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=12,
                             total_steps=12, warmup_steps=2, inner_lr=3e-3,
                             eval_batch=2, loop=loop)
        tr = CrossRegionTrainer(audio, ccfg, tcfg)
        tr.run(eval_every=6, log=lambda s: None)
        return tr

    a, b = make("per_step"), make("segment")
    for x, y in zip(a.history, b.history):
        assert x["nll"] == y["nll"] and x["train_loss"] == y["train_loss"]
    for x, y in zip(jax.tree.leaves(a.params_stack),
                    jax.tree.leaves(b.params_stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_segment_loop_fuses_dispatches():
    """The scanned loop calls the engine only at events: H=8/K=2 cocodc over 24
    steps must execute far fewer host iterations than steps (tracked via
    segment boundaries in next_event_step)."""
    tr = _trainer("cocodc", "segment")
    # every record/step accounted for, and the trainer reached the target
    assert tr.step == 24
    assert tr.history[-1]["step"] == 24


def test_segment_runner_matches_train_step():
    """SegmentRunner over n steps == n sequential vmapped train steps, given
    identical inputs (the fused program is numerically the same loop)."""
    mcfg = TINY
    tcfg = TrainerConfig(method="local", local_batch=2, seq_len=16,
                         total_steps=8, warmup_steps=2, inner_lr=3e-3)
    ccfg = CoCoDCConfig(num_workers=2, local_steps=4, num_fragments=2)
    tr = CrossRegionTrainer(mcfg, ccfg, tcfg)

    from repro.data.pipeline import stacked_batch, stacked_segment
    n = 5
    seg = stacked_segment(tr.streams, 0, n, 2, 16)
    lrs = tr.lr(jnp.arange(n))
    p_seg, o_seg, losses = tr.segment_runner(tr.params_stack, tr.opt_state,
                                             seg, lrs)
    p, o = tr.params_stack, tr.opt_state
    step_losses = []
    for t in range(n):
        batch = stacked_batch(tr.streams, t, 2, 16)
        p, o, l = tr._train_step(p, o, batch, tr.lr(t))
        step_losses.append(np.asarray(l))
    assert losses.shape == (n, 2)
    np.testing.assert_array_equal(np.asarray(losses), np.stack(step_losses))
    for x, y in zip(jax.tree.leaves(p_seg), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# launch/steps fused multi-step transition
# ---------------------------------------------------------------------------


def test_make_segment_step_matches_per_step():
    cfg = TINY
    params = api.init_params(cfg, KEY)
    from repro.optim import adamw_init
    opt = adamw_init(params)
    from repro.data.pipeline import MarkovCorpus
    c = MarkovCorpus(vocab=cfg.vocab, seed=0, worker_id=0)
    n = 3
    seg = c.segment(0, n, 2, 16)
    lrs = jnp.full((n,), 1e-3, jnp.float32)

    seg_fn = jax.jit(steps_lib.make_segment_step(cfg, remat=False))
    p_seg, o_seg, losses = seg_fn(params, opt, seg, lrs)

    step_fn = jax.jit(steps_lib.make_train_step(cfg, remat=False))
    p, o = params, opt
    for t in range(n):
        batch = {k: v[t] for k, v in seg.items()}
        p, o, _ = step_fn(p, o, batch, 1e-3)
    for x, y in zip(jax.tree.leaves(p_seg), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)
    assert losses.shape == (n,)
    assert bool(jnp.all(jnp.isfinite(losses)))


def test_make_pod_segment_step_shapes():
    cfg = TINY
    M, n = 2, 3
    stack = make_stack(M)
    from repro.optim import adamw_init
    opt = jax.vmap(adamw_init)(stack)
    from repro.data.pipeline import make_worker_streams, stacked_segment
    streams = make_worker_streams(M, cfg.vocab, seed=0)
    seg = stacked_segment(streams, 0, n, 2, 16)          # (n, M, B, S)
    lrs = jnp.full((n,), 1e-3, jnp.float32)
    fn = jax.jit(steps_lib.make_pod_segment_step(cfg, remat=False))
    p, o, losses = fn(stack, opt, seg, lrs)
    assert losses.shape == (M, n)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(stack)):
        assert a.shape == b.shape
