"""Dynamic WAN simulator: generated meshes, time-varying links, and the
static-path bitwise regression guard.

The golden constants in `STATIC_GOLDEN` were captured from the PR 2 engine
(static Topology, before the dynamics layer existed): the refactored
`_schedule_transfer` must reproduce the exact same delivery schedule and
traffic accounting when `dynamics is None`.
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core.fragments import make_fragmenter
from repro.core.network import (DiurnalProfile, LinkDynamics, LinkEvent,
                                MESH_PROFILES, Topology, apply_dynamics,
                                generate_mesh, make_scenario, parse_dynamics,
                                paper_network)
from repro.core.protocol import ProtocolEngine
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from repro.models import api

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

KEY = jax.random.PRNGKey(0)
TINY = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                   n_heads=2, n_kv_heads=1, d_ff=128, vocab=128,
                   compute_dtype="float32")


def engine_for(method, network, M=2, H=10, K=2, tau=2, engine_impl="host"):
    ccfg = CoCoDCConfig(num_workers=M, local_steps=H, num_fragments=K,
                        overlap_depth=tau)
    params = api.init_params(TINY, KEY)
    stack = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(TINY, shape, K)
    if network == "paper":
        net = paper_network(M, fragment_bytes=frag.total_bytes // K, tau=tau)
    elif isinstance(network, str):
        net = make_scenario(network, num_workers=M)
    else:
        net = network
    return ProtocolEngine(method, ccfg, frag, net, stack,
                          engine_impl=engine_impl), stack


def zero_lat_topology(bw=1e6, m=2, **kw):
    """Latency-free uniform mesh: transfer time is pure bandwidth work, so the
    dynamics integration can be checked against closed-form arithmetic."""
    lat = np.zeros((m, m))
    b = np.full((m, m), float(bw))
    np.fill_diagonal(b, np.inf)
    return Topology(latency_s=lat, bandwidth_Bps=b, **kw)


# ---------------------------------------------------------------------------
# generated meshes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("profile", sorted(MESH_PROFILES))
@pytest.mark.parametrize("n", [4, 8, 16])
def test_generate_mesh_valid_and_deterministic(profile, n):
    t = generate_mesh(n, profile, seed=3)
    assert t.num_workers == n
    off = ~np.eye(n, dtype=bool)
    assert np.all(np.isfinite(t.bandwidth_Bps[off]))
    assert np.all(t.bandwidth_Bps[off] > 0)
    assert np.all(t.latency_s[off] > 0)
    assert np.all(np.diag(t.latency_s) == 0)
    assert len(set(t.regions)) == n
    assert t.allreduce_time(1_000_000) > 0
    # same seed -> identical mesh; different seed -> different mesh
    t2 = generate_mesh(n, profile, seed=3)
    np.testing.assert_array_equal(t.latency_s, t2.latency_s)
    np.testing.assert_array_equal(t.bandwidth_Bps, t2.bandwidth_Bps)
    t3 = generate_mesh(n, profile, seed=4)
    assert not np.array_equal(t.latency_s, t3.latency_s)


def test_generate_mesh_profiles_differ_structurally():
    hub = generate_mesh(6, "hub_spoke", seed=0)
    assert hub.collective == "hierarchical" and hub.regions[0] == "hub"
    ring = generate_mesh(6, "ring", seed=0)
    assert ring.collective == "ring"
    with pytest.raises(KeyError):
        generate_mesh(6, "nope")


def test_mesh_engine_runs_n8():
    """An 8-region generated mesh drives the full engine (beyond the old
    4-region ceiling)."""
    eng, stack = engine_for("cocodc", generate_mesh(8, "random_geo", seed=1),
                            M=8)
    for t in range(12):
        stack = eng.on_step_end(t, stack)
    assert eng.n_syncs > 0
    assert eng.link_bytes.shape == (8, 8)
    assert eng.link_bytes.sum() > 0


# ---------------------------------------------------------------------------
# time-varying transfer integration
# ---------------------------------------------------------------------------


def test_transfer_time_static_matches_closed_form():
    t = generate_mesh(4, "ring", seed=0)
    finish, nominal, retries = t.transfer_time(10_000_000, 5.0)
    assert finish == 5.0 + t.t_s(10_000_000)
    assert nominal == t.t_s(10_000_000) and retries == 0


def test_diurnal_trough_slows_transfer():
    t = zero_lat_topology(bw=1e6)
    nominal = t.t_s(1_000_000)          # 1 bandwidth-second of work
    dyn = LinkDynamics(diurnal=DiurnalProfile(period_s=100.0, trough_depth=0.8,
                                              n_bins=4))
    td = t.with_dynamics(dyn)
    # start mid-trough (t=50): the factor there is 1 - 0.8*(0.5-0.5*cos(pi+..))
    finish, nom, _ = td.transfer_time(1_000_000, 50.0)
    assert nom == nominal
    assert finish - 50.0 > nominal      # trough stretches the transfer
    # depth 0 == static rate
    flat = t.with_dynamics(LinkDynamics(diurnal=DiurnalProfile(
        period_s=100.0, trough_depth=0.0)))
    finish0, _, _ = flat.transfer_time(1_000_000, 50.0)
    assert abs(finish0 - (50.0 + nominal)) < 1e-9


def test_degradation_factor_integrates_exactly():
    """factor=0.5 over the whole transfer -> exactly twice the bandwidth time
    (latency-free topology, closed-form)."""
    t = zero_lat_topology(bw=1e6)
    nominal = t.t_s(1_000_000)
    td = t.with_dynamics(LinkDynamics(events=(
        LinkEvent(0.0, 1e9, 0, 1, bandwidth_factor=0.5),)))
    finish, _, _ = td.transfer_time(1_000_000, 0.0)
    assert abs(finish - 2 * nominal) < 1e-9


def test_outage_pauses_and_retries():
    """An outage window freezes progress; recovery pays the latency phases
    again (one retry) and the remaining work completes at full rate."""
    m = 2
    lat = np.full((m, m), 0.1)
    np.fill_diagonal(lat, 0.0)
    bw = np.full((m, m), 1e6)
    np.fill_diagonal(bw, np.inf)
    t = Topology(latency_s=lat, bandwidth_Bps=bw)
    lat_part = t.t_s(0)                       # 2*(M-1)*0.1 = 0.2
    work = t.t_s(1_000_000) - lat_part        # 1.0 bandwidth-second
    # outage hits halfway through the bandwidth phase
    outage_start = lat_part + 0.5 * work
    td = t.with_dynamics(LinkDynamics(events=(
        LinkEvent(outage_start, outage_start + 10.0, 0, 1,
                  bandwidth_factor=0.0),)))
    finish, nominal, retries = td.transfer_time(1_000_000, 0.0)
    assert retries == 1
    expect = (outage_start + 10.0) + lat_part + 0.5 * work
    assert abs(finish - expect) < 1e-9
    # the same transfer started after the outage is unaffected
    finish2, _, r2 = td.transfer_time(1_000_000, outage_start + 10.0)
    assert r2 == 0
    assert abs(finish2 - (outage_start + 10.0 + nominal)) < 1e-9


def test_outage_under_diurnal_counts_one_retry():
    """Diurnal bin edges INSIDE an outage window must not each charge a retry
    (code-review finding): one dark window = one recovery = one retry, and the
    latency phases are re-paid once."""
    t = zero_lat_topology(bw=1e6)
    lat = np.full((2, 2), 0.05)
    np.fill_diagonal(lat, 0.0)
    t = dataclasses.replace(t, latency_s=lat)
    lat_part = t.t_s(0)
    # 10 diurnal bins fall inside the [1, 21) outage
    dyn = LinkDynamics(
        diurnal=DiurnalProfile(period_s=2.0, trough_depth=0.2, n_bins=1),
        events=(LinkEvent(1.0, 21.0, 0, 1, bandwidth_factor=0.0),))
    td = t.with_dynamics(dyn)
    finish, _, retries = td.transfer_time(2_000_000, 0.0)
    assert retries == 1
    # finish = recovery + one latency re-pay + remaining work at diurnal rate;
    # served [lat_part, 1.0) before the outage at known bin factors
    assert finish < 21.0 + lat_part + 4.0


def test_mesh_stream_tags_pinned():
    """Profile RNG stream tags are permanent: adding a profile must not shift
    existing meshes (code-review finding). Canary values pin the streams."""
    assert generate_mesh(4, "ring", seed=0).latency_s[0, 1] == \
        pytest.approx(0.07369836444739032, abs=1e-12)
    assert generate_mesh(4, "random_geo", seed=0).latency_s[0, 1] == \
        pytest.approx(0.07467305906078507, abs=1e-12)


def test_permanent_outage_raises():
    t = zero_lat_topology()
    td = t.with_dynamics(LinkDynamics(events=(
        LinkEvent(0.0, np.inf, 0, 1, bandwidth_factor=0.0),)))
    with pytest.raises(RuntimeError, match="outage"):
        td.transfer_time(1_000_000, 0.0)


def test_jitter_deterministic_per_seq():
    d = LinkDynamics(jitter_frac=0.1, seed=7)
    assert d.jitter_mult(3) == d.jitter_mult(3)
    assert d.jitter_mult(3) != d.jitter_mult(4)
    assert abs(d.jitter_mult(3) - 1.0) <= 0.1 + 1e-12
    assert LinkDynamics(jitter_frac=0.0).jitter_mult(5) == 1.0
    # a different seed gives a different stream
    assert LinkDynamics(jitter_frac=0.1, seed=8).jitter_mult(3) != \
        d.jitter_mult(3)


def test_parse_dynamics_spec():
    t = make_scenario("asym4")
    dyn = parse_dynamics("diurnal:period=120:depth=0.6:stagger=1.0,"
                         "hub_failure:start=40:dur=24,"
                         "flaky:n=3:dur=5,jitter:frac=0.07", t, seed=5)
    assert dyn.diurnal.period_s == 120.0
    assert dyn.diurnal.trough_depth == 0.6
    assert len(dyn.diurnal.phase_s) == 4
    assert dyn.jitter_frac == 0.07
    # hub_failure auto-picks the best-connected region; 3 hub links + 3 flaky
    assert len(dyn.events) == 3 + 3
    hub_events = [e for e in dyn.events if e.bandwidth_factor == 0.0]
    assert len(hub_events) == 3
    assert len({e.src for e in hub_events}) == 1
    # flaky windows target the thinnest *used* link and are seed-stable
    dyn2 = parse_dynamics("flaky:n=3:dur=5", t, seed=5)
    flaky = [e for e in dyn.events if e.bandwidth_factor != 0.0]
    assert [e.start_s for e in flaky] == [e.start_s for e in dyn2.events]
    with pytest.raises(KeyError, match="unknown dynamics kind"):
        parse_dynamics("wormhole:x=1", t)
    assert apply_dynamics(t, None) is t
    assert apply_dynamics(t, dyn).dynamics is dyn


# ---------------------------------------------------------------------------
# engine under dynamics: stall accounting + schedule shifts
# ---------------------------------------------------------------------------


def test_engine_accounts_stall_and_retries():
    base = make_scenario("asym4")
    dyn_top = apply_dynamics(base, "hub_failure:start=1.5:dur=30:hub=0",
                             seed=0)
    eng, stack = engine_for("streaming", dyn_top, M=4)
    eng_static, stack_s = engine_for("streaming", base, M=4)
    for t in range(24):
        stack = eng.on_step_end(t, stack)
        stack_s = eng_static.on_step_end(t, stack_s)
    st, ss = eng.stats(), eng_static.stats()
    assert st["stall_seconds"] > 0
    assert st["n_retries"] >= 1
    assert 0 < st["stall_fraction"] <= 1
    assert st["comm_seconds"] > ss["comm_seconds"]
    # per-link busy-seconds include the stall (code-review finding): the
    # stalled run's links are busier than the static run's by at least the
    # stall, so the accounting reconciles with comm_seconds
    assert float(eng.link_seconds.sum()) > \
        float(eng_static.link_seconds.sum()) + st["stall_seconds"] * 0.9
    # static runs never touch the dynamic counters
    assert ss["stall_seconds"] == 0 and ss["n_retries"] == 0
    # delayed deliveries land later than on the static network
    assert eng.n_syncs <= eng_static.n_syncs or \
        st["comm_seconds"] > ss["comm_seconds"]


def test_scheduler_state_roundtrips_dynamics_clocks():
    dyn_top = apply_dynamics(make_scenario("asym4"),
                             "diurnal:period=24:depth=0.7,jitter:frac=0.1",
                             seed=3)
    eng, stack = engine_for("cocodc", dyn_top, M=4)
    for t in range(10):
        stack = eng.on_step_end(t, stack)
    st = eng.scheduler_state()
    assert st["dyn_seq"] == eng._dyn_seq > 0
    eng2, _ = engine_for("cocodc", dyn_top, M=4)
    eng2.restore_scheduler(st)
    assert eng2._dyn_seq == eng._dyn_seq
    assert eng2.stall_seconds == eng.stall_seconds
    assert eng2.n_retries == eng.n_retries
    # pre-dynamics checkpoints (no dyn keys) restore with zeroed clocks
    legacy = {k: v for k, v in st.items()
              if k not in ("dyn_seq", "stall_seconds", "n_retries")}
    eng3, _ = engine_for("cocodc", dyn_top, M=4)
    eng3.restore_scheduler(legacy)
    assert eng3._dyn_seq == 0 and eng3.stall_seconds == 0.0


# ---------------------------------------------------------------------------
# static-path bitwise regression guard (PR 2 goldens)
# ---------------------------------------------------------------------------

# (network, method) -> end-of-run counters captured on the PR 2 engine with
# the TINY model above, M as listed, H=10, K=2, tau=2, 24 steps. Delivery
# steps and transfer finish times must stay EXACTLY equal: the dynamics
# refactor may not perturb the static arithmetic.
STATIC_GOLDEN = {
    ("paper", "streaming", 2): dict(
        wall=24.0, comm=10.000700661736085, nbytes=1644288, syncs=5,
        ch=[23.000700661736083], ls=20.00140132347217, lb=3288576.0,
        first_events=[(0, 0, 0, 3, 3.0007006617360843),
                      (5, 1, 5, 7, 7.999299338263916)]),
    ("paper", "cocodc", 2): dict(
        wall=24.0, comm=10.000700661736085, nbytes=1644288, syncs=5,
        ch=[22.999299338263917], ls=20.00140132347217, lb=3288576.0,
        first_events=[(0, 0, 0, 3, 3.0007006617360843),
                      (5, 1, 5, 7, 7.999299338263916)]),
    ("asym4", "streaming", 4): dict(
        wall=24.0, comm=3.6078925824, nbytes=1644288, syncs=5,
        ch=[21.721579008], ls=9.31509456384, lb=9865728.0,
        first_events=[(0, 0, 0, 1, 1.721579008),
                      (5, 1, 5, 6, 6.7215777792)]),
    ("asym4", "cocodc", 4): dict(
        wall=24.0, comm=8.658945638399999, nbytes=3947008, syncs=12,
        ch=[23.721579008], ls=22.356233533439998, lb=23682048.0,
        first_events=[(0, 0, 0, 1, 1.721579008),
                      (2, 1, 2, 3, 3.7215777792)]),
    ("transpacific_flaky", "streaming", 4): dict(
        wall=24.0, comm=4.9657851648, nbytes=1644288, syncs=5,
        ch=[21.993158016], ls=11.72693343744, lb=9865728.0,
        first_events=[(0, 0, 0, 1, 1.993158016),
                      (5, 1, 5, 6, 6.9931555584)]),
    ("transpacific_flaky", "cocodc", 4): dict(
        wall=24.0, comm=11.9178912768, nbytes=3947008, syncs=12,
        ch=[23.993158016], ls=28.14465199104, lb=23682048.0,
        first_events=[(0, 0, 0, 1, 1.993158016),
                      (2, 1, 2, 3, 3.9931555584)]),
}


@pytest.mark.parametrize("network,method,M", sorted(STATIC_GOLDEN))
def test_static_schedule_bitwise_unchanged(network, method, M):
    golden = STATIC_GOLDEN[(network, method, M)]
    eng, stack = engine_for(method, network, M=M)
    assert eng.topology.dynamics is None
    initiations = []
    for t in range(24):
        before = {e.seq for e in eng.pending}
        stack = eng.on_step_end(t, stack)
        for e in eng.pending:
            if e.seq not in before:
                initiations.append((t, e.frag, e.t_init, e.deliver_at,
                                    e.finish_time))
    assert eng.wall_clock == golden["wall"]
    assert eng.comm_seconds == golden["comm"]
    assert eng.bytes_sent == golden["nbytes"]
    assert eng.n_syncs == golden["syncs"]
    assert eng._channel_free == golden["ch"]
    assert float(eng.link_seconds.sum()) == golden["ls"]
    assert float(eng.link_bytes.sum()) == golden["lb"]
    assert initiations[:2] == golden["first_events"]
    # the dynamic counters never move on a static topology
    assert eng._dyn_seq == 0 and eng.stall_seconds == 0.0


# ---------------------------------------------------------------------------
# mid-transfer checkpoint/resume on a dynamic topology (satellite)
# ---------------------------------------------------------------------------


def _dyn_trainer(seed=0):
    mcfg = dataclasses.replace(TINY, name="dyn-ck")
    ccfg = CoCoDCConfig(num_workers=4, local_steps=8, num_fragments=2,
                        overlap_depth=2)
    tcfg = TrainerConfig(method="cocodc", local_batch=2, seq_len=16,
                         total_steps=24, warmup_steps=4, inner_lr=3e-3,
                         eval_batch=4, seed=seed)
    return CrossRegionTrainer(
        mcfg, ccfg, tcfg, network=make_scenario("asym4"),
        dynamics="diurnal:period=16:depth=0.7,jitter:frac=0.1",
        dynamics_seed=11)


def test_dynamic_mid_transfer_kill_and_resume(tmp_path):
    """Kill the run while a fragment is IN FLIGHT on a diurnal link, resume,
    and require the bitwise-identical trajectory AND link accounting the
    uninterrupted run produces — the dynamics clocks must serialize."""
    ck = os.path.join(tmp_path, "dyn.msgpack")

    ref = _dyn_trainer()
    ref.run(eval_every=8, log=lambda s: None)
    assert ref.engine.stats()["stall_seconds"] > 0     # dynamics really bit

    tr = _dyn_trainer()
    tr.run(steps=6, eval_every=8, log=lambda s: None)
    while not tr.engine.pending and tr.step < 20:      # need an in-flight frag
        tr.run(steps=tr.step + 1, eval_every=8, log=lambda s: None)
    assert tr.engine.pending, "no mid-transfer state to checkpoint"
    tr.save_checkpoint(ck)

    resumed = _dyn_trainer().restore_checkpoint(ck)
    assert resumed.engine._dyn_seq == tr.engine._dyn_seq > 0
    assert [e.finish_time for e in resumed.engine.pending] == \
        [e.finish_time for e in tr.engine.pending]
    resumed.run(eval_every=8, log=lambda s: None)

    ra = {r["step"]: r for r in ref.history}
    rb = {r["step"]: r for r in resumed.history}
    shared = sorted(set(ra) & set(rb))
    assert shared
    for s in shared:
        assert ra[s]["nll"] == rb[s]["nll"]
        assert ra[s]["wall_clock_s"] == rb[s]["wall_clock_s"]
        assert ra[s]["stall_seconds"] == rb[s]["stall_seconds"]

    sa, sb = ref.engine.stats(), resumed.engine.stats()
    for k in sa:
        assert sa[k] == sb[k], f"stats[{k}]: {sa[k]} vs {sb[k]}"
    np.testing.assert_array_equal(ref.engine.link_bytes,
                                  resumed.engine.link_bytes)
    np.testing.assert_array_equal(ref.engine.link_seconds,
                                  resumed.engine.link_seconds)
    for x, y in zip(jax.tree.leaves(ref.params_stack),
                    jax.tree.leaves(resumed.params_stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# sweep harness schema contract
# ---------------------------------------------------------------------------


def test_sweep_validate_payload_catches_drift():
    from benchmarks.sweep import STATS_KEYS, validate_payload
    ok = {"scenario": {"dynamics": None}, "steps": 8, "target_ppl": 30.0,
          "runs": {"cocodc": {
              "final_ppl": 25.0, "final_nll": 3.2, "steps_to_target": 8,
              "host_s": 1.0, "history": [{"step": 8, "nll": 3.2}],
              "stats": {k: 1.0 for k in STATS_KEYS},
              "link_stats": {"links": {"a->b": {"busy_fraction": 0.5}}}}}}
    validate_payload(ok, "ok")                     # no raise
    bad = {**ok, "runs": {"cocodc": {**ok["runs"]["cocodc"],
                                     "final_ppl": float("nan")}}}
    with pytest.raises(AssertionError, match="not finite"):
        validate_payload(bad, "nan")
    missing = {**ok, "runs": {"cocodc": {
        k: v for k, v in ok["runs"]["cocodc"].items() if k != "stats"}}}
    with pytest.raises(AssertionError, match="stats"):
        validate_payload(missing, "missing")
    nofrac = {**ok, "runs": {"cocodc": {
        **ok["runs"]["cocodc"], "link_stats": {"links": {"a->b": {}}}}}}
    with pytest.raises(AssertionError, match="busy_fraction"):
        validate_payload(nofrac, "nofrac")


def test_sweep_bw_autocalibration_is_bandwidth_dominated():
    """Auto-calibrated bw_scale puts every grid topology's mean-fragment
    collective at CALIB_BW_STEPS bandwidth-seconds — strictly above its
    latency phases, so the dynamics under test actually bite."""
    from benchmarks.sweep import (CALIB_BW_STEPS, SCENARIOS, build_network,
                                  fragment_wire_bytes)
    fb = fragment_wire_bytes()
    checked = 0
    for sc in SCENARIOS:
        if sc.mesh is None and sc.topology is None:
            continue
        net = build_network(sc)
        lat = net.allreduce_time(0)
        # the pure bandwidth phase (latency-free copy) hits the target exactly
        lat_free = dataclasses.replace(net,
                                       latency_s=np.zeros_like(net.latency_s))
        assert lat_free.allreduce_time(fb) == pytest.approx(
            CALIB_BW_STEPS * net.step_time_s, rel=1e-9), sc.name
        # and on the real mesh the transfer stays bandwidth-dominated
        assert net.allreduce_time(fb) - lat > lat, sc.name
        checked += 1
    assert checked >= 6
    # the override field still wins over the calibration
    import dataclasses as dc
    sc = next(s for s in SCENARIOS if s.name == "hub_failure8")
    net_auto = build_network(sc)
    net_fixed = build_network(dc.replace(sc, bw_scale=1.0))
    assert float(net_fixed.bandwidth_Bps[0, 1]) != \
        float(net_auto.bandwidth_Bps[0, 1])


def test_sweep_compare_routed_contract():
    """--smoke fails iff the routed run's stall_fraction is not STRICTLY
    below its static twin's."""
    from benchmarks.sweep import compare_routed

    def payload(sf):
        return {"runs": {"cocodc": {"stats": {
            "stall_fraction": sf, "reroutes": 1.0, "hub_elections": 2.0}}}}

    worse = compare_routed({"hub_failure8": payload(0.1),
                            "hub_failure8_routed": payload(0.1)})
    assert worse and "not strictly below" in worse[0]
    better = compare_routed({"hub_failure8": payload(0.2),
                             "hub_failure8_routed": payload(0.05)})
    assert better == []
    # a lone scenario (no twin present) is not comparable -> no failure
    assert compare_routed({"hub_failure8": payload(0.2)}) == []
