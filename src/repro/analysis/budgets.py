"""Declarative budget registry for the static-analysis pass.

Everything the auditor/linter enforces that is a *number or a list* lives
here, so adding a method or a kernel family means declaring its contract in
one place — not editing checker code:

  * ``ENGINE_DISPATCH_BUDGETS`` — exact ``pallas_call`` dispatch counts for
    the jitted engine transitions, per (method, fused_updates, impl mode).
    ROADMAP item-1 authors: a new ``@register_method`` strategy MUST add its
    rows (``register_dispatch_budget``) or ``python -m repro.analysis``
    fails with a coverage error.
  * ``SERVE_DISPATCH_BUDGETS`` / ``SEGMENT_SCAN_PALLAS_CALLS`` — the serve
    decode/prefill steps and the trainer's fused segment scan.
  * ``BANNED_PRIMITIVES`` — primitives that must never appear inside a
    jitted protocol-plane program (host callbacks stall the device pipeline;
    ``debug_callback`` is what ``jax.debug.print`` lowers to).
  * ``KERNEL_CONTRACTS`` — per kernel family: upper bounds for tile dims the
    linter cannot resolve statically (the TPU-target shapes), and a VMEM
    footprint budget for the sum of all declared BlockSpec tiles
    (TPU VMEM is ~16 MiB/core; every family must fit with headroom).

Counts are audited on the *traced jaxpr*, so they are backend-independent:
``impl="kernel"``/``"pallas"`` entries pin the accelerator program (interpret
mode emits the same ``pallas_call`` primitives), ``"ref"`` entries pin that
the oracle paths stay kernel-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple, Union

# Sentinel: expected count == number of (non-None) leaves in the audited
# fragment — the per-leaf kernel path pays one dispatch per fragment leaf.
LEAVES = "leaves"

CountSpec = Union[int, str]
BudgetKey = Tuple[str, bool, str]          # (method, fused_updates, impl)

# ---------------------------------------------------------------------------
# engine transition dispatch budgets
# ---------------------------------------------------------------------------

# Transitions audited per entry are exactly the dict keys — non-overlapped
# methods (diloco/local) park nothing in flight, so only their blocking
# round is traced. impl modes: per-leaf entries use the delay-comp policy
# ("ref" oracle | "kernel"), fused entries use the outer_update policy
# ("ref" | "pallas"). The fused kernel path is the PR-8 guarantee: exactly
# TWO dispatches per delivery/round (one Nesterov, one fused deliver),
# independent of model depth.
ENGINE_DISPATCH_BUDGETS: Dict[BudgetKey, Dict[str, CountSpec]] = {
    ("local", False, "ref"): {"diloco_round": 0},
    ("local", True, "ref"): {"diloco_round": 0},
    ("local", True, "pallas"): {"diloco_round": 2},

    ("diloco", False, "ref"): {"diloco_round": 0},
    ("diloco", True, "ref"): {"diloco_round": 0},
    ("diloco", True, "pallas"): {"diloco_round": 2},

    ("streaming", False, "ref"): {"initiate": 0, "deliver": 0,
                                  "diloco_round": 0},
    ("streaming", False, "kernel"): {"initiate": 0, "deliver": 0},
    ("streaming", True, "ref"): {"initiate": 0, "deliver": 0},
    ("streaming", True, "pallas"): {"initiate": 0, "deliver": 2,
                                    "diloco_round": 2},

    ("cocodc", False, "ref"): {"initiate": 0, "deliver": 0},
    # the per-leaf kernel path pays one delay-comp dispatch PER LEAF
    ("cocodc", False, "kernel"): {"initiate": 0, "deliver": LEAVES},
    ("cocodc", True, "ref"): {"initiate": 0, "deliver": 0},
    ("cocodc", True, "pallas"): {"initiate": 0, "deliver": 2,
                                 "diloco_round": 2},
}


def register_dispatch_budget(method: str, *, fused: bool, impl: str,
                             budget: Dict[str, CountSpec]) -> None:
    """Declare the dispatch budget for a new sync method (the method-author
    half of the audit contract). Keys of `budget` are the transitions to
    trace ("initiate" | "deliver" | "diloco_round"); values are exact
    ``pallas_call`` counts (or the LEAVES sentinel)."""
    for k in budget:
        if k not in ("initiate", "deliver", "diloco_round"):
            raise ValueError(f"unknown transition {k!r} in budget for "
                             f"{method!r}")
    ENGINE_DISPATCH_BUDGETS[(method, fused, impl)] = dict(budget)


def budgeted_methods() -> Tuple[str, ...]:
    """Methods with at least one declared dispatch budget."""
    return tuple(sorted({m for (m, _, _) in ENGINE_DISPATCH_BUDGETS}))


# ---------------------------------------------------------------------------
# serve plane + segment scan
# ---------------------------------------------------------------------------

# attn_impl -> exact pallas_call count per traced step. "flash" decode is ONE
# dispatch: the layer stack runs under lax.scan, so the kernel appears once
# in the traced program regardless of depth.
SERVE_DISPATCH_BUDGETS: Dict[str, Dict[str, int]] = {
    "ref": {"decode": 0, "prefill": 0},
    "flash": {"decode": 1, "prefill": 0},
}

# the fused inner-step scan is pure XLA — no Pallas dispatch ever
SEGMENT_SCAN_PALLAS_CALLS = 0

# ---------------------------------------------------------------------------
# banned primitives (jitted protocol plane)
# ---------------------------------------------------------------------------

BANNED_PRIMITIVES = frozenset({
    "pure_callback",        # host round-trip inside the hot path
    "io_callback",
    "callback",
    "debug_callback",       # jax.debug.print / jax.debug.callback
    "infeed", "outfeed",    # legacy host transfers
})

# ---------------------------------------------------------------------------
# kernel family contracts (AST linter)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Static contract for one ``kernels/<family>/`` package.

    ``dim_bounds`` declares the TPU-target upper bound for every BlockSpec
    tile dimension the linter cannot resolve to a module constant (runtime
    names like ``hd``/``bc``/``block``). Bounds participate in two checks:
    a LAST tile dim must be lane-aligned (% 128 == 0) whether it is a
    resolved constant or a declared bound, and the VMEM footprint estimate
    (sum over every declared tile of prod(dims) * dtype_bytes) must stay
    under ``vmem_budget_bytes``."""
    dim_bounds: Mapping[str, int] = dataclasses.field(default_factory=dict)
    vmem_budget_bytes: int = 8 * 1024 * 1024      # half of ~16 MiB VMEM/core
    dtype_bytes: int = 4                          # f32 operands


KERNEL_CONTRACTS: Dict[str, KernelContract] = {
    # (block, LANES=1024) tiles, block = min(BLOCK_ROWS=256, rows)
    "delay_comp": KernelContract(dim_bounds={"block": 256}),
    # encode: (rows, block) in, (rows, pb)+(rows, LANES=128) out; block is
    # kernel-gated to a multiple of 256 and the engine dials run <= 1024
    "delta_codec": KernelContract(
        dim_bounds={"rows": 256, "block": 1024, "pb": 1024}),
    # q tile (bq, hd) vs full-K kv tiles (Sk, hd): Sk bound = the longest
    # sequence the training configs trace (paper seq lens << 4096)
    "flash_attention": KernelContract(
        dim_bounds={"bq": 128, "bk": 128, "hd": 128, "Sk": 4096}),
    # per-(b, kv-head) decode: (bc, hd) cache tiles over the ring buffer
    "flash_decode": KernelContract(
        dim_bounds={"bc": 512, "hd": 128, "G": 16}),
    # (block, D) rows x model width; D bound = widest registered d_model
    "rms_norm": KernelContract(dim_bounds={"block": 256, "D": 2048}),
    "rglru_scan": KernelContract(dim_bounds={"bt": 256, "bd": 128}),
    "rwkv6_scan": KernelContract(dim_bounds={"bt": 128, "hd": 128}),
    # flat fragment plane: (BLOCK_ROWS=256, LANES=1024) f32 tiles
    "outer_update": KernelContract(dim_bounds={"block": 256}),
}
