"""`python -m repro.analysis` — run the full static-analysis pass.

Sections (each prints PASS or its violation list; exit 1 if any fail):

  kernel-contracts   AST lint of every kernels/<family>/ package
  purity             unseeded np.random + wall-clock-in-core lint
  engine-dispatch    pallas_call budgets per method x fused x impl,
                     banned primitives, no-f64 (traced jaxprs)
  segment-scan       the fused inner-step scan stays pure XLA
  serve              decode/prefill budgets per attn_impl
  donation           declared donations appear in the lowering

`--smoke` is the CI entrypoint (the pass is already smoke-sized — identical
checks, kept as a flag so every CI job reads uniformly). `--section NAME`
runs one section (repeatable).
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List


def _sections() -> Dict[str, Callable[[], List[str]]]:
    # imported lazily so `--help` stays instant and import errors surface
    # per-section instead of killing the whole CLI
    from repro.analysis import jaxpr_audit, kernel_lint
    return {
        "kernel-contracts": kernel_lint.run_kernel_lint,
        "purity": kernel_lint.lint_purity,
        "engine-dispatch": jaxpr_audit.audit_engine,
        "segment-scan": jaxpr_audit.audit_segment,
        "serve": jaxpr_audit.audit_serve,
        "donation": jaxpr_audit.audit_donation,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: trace auditor + kernel-contract linter")
    ap.add_argument("--smoke", action="store_true",
                    help="CI entrypoint (same checks; the pass is smoke-sized)")
    ap.add_argument("--section", action="append", default=None,
                    metavar="NAME", help="run only the named section(s)")
    args = ap.parse_args(argv)

    sections = _sections()
    names = args.section or list(sections)
    unknown = [n for n in names if n not in sections]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; options: {list(sections)}")

    n_violations = 0
    for name in names:
        try:
            violations = sections[name]()
        except Exception as e:                     # a crashed checker FAILS
            violations = [f"{name}: checker crashed: {type(e).__name__}: {e}"]
        status = "PASS" if not violations else f"FAIL ({len(violations)})"
        print(f"[{name:16s}] {status}")
        for v in violations:
            print(f"  - {v}")
        n_violations += len(violations)
    if n_violations:
        print(f"\nstatic analysis: {n_violations} violation(s)")
        return 1
    print("\nstatic analysis: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
