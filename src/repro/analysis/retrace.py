"""Retrace sentinel: a reusable trace-once (trace-at-most-N) guard for jitted
functions.

The repo's hot paths are built so batch/schedule churn never changes a traced
shape: the serve plane decodes over a fixed slot plane, and the segment
runner dispatches descending power-of-two chunks so its compiled-program set
is bounded by log2(max_segment). Those are *invariants*, and before this
module each was asserted ad hoc (a private ``_cache_size`` probe inside
``ServeEngine``, nothing at all on ``SegmentRunner``). ``RetraceSentinel``
is the one shared guard: wrap the jitted function, declare the trace budget,
and any recompile beyond it fails LOUDLY at the call that caused it —
instead of silently costing wall-clock for the rest of the run.

Usage::

    fn = RetraceSentinel(jax.jit(step), name="serve.decode")        # once
    run = RetraceSentinel(jax.jit(seg), name="trainer.segment_scan",
                          max_traces=max_segment.bit_length())      # 2^k set

The sentinel is transparent: calls pass through, and the wrapped jitted
function stays reachable as ``.fn`` (the jaxpr auditor lowers/traces through
it). ``trace_count`` exposes the live compiled-trace count for tests and
benchmark gates.
"""
from __future__ import annotations

from typing import Any, Callable


class RetraceError(RuntimeError):
    """A guarded jitted function compiled more distinct traces than its
    declared budget — some input's shape/dtype/static-arg churned."""


class RetraceSentinel:
    """Wrap a ``jax.jit``-compiled callable and enforce a trace budget.

    Parameters
    ----------
    fn:         the jitted function (must expose ``_cache_size`` — i.e. the
                object returned by ``jax.jit``, not a plain Python function).
    name:       label used in the violation message ("serve.decode").
    max_traces: largest allowed number of distinct compiled traces. 1 = the
                strict trace-once contract; the segment runner declares
                ``max_segment.bit_length()`` (one per power-of-two chunk).
    """

    def __init__(self, fn: Callable[..., Any], *, name: str,
                 max_traces: int = 1):
        if not hasattr(fn, "_cache_size"):
            raise TypeError(
                f"RetraceSentinel({name!r}) needs a jax.jit-compiled "
                f"function (got {type(fn).__name__} with no _cache_size)")
        if max_traces < 1:
            raise ValueError(f"max_traces must be >= 1, got {max_traces}")
        self.fn = fn
        self.name = name
        self.max_traces = int(max_traces)

    @property
    def trace_count(self) -> int:
        """Number of distinct traces compiled so far."""
        return self.fn._cache_size()

    def check(self) -> None:
        """Raise RetraceError if the budget is exceeded."""
        n = self.trace_count
        if n > self.max_traces:
            raise RetraceError(
                f"{self.name}: {n} distinct traces compiled, declared budget "
                f"is {self.max_traces} — an input's shape/dtype/static arg "
                f"is churning (each retrace recompiles and silently costs "
                f"wall-clock)")

    def __call__(self, *args, **kwargs):
        out = self.fn(*args, **kwargs)
        self.check()
        return out
