"""Static analysis over the jitted protocol plane.

Three passes, one entrypoint (`python -m repro.analysis`):

  * `jaxpr_audit` — walk the traced programs of the engine transitions, the
    segment scan, and the serve steps; enforce the dispatch/donation/
    banned-primitive budgets declared in `budgets`;
  * `kernel_lint` — AST contract over every `kernels/<family>/` package
    (pure-jnp ref.py, impl="auto" ops.py, lane-aligned BlockSpecs, VMEM
    budget) plus the repo purity lint;
  * `retrace`     — the reusable trace-once sentinel (used live by
    `SegmentRunner` and `ServeEngine`, not just at audit time).

This package __init__ re-exports ONLY the retrace sentinel: `core.trainer`
and `serve.engine` import it at module load, so pulling the audit machinery
(which imports them back) in here would cycle. Import `repro.analysis.
jaxpr_audit` / `repro.analysis.kernel_lint` / `repro.analysis.budgets`
directly for the checkers.
"""
from repro.analysis.retrace import RetraceError, RetraceSentinel

__all__ = ["RetraceError", "RetraceSentinel"]
