"""Kernel-contract linter: AST checks over `src/repro/kernels/<family>/` plus
the repo-wide purity lint.

Every kernel family ships three layers, and this module makes the layering a
machine-checked contract instead of a convention:

  * ``ref.py``  — the pure-jnp oracle. MUST NOT import Pallas or touch `pl.`/
    `pltpu` — the oracle is the semantics, and it has to run anywhere.
  * ``ops.py``  — the public policy layer. MUST expose an ``impl="auto"``
    dial (ref oracle | Pallas kernel, auto-resolved per backend) and gate the
    kernel through the shared ``is_cpu()`` interpret fallback.
  * ``<family>.py`` — the ``pl.pallas_call`` kernels. Every BlockSpec tile's
    LAST dim must be lane-aligned (% 128 — the TPU vector lane width, see the
    accelerator guide), and the per-kernel VMEM footprint estimate (sum of
    each distinct BlockSpec tile constructed in the function, at f32) must
    stay under the family's declared budget.

Tile dims that are not literals resolve through (1) module-level integer
constants (``LANES = 1024``), then (2) the family's declared ``dim_bounds``
in ``analysis/budgets.py`` — a runtime-sized dim with no declared bound is a
violation, and declaring the bound is the documented path for new kernels.
``None`` dims (squeezed axes) count as 1.

The purity lint walks all of ``src/repro``: no unseeded ``np.random`` module
calls (seeded ``RandomState(seed)``/``default_rng(seed)`` constructors are
fine), and no wall-clock imports (`time`/`datetime`) inside ``core/`` —
simulated time is the trainer's clock, and a wall-clock read inside the
protocol core would silently break resume determinism.

All functions return violation-message lists (empty == clean).
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional

from repro.analysis.budgets import KERNEL_CONTRACTS, KernelContract

REPO_SRC = pathlib.Path(__file__).resolve().parents[1]      # src/repro
KERNELS_DIR = REPO_SRC / "kernels"

# np.random module-level *stateful* functions (global-RNG mutation)
_STATEFUL_NP_RANDOM = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "normal", "uniform", "choice", "shuffle", "permutation",
    "standard_normal", "beta", "binomial", "poisson", "exponential",
    "bytes", "gamma",
})
# constructors that are fine WHEN SEEDED (>= 1 argument)
_SEEDED_NP_CTORS = frozenset({"RandomState", "default_rng", "Generator",
                              "PCG64"})


def _parse(path: pathlib.Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


# ---------------------------------------------------------------------------
# dim resolution
# ---------------------------------------------------------------------------


def _module_int_constants(tree: ast.Module) -> Dict[str, int]:
    consts: Dict[str, int] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            try:
                val = ast.literal_eval(node.value)
            except (ValueError, TypeError, SyntaxError):
                continue
            if isinstance(val, int) and not isinstance(val, bool):
                consts[node.targets[0].id] = val
    return consts


def _resolve_dim(node: ast.expr, consts: Dict[str, int],
                 bounds) -> Optional[int]:
    """Static value (or declared upper bound) for one BlockSpec tile dim;
    None if unresolvable. `None` literals (squeezed dims) resolve to 1."""
    if isinstance(node, ast.Constant):
        if node.value is None:
            return 1
        if isinstance(node.value, int) and not isinstance(node.value, bool):
            return node.value
        return None
    if isinstance(node, ast.Name):
        if node.id in consts:
            return consts[node.id]
        return bounds.get(node.id)
    if isinstance(node, ast.BinOp):
        lo = _resolve_dim(node.left, consts, bounds)
        ro = _resolve_dim(node.right, consts, bounds)
        if lo is None or ro is None:
            return None
        if isinstance(node.op, ast.Mult):
            return lo * ro
        if isinstance(node.op, ast.Add):
            return lo + ro
        if isinstance(node.op, ast.Sub):
            return lo - ro
        if isinstance(node.op, ast.FloorDiv) and ro:
            return lo // ro
        return None
    return None


def _dim_repr(node: ast.expr) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return "<dim>"


def _iter_blockspecs(root: ast.AST):
    """Yield every `pl.BlockSpec((...), ...)` call carrying a tuple block
    shape (memory-space-only specs — SMEM scalar refs — have none)."""
    for node in ast.walk(root):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if name != "BlockSpec":
            continue
        shape = node.args[0] if node.args else None
        if shape is None:
            for kw in node.keywords:
                if kw.arg == "block_shape":
                    shape = kw.value
        if isinstance(shape, ast.Tuple):
            yield node, shape


# ---------------------------------------------------------------------------
# per-family checks
# ---------------------------------------------------------------------------


def _lint_ref_purity(path: pathlib.Path) -> List[str]:
    """ref.py must be pure jnp: no pallas imports, no `pl`/`pltpu` usage."""
    out: List[str] = []
    tree = _parse(path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if "pallas" in a.name:
                    out.append(f"{path.name}: imports `{a.name}` — the ref "
                               f"oracle must stay pure jnp")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            names = ", ".join(a.name for a in node.names)
            if "pallas" in mod or "pallas" in names:
                out.append(f"{path.name}: `from {mod} import {names}` — the "
                           f"ref oracle must stay pure jnp")
        elif isinstance(node, ast.Name) and node.id in ("pl", "pltpu"):
            out.append(f"{path.name}: references `{node.id}` — the ref "
                       f"oracle must stay pure jnp")
    return out


def _lint_ops_contract(path: pathlib.Path) -> List[str]:
    """ops.py must expose impl="auto" and the is_cpu interpret fallback."""
    out: List[str] = []
    tree = _parse(path)
    has_impl_auto = False
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name.startswith("_"):
            continue
        a = node.args
        params = a.args + a.kwonlyargs
        defaults = ([None] * (len(a.args) - len(a.defaults))
                    + list(a.defaults) + list(a.kw_defaults))
        for p, d in zip(params, defaults):
            if (p.arg == "impl" and isinstance(d, ast.Constant)
                    and d.value == "auto"):
                has_impl_auto = True
    if not has_impl_auto:
        out.append(f"{path.name}: no public function takes impl=\"auto\" — "
                   f"every kernel family must expose the ref|pallas|auto "
                   f"dial (auto = oracle/interpret on CPU, kernel on "
                   f"accelerators)")
    src_names = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    imported = {a.asname or a.name for node in ast.walk(tree)
                if isinstance(node, ast.ImportFrom)
                for a in node.names}
    if not ({"is_cpu", "_is_cpu"} & (src_names | imported)):
        out.append(f"{path.name}: does not reference `is_cpu` — the "
                   f"interpret-on-CPU fallback (repro.kernels.is_cpu) is "
                   f"part of the ops contract")
    ref_imported = any(
        isinstance(node, ast.ImportFrom)
        and ((node.module or "").endswith("ref")
             or any(a.name == "ref" or a.name.endswith("_ref")
                    or "ref" == a.name for a in node.names)
             or any(a.name == "ref" for a in node.names))
        for node in ast.walk(tree))
    if not ref_imported:
        out.append(f"{path.name}: never imports the family's ref oracle — "
                   f"the impl=\"ref\" escape hatch must route to ref.py")
    return out


def _lint_blockspecs(path: pathlib.Path,
                     contract: KernelContract) -> List[str]:
    """Lane alignment + per-function VMEM footprint over one kernel module."""
    out: List[str] = []
    tree = _parse(path)
    consts = _module_int_constants(tree)
    bounds = dict(contract.dim_bounds)
    groups = [(f"{path.name}::{n.name}", n) for n in tree.body
              if isinstance(n, ast.FunctionDef)]
    groups.append((f"{path.name}::<module>", tree))
    seen = set()
    for label, scope in groups:
        vmem = 0
        for call, shape in _iter_blockspecs(scope):
            if id(call) in seen:
                continue
            seen.add(id(call))
            dims = [(_resolve_dim(d, consts, bounds), _dim_repr(d))
                    for d in shape.elts]
            for val, rep in dims:
                if val is None:
                    out.append(
                        f"{label}: BlockSpec dim `{rep}` is not statically "
                        f"resolvable — declare its bound in analysis/"
                        f"budgets.py KERNEL_CONTRACTS[...].dim_bounds")
            if dims and dims[-1][0] is not None and dims[-1][0] % 128 != 0:
                out.append(
                    f"{label}: BlockSpec last dim `{dims[-1][1]}` = "
                    f"{dims[-1][0]} is not lane-aligned (% 128 != 0) — "
                    f"unaligned tiles pad every VMEM transfer on TPU")
            if all(v is not None for v, _ in dims):
                tile = 1
                for v, _ in dims:
                    tile *= v
                vmem += tile * contract.dtype_bytes
        if vmem > contract.vmem_budget_bytes:
            out.append(
                f"{label}: estimated VMEM footprint {vmem} B exceeds the "
                f"family budget {contract.vmem_budget_bytes} B "
                f"(analysis/budgets.py) — shrink the tiles or justify a "
                f"bigger declared budget")
    return out


def lint_kernel_family(family_dir: pathlib.Path,
                       contract: KernelContract) -> List[str]:
    """Run the full contract on one `kernels/<family>/` package."""
    out: List[str] = []
    fam = family_dir.name
    ref = family_dir / "ref.py"
    ops = family_dir / "ops.py"
    if not ref.exists():
        out.append(f"{fam}: missing ref.py — every kernel family ships a "
                   f"pure-jnp oracle")
    else:
        out.extend(f"{fam}/{v}" for v in _lint_ref_purity(ref))
    if not ops.exists():
        out.append(f"{fam}: missing ops.py — every kernel family ships the "
                   f"public impl-policy wrapper")
    else:
        out.extend(f"{fam}/{v}" for v in _lint_ops_contract(ops))
    for mod in sorted(family_dir.glob("*.py")):
        if mod.name in ("ref.py", "ops.py", "__init__.py"):
            continue
        out.extend(f"{fam}/{v}" for v in _lint_blockspecs(mod, contract))
    return out


def run_kernel_lint(kernels_dir: pathlib.Path = KERNELS_DIR) -> List[str]:
    """Lint every family package; also the coverage contract both ways
    (a family without a declared KernelContract is a violation, as is a
    stale contract for a family that no longer exists)."""
    out: List[str] = []
    families = sorted(p.name for p in kernels_dir.iterdir()
                      if p.is_dir() and (p / "__init__.py").exists())
    for fam in families:
        contract = KERNEL_CONTRACTS.get(fam)
        if contract is None:
            out.append(f"{fam}: no KernelContract declared — add the family "
                       f"to analysis/budgets.py KERNEL_CONTRACTS (dim "
                       f"bounds + VMEM budget)")
            continue
        out.extend(lint_kernel_family(kernels_dir / fam, contract))
    for fam in sorted(KERNEL_CONTRACTS):
        if fam not in families:
            out.append(f"{fam}: KernelContract declared but no such family "
                       f"under kernels/ — remove the stale entry")
    return out


# ---------------------------------------------------------------------------
# purity lint (repo-wide)
# ---------------------------------------------------------------------------


def _lint_np_random(path: pathlib.Path, tree: ast.Module) -> List[str]:
    out: List[str] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Attribute)
                and isinstance(fn.value.value, ast.Name)
                and fn.value.value.id in ("np", "numpy")
                and fn.value.attr == "random"):
            continue
        if fn.attr in _STATEFUL_NP_RANDOM:
            out.append(
                f"{path}: `np.random.{fn.attr}(...)` uses the unseeded "
                f"global RNG — thread a seeded RandomState/default_rng "
                f"instead (determinism is what makes resume/CI gates exact)")
        elif fn.attr in _SEEDED_NP_CTORS and not (node.args or node.keywords):
            out.append(
                f"{path}: `np.random.{fn.attr}()` constructed without a "
                f"seed — pass one explicitly")
    return out


def _lint_wall_clock(path: pathlib.Path, tree: ast.Module) -> List[str]:
    out: List[str] = []
    for node in ast.walk(tree):
        mods = []
        if isinstance(node, ast.Import):
            mods = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            mods = [node.module or ""]
        for m in mods:
            if m.split(".")[0] in ("time", "datetime"):
                out.append(
                    f"{path}: imports `{m}` inside core/ — the protocol "
                    f"core runs on the simulated clock; a wall-clock read "
                    f"here would break deterministic resume")
    return out


def lint_purity(root: pathlib.Path = REPO_SRC) -> List[str]:
    out: List[str] = []
    for path in sorted(root.rglob("*.py")):
        tree = _parse(path)
        rel = path.relative_to(root.parent)
        out.extend(_lint_np_random(rel, tree))
        if (root / "core") in path.parents:
            out.extend(_lint_wall_clock(rel, tree))
    return out
