"""Jaxpr auditor: machine-checked invariants over the TRACED programs of the
jitted protocol plane.

The repo's hot-path guarantees are properties of lowered computations, not of
Python source — "the fused deliver is exactly two Pallas dispatches", "no host
callback ever rides inside a jitted transition", "buffers declared donated
really alias their outputs". This module walks the closed jaxprs of the engine
transitions (`initiate`/`deliver`/`diloco_round`, per-leaf and fused), the
segment scan, and the serve decode/prefill steps, and enforces the declarative
registry in `analysis/budgets.py`:

  * ``check_pallas_budget``     — exact ``pallas_call`` dispatch counts
  * ``check_banned_primitives`` — no host callbacks / debug prints / infeed
  * ``check_no_f64``            — no float64 widening inside jitted programs
  * ``check_donation``          — declared donations appear in the lowering
    (counted as ``tf.aliasing_output`` / ``jax.buffer_donor`` attributes; one
    per donated pytree leaf)

`iter_subjaxprs`/`count_pallas_calls` are THE canonical jaxpr walker (hoisted
from tests/test_outer_update.py — the test now imports from here).

Checks raise :class:`AuditError`; the ``audit_*`` drivers collect violations
into plain string lists so `python -m repro.analysis` can report everything at
once. Drivers import the engine/trainer/serve modules lazily — `analysis` is
imported BY `core.trainer` and `serve.engine` (for the retrace sentinel), so
eager imports here would cycle.
"""
from __future__ import annotations

import re
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.analysis import budgets as budgets_lib

# the tiny dense config every audit traces against (mirrors the test fixture:
# small enough that a full budget-table sweep is CI-cheap, deep enough that a
# per-leaf-vs-fused dispatch regression is visible)
_TINY_KW = dict(name="audit-tiny", family="dense", n_layers=4, d_model=64,
                n_heads=2, n_kv_heads=1, d_ff=128, vocab=128,
                compute_dtype="float32")


class AuditError(AssertionError):
    """A traced program violates a declared budget/contract."""


# ---------------------------------------------------------------------------
# the canonical jaxpr walker
# ---------------------------------------------------------------------------


def iter_subjaxprs(val):
    """Yield every (sub)jaxpr reachable from an eqn-params value: ClosedJaxpr
    (`.jaxpr`), bare Jaxpr (`.eqns`), and tuples/lists of either."""
    if hasattr(val, "jaxpr"):                      # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):                     # Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from iter_subjaxprs(v)


def count_pallas_calls(jaxpr) -> int:
    """Total `pallas_call` eqns in `jaxpr`, recursing into every subjaxpr
    (pjit bodies, scan/while/cond branches, custom_vjp closures, ...)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in iter_subjaxprs(v):
                n += count_pallas_calls(sub)
    return n


def iter_eqns(jaxpr):
    """Depth-first over every eqn in `jaxpr` and all nested subjaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in iter_subjaxprs(v):
                yield from iter_eqns(sub)


# ---------------------------------------------------------------------------
# checks (raise AuditError)
# ---------------------------------------------------------------------------


def check_pallas_budget(jaxpr, expected: int, label: str) -> None:
    got = count_pallas_calls(jaxpr)
    if got != expected:
        raise AuditError(
            f"{label}: {got} pallas_call dispatches in the traced program, "
            f"budget declares exactly {expected} (analysis/budgets.py)")


def check_banned_primitives(jaxpr, label: str,
                            banned=budgets_lib.BANNED_PRIMITIVES) -> None:
    hits = sorted({e.primitive.name for e in iter_eqns(jaxpr)
                   if e.primitive.name in banned})
    if hits:
        raise AuditError(
            f"{label}: banned primitive(s) {hits} inside a jitted "
            f"protocol-plane program (host callbacks/debug prints stall the "
            f"device pipeline)")


def check_no_f64(jaxpr, label: str) -> None:
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            dt = getattr(getattr(var, "aval", None), "dtype", None)
            if dt is not None and dt == jnp.dtype("float64"):
                raise AuditError(
                    f"{label}: float64 value produced by `{eqn.primitive.name}`"
                    f" — the protocol plane is f32/bf16 only (f64 halves "
                    f"accelerator throughput and doubles wire bytes)")


def count_donation_annotations(lowered_text: str) -> int:
    """Donated-buffer annotations in StableHLO text: `tf.aliasing_output`
    (input aliases an output buffer) plus `jax.buffer_donor` (donated but
    matched to no output — still released). One per donated pytree leaf
    that survives into the lowered computation."""
    return (lowered_text.count("tf.aliasing_output")
            + lowered_text.count("jax.buffer_donor"))


def count_lowered_args(lowered_text: str) -> int:
    """Number of parameters of the lowered module's public entry function."""
    m = re.search(r"func\.func public @\w+\((.*?)\)(?: ->|\s*\{)",
                  lowered_text, re.S)
    if not m:
        return 0
    return len(re.findall(r"%arg\d+:", m.group(1)))


def check_donation(lowered_text: str, expected_leaves: int, label: str,
                   total_input_leaves: Optional[int] = None) -> None:
    """Every donated leaf must carry an aliasing annotation — up to the
    leaves jit legitimately removes from the computation (unused args are
    dropped, untouched inputs are forwarded straight to outputs; both lose
    their annotation). `total_input_leaves` (all args, donated or not)
    bounds that allowance: annotations must land in
    [expected - dropped, expected], and never 0 while leaves are declared."""
    got = count_donation_annotations(lowered_text)
    dropped = 0
    if total_input_leaves is not None:
        dropped = max(0, total_input_leaves - count_lowered_args(lowered_text))
    lo = max(min(1, expected_leaves), expected_leaves - dropped)
    if not (lo <= got <= expected_leaves):
        raise AuditError(
            f"{label}: {got} donated-buffer annotations in the lowered "
            f"computation, declared donation covers {expected_leaves} pytree "
            f"leaves ({dropped} inputs dropped/forwarded by jit) — the "
            f"donate_argnums wiring regressed or a donated buffer silently "
            f"stopped aliasing its output")


def _collect(errors: List[str], fn: Callable[[], None]) -> None:
    try:
        fn()
    except AuditError as e:
        errors.append(str(e))


# ---------------------------------------------------------------------------
# shared tiny fixtures (lazy model/engine imports)
# ---------------------------------------------------------------------------


def _tiny_model():
    from repro.configs.base import ModelConfig
    return ModelConfig(**_TINY_KW)


def _tiny_stack(mcfg, M: int = 2):
    from repro.models import api
    params = api.init_params(mcfg, jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)


def _engine_setup(fused: bool):
    from repro.configs.base import CoCoDCConfig
    from repro.core.fragments import make_fragmenter
    mcfg = _tiny_model()
    stack = _tiny_stack(mcfg, M=2)
    ccfg = CoCoDCConfig(num_workers=2, local_steps=10, num_fragments=2,
                        overlap_depth=2, fused_updates=fused)
    shape = jax.eval_shape(lambda: jax.tree.map(lambda a: a[0], stack))
    frag = make_fragmenter(mcfg, shape, ccfg.num_fragments)
    return ccfg, frag, stack


def _trace_transition(fns, state, stack, transition: str):
    if transition == "initiate":
        fn = lambda st, s: fns.initiate(st, 3, s, 0)          # noqa: E731
    elif transition == "deliver":
        fn = lambda st, s: fns.deliver(st, 5, s, 0)           # noqa: E731
    elif transition == "diloco_round":
        fn = fns.diloco_round
    else:
        raise ValueError(f"unknown transition {transition!r}")
    return jax.make_jaxpr(fn)(state, stack).jaxpr


# ---------------------------------------------------------------------------
# audit drivers
# ---------------------------------------------------------------------------


def audit_engine(budgets: Optional[Dict] = None) -> List[str]:
    """Trace every budgeted engine transition and enforce dispatch counts,
    the banned-primitive list, and the no-f64 rule. Also the method-coverage
    contract: every registered sync method must declare at least one dispatch
    budget (ROADMAP item-1 authors: `register_dispatch_budget`)."""
    from repro.core import engine_state as es
    from repro.core.methods import registered_methods
    if budgets is None:
        budgets = budgets_lib.ENGINE_DISPATCH_BUDGETS
    errors: List[str] = []
    covered = {m for (m, _, _) in budgets}
    for m in registered_methods():
        if m not in covered:
            errors.append(
                f"engine: method {m!r} is registered but declares no "
                f"dispatch budget — add rows via analysis.budgets."
                f"register_dispatch_budget so its traced transitions are "
                f"audited")
    for (method, fused, impl_mode), budget in sorted(budgets.items()):
        ccfg, frag, stack = _engine_setup(fused)
        kw = ({"fused_impl": impl_mode} if fused
              else {"dc_impl": impl_mode})
        fns = es.make_engine_fns(method, ccfg, frag, use_jit=True, **kw)
        state = es.init_state(method, ccfg, stack, frag=frag)
        n_leaves = len(frag.flat._by_path[0])     # fragment 0's leaf count
        for transition, want in sorted(budget.items()):
            label = (f"engine[{method} fused={fused} impl={impl_mode}]"
                     f".{transition}")
            expected = n_leaves if want is budgets_lib.LEAVES else want
            jaxpr = _trace_transition(fns, state, stack, transition)
            _collect(errors,
                     lambda j=jaxpr, e=expected, l=label:
                     check_pallas_budget(j, e, l))
            _collect(errors,
                     lambda j=jaxpr, l=label: check_banned_primitives(j, l))
            _collect(errors, lambda j=jaxpr, l=label: check_no_f64(j, l))
    return errors


def _segment_fixture(*, donate=None, max_segment: int = 8):
    """A real SegmentRunner over the tiny dense model — the same single_step
    shape the trainer builds (loss + AdamW), sized for tracing."""
    from repro.core.trainer import SegmentRunner
    from repro.models import api
    from repro.optim import adamw_init, adamw_update
    mcfg = _tiny_model()
    stack = _tiny_stack(mcfg, M=2)
    opt = jax.vmap(adamw_init)(stack)

    def single_step(params, opt_state, batch, lr):
        (loss, _), grads = jax.value_and_grad(
            lambda p: api.loss_fn(mcfg, p, batch), has_aux=True)(params)
        params, opt_state = adamw_update(grads, opt_state, params, lr,
                                         weight_decay=0.1)
        return params, opt_state, loss

    runner = SegmentRunner(single_step, max_segment=max_segment,
                           donate=donate)
    batch_seg = {"tokens": jnp.zeros((4, 2, 2, 8), jnp.int32),
                 "labels": jnp.zeros((4, 2, 2, 8), jnp.int32)}
    lrs = jnp.full((4,), 1e-3, jnp.float32)
    return runner, stack, opt, batch_seg, lrs


def audit_segment() -> List[str]:
    """The fused inner-step scan must stay pure XLA (zero Pallas dispatches),
    callback-free, and f64-free."""
    errors: List[str] = []
    runner, stack, opt, batch_seg, lrs = _segment_fixture()
    jaxpr = jax.make_jaxpr(runner._fn.fn)(stack, opt, batch_seg, lrs).jaxpr
    label = "trainer.segment_scan"
    _collect(errors, lambda: check_pallas_budget(
        jaxpr, budgets_lib.SEGMENT_SCAN_PALLAS_CALLS, label))
    _collect(errors, lambda: check_banned_primitives(jaxpr, label))
    _collect(errors, lambda: check_no_f64(jaxpr, label))
    return errors


def _serve_engine(attn_impl: str):
    from repro.models import api
    from repro.serve.engine import ServeEngine
    mcfg = _tiny_model()
    params = api.init_params(mcfg, jax.random.PRNGKey(0))
    return ServeEngine(mcfg, params, n_slots=2, cache_len=32, max_prompt=8,
                       prefill_chunk=4, attn_impl=attn_impl)


def audit_serve() -> List[str]:
    """Serve decode/prefill steps: dispatch budgets per attn_impl (flash
    decode is ONE kernel for the whole layer scan), no callbacks, no f64."""
    errors: List[str] = []
    for attn_impl, budget in sorted(
            budgets_lib.SERVE_DISPATCH_BUDGETS.items()):
        eng = _serve_engine(attn_impl)
        traced = {
            "decode": jax.make_jaxpr(eng._decode_fn.fn)(
                eng.params, eng.state).jaxpr,
            "prefill": jax.make_jaxpr(eng._prefill_fn.fn)(
                eng.params, eng.state, 0).jaxpr,
        }
        for step, want in sorted(budget.items()):
            label = f"serve[attn_impl={attn_impl}].{step}"
            jaxpr = traced[step]
            _collect(errors, lambda j=jaxpr, w=want, l=label:
                     check_pallas_budget(j, w, l))
            _collect(errors, lambda j=jaxpr, l=label:
                     check_banned_primitives(j, l))
            _collect(errors, lambda j=jaxpr, l=label: check_no_f64(j, l))
    return errors


def audit_donation() -> List[str]:
    """Donation verification: force `donate=True` (the accelerator wiring,
    backend-independent at lower time) and require one aliasing annotation
    per pytree leaf of every arg declared donated in ENGINE_DONATION /
    SegmentRunner.DONATE_ARGNUMS."""
    from repro.core import engine_state as es
    errors: List[str] = []
    for fused in (False, True):
        ccfg, frag, stack = _engine_setup(fused)
        fns = es.make_engine_fns("cocodc", ccfg, frag, use_jit=True,
                                 donate=True)
        state = es.init_state("cocodc", ccfg, stack, frag=frag)
        args = {"initiate": (state, 3, stack, 0),
                "deliver": (state, 5, stack, 0),
                "diloco_round": (state, stack)}
        for name, argnums in sorted(es.ENGINE_DONATION.items()):
            expected = sum(len(jax.tree.leaves(args[name][i]))
                           for i in argnums)
            # static args (the fragment id p) carry no leaves
            total = len(jax.tree.leaves(args[name][:3 if name !=
                                                   "diloco_round" else 2]))
            text = getattr(fns, name).lower(*args[name]).as_text()
            _collect(errors, lambda t=text, e=expected, n=total,
                     l=f"engine[cocodc fused={fused}].{name} donation":
                     check_donation(t, e, l, n))
    runner, stack, opt, batch_seg, lrs = _segment_fixture(donate=True)
    expected = len(jax.tree.leaves(stack)) + len(jax.tree.leaves(opt))
    total = len(jax.tree.leaves((stack, opt, batch_seg, lrs)))
    text = runner._fn.fn.lower(stack, opt, batch_seg, lrs).as_text()
    _collect(errors, lambda: check_donation(
        text, expected, "trainer.segment_scan donation", total))
    return errors
