"""Mixture-of-Experts FFN with FLOP-lean gather/scatter (capacity) dispatch.

Dense one-hot dispatch einsums cost O(T·E·C·D) matmul FLOPs which would swamp the
roofline at dbrx scale; instead we sort token-expert pairs by expert, scatter into an
(E, C, D) buffer (memory ops, no FLOPs), run the per-expert SwiGLU as a batched
einsum, and scatter-add back. Dropless up to the capacity factor; overflow tokens
fall back to identity (standard Switch behaviour).

Expert weights are stacked (L, E, D, F) and sharded expert-parallel over the `model`
mesh axis (see launch/sharding.py).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array   # Switch-style load-balance loss
    overflow_frac: jax.Array


def init_moe_params(key, n_layers: int, d_model: int, d_ff: int, moe: MoEConfig, dtype):
    ks = jax.random.split(key, 4)
    E = moe.num_experts
    return {
        "router": dense_init(ks[0], (n_layers, d_model, E), jnp.float32, fan_in=d_model),
        "w_gate": dense_init(ks[1], (n_layers, E, d_model, d_ff), dtype, fan_in=d_model),
        "w_up":   dense_init(ks[2], (n_layers, E, d_model, d_ff), dtype, fan_in=d_model),
        "w_down": dense_init(ks[3], (n_layers, E, d_ff, d_model), dtype, fan_in=d_ff),
    }


def capacity(moe: MoEConfig, n_tokens: int, capacity_factor: float = 1.25) -> int:
    c = math.ceil(moe.top_k * n_tokens / moe.num_experts * capacity_factor)
    return max(8, -(-c // 8) * 8)  # >=8, multiple of 8 (TPU sublane alignment)


def moe_ffn(x, lp, moe: MoEConfig, *, capacity_factor: float = 1.25) -> MoEOut:
    """x: (B, S, D); lp: per-layer slice {router,(D,E); w_gate/w_up,(E,D,F); w_down,(E,F,D)}."""
    B, S, D = x.shape
    T = B * S
    E, K = moe.num_experts, moe.top_k
    C = capacity(moe, T, capacity_factor)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ lp["router"])                 # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                           # (T, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- sort token-expert pairs by expert id -----------------------------
    flat_e = top_e.reshape(T * K)
    sort_idx = jnp.argsort(flat_e, stable=True)                      # (T*K,)
    sorted_e = flat_e[sort_idx]
    # position within expert = rank - index of first pair with the same expert
    first_of_expert = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(T * K) - first_of_expert
    overflow = pos_in_e >= C
    slot = jnp.where(overflow, E * C, sorted_e * C + pos_in_e)       # E*C = trash slot

    token_of_pair = sort_idx // K                                    # (T*K,)
    xs = xf[token_of_pair]                                           # gather (T*K, D)
    disp = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xs)[:E * C]
    disp = disp.reshape(E, C, D)

    # ---- per-expert SwiGLU (batched over experts) -------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, lp["w_gate"],
                               preferred_element_type=jnp.float32)) * \
        jnp.einsum("ecd,edf->ecf", disp, lp["w_up"],
                   preferred_element_type=jnp.float32)
    y_exp = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), lp["w_down"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    y_exp = y_exp.reshape(E * C, D)

    # ---- combine back ------------------------------------------------------
    pair_w = top_w.reshape(T * K)[sort_idx].astype(x.dtype)          # (T*K,)
    y_pairs = jnp.where(overflow[:, None], jnp.zeros((), x.dtype),
                        y_exp[jnp.minimum(slot, E * C - 1)] * pair_w[:, None])
    y = jnp.zeros((T, D), x.dtype).at[token_of_pair].add(y_pairs).reshape(B, S, D)

    # ---- Switch load-balance loss ------------------------------------------
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs) * moe.load_balance_coef
    return MoEOut(y, aux, jnp.mean(overflow.astype(jnp.float32)))
