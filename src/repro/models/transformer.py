"""Decoder-only transformer stack: covers families dense, moe, vlm.

Layer params are stacked (leading L axis) and the stack is a single `lax.scan`
(wrapped in `jax.checkpoint` for training) so compile time and HLO size are O(1) in
depth. The same stack is reused by the enc-dec (audio) family in encdec.py.

API (shared by all families via models/api.py):
  init_params(cfg, key)                         -> params
  forward(cfg, params, batch, train)            -> (h, aux)   h: (B,S,D)
  loss_fn(cfg, params, batch)                   -> (loss, metrics)
  init_cache(cfg, params, batch_size, cache_len)-> cache
  decode_step(cfg, params, cache, tokens, pos)  -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models.layers import (apply_rope, attn_out, attn_qkv, chunked_cross_entropy,
                                 dense_init, embed_init, gqa_attention, init_attn_params,
                                 rms_norm, swiglu)
from repro.models.layers import cast_params_for_compute


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    ks = jax.random.split(key, 8)
    layers = {
        "attn": init_attn_params(ks[0], cfg, L, dtype),
        "ln1": jnp.ones((L, D), dtype),
        "ln2": jnp.ones((L, D), dtype),
    }
    if cfg.moe is not None:
        layers["moe"] = moe_lib.init_moe_params(ks[1], L, D, F, cfg.moe, dtype)
    else:
        layers["mlp"] = {
            "w_gate": dense_init(ks[2], (L, D, F), dtype, fan_in=D),
            "w_up":   dense_init(ks[3], (L, D, F), dtype, fan_in=D),
            "w_down": dense_init(ks[4], (L, F, D), dtype, fan_in=F),
        }
    params = {
        "embed": embed_init(ks[5], (V, D), dtype),
        "layers": layers,
        "final_norm": jnp.ones((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[6], (D, V), dtype, fan_in=D)
    if cfg.family == "vlm":
        P = cfg.prefix_dim
        params["projector"] = {
            "w1": dense_init(ks[7], (P, D), dtype, fan_in=P),
            "w2": dense_init(jax.random.fold_in(ks[7], 1), (D, D), dtype, fan_in=D),
        }
    return params


def lm_head_weight(cfg: ModelConfig, params):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _seq_shard(x):
    """Sequence-parallel residual constraint (beyond-paper, §Perf iteration 5):
    shard the residual stream's sequence dim over `model` so GSPMD lowers the
    TP boundary as reduce-scatter + all-gather (half the bytes of the Megatron
    all-reduce) and runs norms/elementwise sequence-sharded."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax._src import mesh as mesh_lib
    mesh = mesh_lib.thread_resources.env.physical_mesh  # the `with mesh:` ctx
    names = getattr(mesh, "axis_names", ()) or ()
    if "model" not in names or x.ndim != 3:
        return x
    size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if x.shape[1] % size != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(None, "model", None)))


def _layer(cfg: ModelConfig, x, lp, positions, window, attn_impl,
           seq_parallel=False):
    if seq_parallel:
        x = _seq_shard(x)
    h = rms_norm(x, lp["ln1"], cfg.rms_eps)
    q, k, v = attn_qkv(h, lp["attn"], cfg, positions)
    if attn_impl == "flash":
        from repro.kernels.flash_attention import ops as flash_ops
        o = flash_ops.flash_attention(q, k, v, causal=True, window=window)
    else:
        o = gqa_attention(q, k, v, causal=True, window=window,
                          q_positions=positions, kv_positions=positions)
    x = x + attn_out(o, lp["attn"], cfg)
    h = rms_norm(x, lp["ln2"], cfg.rms_eps)
    if cfg.moe is not None:
        out = moe_lib.moe_ffn(h, lp["moe"], cfg.moe)
        return x + out.y, out.aux_loss
    return x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"]), \
        jnp.zeros((), jnp.float32)


def embed_inputs(cfg: ModelConfig, params, batch):
    """Token (+ vlm prefix) embedding. Returns (x, positions, n_prefix)."""
    emb = params["embed"]
    x = emb[batch["tokens"]].astype(jnp.dtype(cfg.compute_dtype))
    n_prefix = 0
    if cfg.family == "vlm" and "prefix_emb" in batch:
        pj = params["projector"]
        pe = jax.nn.gelu(batch["prefix_emb"].astype(pj["w1"].dtype) @ pj["w1"]) @ pj["w2"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        n_prefix = pe.shape[1]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions, n_prefix


def forward(cfg: ModelConfig, params, batch, *, train: bool = True,
            attn_impl: str = "ref", remat: bool = True, unroll: bool = False,
            seq_parallel: bool = False):
    params = cast_params_for_compute(cfg, params)
    x, positions, n_prefix = embed_inputs(cfg, params, batch)
    window = cfg.attn_window

    def body(carry, lp):
        x = carry
        y, aux = _layer(cfg, x, lp, positions, window, attn_impl,
                        seq_parallel=seq_parallel)
        return y, aux

    if unroll:  # roofline probes: loop bodies visible to HLO cost analysis
        auxs = []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            x, aux = body(x, lp)
            auxs.append(aux)
        auxs = jnp.stack(auxs)
    else:
        body_fn = jax.checkpoint(body) if (train and remat) else body
        x, auxs = jax.lax.scan(body_fn, x, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return h, {"moe_aux": jnp.sum(auxs), "n_prefix": n_prefix}


def loss_fn(cfg: ModelConfig, params, batch, *, attn_impl: str = "ref",
            remat: bool = True, xent_chunk: int = 512, unroll: bool = False,
            seq_parallel: bool = False):
    h, aux = forward(cfg, params, batch, train=True, attn_impl=attn_impl, remat=remat,
                     unroll=unroll, seq_parallel=seq_parallel)
    n_prefix = aux["n_prefix"]
    if n_prefix:
        h = h[:, n_prefix:]
    nll = chunked_cross_entropy(h, lm_head_weight(cfg, params), batch["labels"],
                                chunk=xent_chunk)
    loss = nll + aux["moe_aux"]
    return loss, {"nll": nll, "moe_aux": aux["moe_aux"], "ppl": jnp.exp(nll)}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch_size, cache_len, cfg.n_kv_heads, hd)
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "kv_pos": jnp.full((cache_len,), -1, jnp.int32),  # ring-buffer slot -> position
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, *,
                window: Optional[int] = None, attn_impl: str = "ref",
                unroll: bool = False):
    """One-token decode. tokens: (B,) int32. Window falls back to the arch's native
    window; pass cfg.long_decode_window for the long_500k variant."""
    window = window if window is not None else cfg.attn_window
    params = cast_params_for_compute(cfg, params)
    pos = cache["pos"]
    C = cache["k"].shape[2]
    slot = pos % C
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    kv_pos = cache["kv_pos"].at[slot].set(pos)
    kv_positions = jnp.broadcast_to(kv_pos[None], (B, C))
    kv_mask = kv_positions >= 0

    def body(x, xs):
        lp, kc, vc = xs
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg, positions)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        if attn_impl == "flash":
            from repro.kernels.flash_decode import ops as fd_ops
            o = fd_ops.flash_decode(q[:, 0], kc, vc, kv_pos, pos,
                                    window=window)[:, None]
        else:
            o = gqa_attention(q, kc, vc, causal=True, window=window,
                              q_positions=positions, kv_positions=kv_positions,
                              kv_mask=kv_mask)
        x = x + attn_out(o, lp["attn"], cfg)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        if cfg.moe is not None:
            x = x + moe_lib.moe_ffn(h, lp["moe"], cfg.moe).y
        else:
            x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
        return x, (kc, vc)

    if unroll:
        ks_l, vs_l = [], []
        for l in range(cfg.n_layers):
            xs_l = jax.tree.map(lambda a: a[l],
                                (params["layers"], cache["k"], cache["v"]))
            x, (kc, vc) = body(x, xs_l)
            ks_l.append(kc)
            vs_l.append(vc)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    else:
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["layers"], cache["k"], cache["v"]))
    h = rms_norm(x[:, 0], params["final_norm"], cfg.rms_eps)
    logits = h.astype(jnp.float32) @ lm_head_weight(cfg, params).astype(jnp.float32)
    new_cache = {"k": ks, "v": vs, "kv_pos": kv_pos, "pos": pos + 1}
    return logits, new_cache


def init_slot_cache(cfg: ModelConfig, n_slots: int, cache_len: int):
    """Slot-plane KV cache for continuous-batching serving: unlike `init_cache`
    (one shared position map + scalar clock for a lock-step batch), every slot
    carries its OWN ring-buffer position map and decode position, so the plane
    can hold requests at arbitrary, independent depths."""
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, n_slots, cache_len, cfg.n_kv_heads, hd)
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "kv_pos": jnp.full((n_slots, cache_len), -1, jnp.int32),
        "pos": jnp.zeros((n_slots,), jnp.int32),
    }


def decode_step_slotted(cfg: ModelConfig, params, cache, tokens, *, active,
                        window: Optional[int] = None, attn_impl: str = "ref"):
    """One decode step over the whole slot plane. tokens: (B,) int32 (last
    sampled token per slot); active: (B,) bool. Inactive slots are computed
    (the traced shapes never change with batch composition) but neither write
    their cache row nor advance their position — their writes land on a
    deliberately out-of-bounds column and are dropped."""
    window = window if window is not None else cfg.attn_window
    params = cast_params_for_compute(cfg, params)
    pos = cache["pos"]                                  # (B,)
    C = cache["k"].shape[2]
    B = tokens.shape[0]
    bidx = jnp.arange(B)
    slot = jnp.where(active, pos % C, C)                # C -> dropped scatter
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    positions = pos[:, None]                            # (B, 1)
    kv_pos = cache["kv_pos"].at[bidx, slot].set(pos, mode="drop")
    kv_mask = kv_pos >= 0

    def body(x, xs):
        lp, kc, vc = xs
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg, positions)
        kc = kc.at[bidx, slot].set(k[:, 0], mode="drop")
        vc = vc.at[bidx, slot].set(v[:, 0], mode="drop")
        if attn_impl == "flash":
            from repro.kernels.flash_decode import ops as fd_ops
            o = fd_ops.flash_decode(q[:, 0], kc, vc, kv_pos, pos,
                                    window=window)[:, None]
        else:
            o = gqa_attention(q, kc, vc, causal=True, window=window,
                              q_positions=positions, kv_positions=kv_pos,
                              kv_mask=kv_mask)
        x = x + attn_out(o, lp["attn"], cfg)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        if cfg.moe is not None:
            x = x + moe_lib.moe_ffn(h, lp["moe"], cfg.moe).y
        else:
            x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x,
                               (params["layers"], cache["k"], cache["v"]))
    h = rms_norm(x[:, 0], params["final_norm"], cfg.rms_eps)
    logits = h.astype(jnp.float32) @ lm_head_weight(cfg, params).astype(jnp.float32)
    new_cache = {"k": ks, "v": vs, "kv_pos": kv_pos,
                 "pos": pos + active.astype(jnp.int32)}
    return logits, new_cache


def prefill_chunk_slotted(cfg: ModelConfig, params, cache, tokens, slot, start,
                          n_valid, *, window: Optional[int] = None):
    """Prefill ONE fixed-size chunk of ONE slot's prompt into the slot plane.

    tokens: (Pc,) int32 (entries past n_valid ignored); slot/start/n_valid:
    traced scalars (so admission order never retraces). Writes the chunk's K/V
    into the slot's cache row at ring positions start..start+n_valid-1, sets
    cache['pos'][slot] = start + n_valid, and returns (last_logits, cache)
    where last_logits (V,) are the logits at the chunk's last valid token —
    the first-token sampling point when the chunk completes the prompt."""
    window = window if window is not None else cfg.attn_window
    params = cast_params_for_compute(cfg, params)
    C = cache["k"].shape[2]
    Pc = tokens.shape[0]
    ar = jnp.arange(Pc, dtype=jnp.int32)
    positions = (start + ar)[None]                      # (1, Pc)
    valid = ar < n_valid
    widx = jnp.where(valid, (start + ar) % C, C)        # C -> dropped scatter
    x = params["embed"][tokens][None].astype(jnp.dtype(cfg.compute_dtype))
    kv_row = jax.lax.dynamic_slice_in_dim(cache["kv_pos"], slot, 1, axis=0)
    kv_row = kv_row[0].at[widx].set(start + ar, mode="drop")[None]  # (1, C)
    kv_mask = kv_row >= 0

    k_rows = jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, axis=1)
    v_rows = jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, axis=1)

    def body(x, xs):
        lp, kc, vc = xs                                 # kc/vc: (1, C, KV, hd)
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg, positions)
        kc = kc[0].at[widx].set(k[0], mode="drop")[None]
        vc = vc[0].at[widx].set(v[0], mode="drop")[None]
        # chunk queries attend over the updated row: earlier cache content plus
        # the in-chunk prefix, both selected by position (kp <= qp)
        o = gqa_attention(q, kc, vc, causal=True, window=window,
                          q_positions=positions, kv_positions=kv_row,
                          kv_mask=kv_mask)
        x = x + attn_out(o, lp["attn"], cfg)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        if cfg.moe is not None:
            x = x + moe_lib.moe_ffn(h, lp["moe"], cfg.moe).y
        else:
            x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], k_rows, v_rows))
    last = jnp.clip(n_valid - 1, 0, Pc - 1)
    h_last = jax.lax.dynamic_slice_in_dim(x[0], last, 1, axis=0)[0]  # (D,)
    h_last = rms_norm(h_last, params["final_norm"], cfg.rms_eps)
    logits = h_last.astype(jnp.float32) @ lm_head_weight(cfg, params).astype(
        jnp.float32)
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, slot, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, slot, axis=1),
        "kv_pos": jax.lax.dynamic_update_slice_in_dim(cache["kv_pos"], kv_row,
                                                      slot, axis=0),
        "pos": cache["pos"].at[slot].set(start + n_valid),
    }
    return logits, new_cache


def prefill(cfg: ModelConfig, params, batch, *, cache_len: Optional[int] = None):
    """Run the prompt through the stack, returning (last-token logits, cache).
    Requires cache_len >= prompt length (no ring wrap during prefill)."""
    params = cast_params_for_compute(cfg, params)
    x, positions, n_prefix = embed_inputs(cfg, params, batch)
    B, S = x.shape[:2]
    C = cache_len or S
    assert C >= S, "prefill requires cache_len >= prompt length"
    window = cfg.attn_window

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg, positions)
        o = gqa_attention(q, k, v, causal=True, window=window,
                          q_positions=positions, kv_positions=positions)
        x = x + attn_out(o, lp["attn"], cfg)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        if cfg.moe is not None:
            x = x + moe_lib.moe_ffn(h, lp["moe"], cfg.moe).y
        else:
            x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(x[:, -1], params["final_norm"], cfg.rms_eps)
    logits = h.astype(jnp.float32) @ lm_head_weight(cfg, params).astype(jnp.float32)
    pad = C - S
    hd = cfg.resolved_head_dim
    kc = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kv_pos = jnp.where(jnp.arange(C) < S, jnp.arange(C), -1).astype(jnp.int32)
    cache = {"k": kc, "v": vc, "kv_pos": kv_pos, "pos": jnp.array(S, jnp.int32)}
    return logits, cache
