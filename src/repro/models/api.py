"""Uniform model API: dispatch by cfg.family.

All families implement:
  init_params(cfg, key) -> params
  forward(cfg, params, batch, train=..., ...) -> (h, aux)
  loss_fn(cfg, params, batch, ...) -> (loss, metrics)
  init_cache(cfg, batch_size, cache_len) -> cache
  decode_step(cfg, params, cache, tokens, ...) -> (logits, cache)

Batches are dicts:
  dense/moe/ssm/hybrid: {tokens (B,S), labels (B,S)}
  vlm:   {tokens (B,S_text), prefix_emb (B,P,prefix_dim), labels (B,S_text)}
  audio: {frames (B,F,prefix_dim), tokens (B,S), labels (B,S)}
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, rglru, rwkv6, transformer

_FAMILY_MOD = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": rglru,
    "audio": encdec,
}


def family_module(cfg: ModelConfig):
    return _FAMILY_MOD[cfg.family]


def init_params(cfg: ModelConfig, key):
    return family_module(cfg).init_params(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch, **kw):
    return family_module(cfg).loss_fn(cfg, params, batch, **kw)


def forward(cfg: ModelConfig, params, batch, **kw):
    return family_module(cfg).forward(cfg, params, batch, **kw)


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int):
    return family_module(cfg).init_cache(cfg, batch_size, cache_len)


def decode_step(cfg: ModelConfig, params, cache, tokens, **kw):
    return family_module(cfg).decode_step(cfg, params, cache, tokens, **kw)


def decode_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Effective KV-cache length for a decode shape: ring-buffer bounded by the
    native or long-decode window for windowed archs; full length otherwise."""
    if cfg.family == "ssm":
        return 1  # unused: constant-size state
    win = cfg.attn_window or cfg.long_decode_window
    if cfg.family == "hybrid":
        win = cfg.attn_window
    return min(seq_len, win) if win else seq_len


def batch_shapes(cfg: ModelConfig, shape: InputShape,
                 batch_override: int | None = None) -> Dict[str, Any]:
    """Abstract shapes/dtypes for a training/prefill batch of this arch.
    Returns dict name -> (shape tuple, dtype). Decode shapes are handled by
    cache/token specs in launch/dryrun.py."""
    B = batch_override if batch_override is not None else shape.global_batch
    S = shape.seq_len
    if cfg.family == "vlm":
        P = cfg.n_prefix_tokens
        s_text = max(S - P, 1)
        return {"tokens": ((B, s_text), jnp.int32),
                "prefix_emb": ((B, P, cfg.prefix_dim), jnp.bfloat16),
                "labels": ((B, s_text), jnp.int32)}
    if cfg.family == "audio":
        return {"frames": ((B, cfg.n_prefix_tokens, cfg.prefix_dim), jnp.bfloat16),
                "tokens": ((B, S), jnp.int32),
                "labels": ((B, S), jnp.int32)}
    return {"tokens": ((B, S), jnp.int32), "labels": ((B, S), jnp.int32)}


def param_count(params) -> int:
    import jax
    return sum(p.size for p in jax.tree.leaves(params))
