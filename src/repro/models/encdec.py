"""Encoder-decoder backbone (family=audio; Seamless-M4T-v2-style).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB per the
assignment carve-out: the model consumes precomputed frame embeddings
(B, F, prefix_dim) and projects them to d_model. Encoder is bidirectional; decoder is
causal with cross-attention to the encoder memory. Both stacks `lax.scan` over depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (attn_out, attn_qkv, chunked_cross_entropy, dense_init,
                                 embed_init, gqa_attention, init_attn_params, rms_norm,
                                 swiglu)
from repro.models.layers import cast_params_for_compute


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    ks = jax.random.split(key, 12)
    enc_layers = {
        "attn": init_attn_params(ks[0], cfg, Le, dtype),
        "mlp": {"w_gate": dense_init(ks[1], (Le, D, F), dtype, fan_in=D),
                "w_up": dense_init(ks[2], (Le, D, F), dtype, fan_in=D),
                "w_down": dense_init(ks[3], (Le, F, D), dtype, fan_in=F)},
        "ln1": jnp.ones((Le, D), dtype), "ln2": jnp.ones((Le, D), dtype),
    }
    dec_layers = {
        "self_attn": init_attn_params(ks[4], cfg, Ld, dtype),
        "cross_attn": init_attn_params(ks[5], cfg, Ld, dtype),
        "mlp": {"w_gate": dense_init(ks[6], (Ld, D, F), dtype, fan_in=D),
                "w_up": dense_init(ks[7], (Ld, D, F), dtype, fan_in=D),
                "w_down": dense_init(ks[8], (Ld, F, D), dtype, fan_in=F)},
        "ln1": jnp.ones((Ld, D), dtype), "ln_x": jnp.ones((Ld, D), dtype),
        "ln2": jnp.ones((Ld, D), dtype),
    }
    return {
        "frame_proj": dense_init(ks[9], (cfg.prefix_dim, D), dtype,
                                 fan_in=cfg.prefix_dim),
        "embed": embed_init(ks[10], (V, D), dtype),
        "encoder": enc_layers,
        "decoder": dec_layers,
        "enc_norm": jnp.ones((D,), dtype),
        "final_norm": jnp.ones((D,), dtype),
        "lm_head": dense_init(ks[11], (D, V), dtype, fan_in=D),
    }


def encode(cfg: ModelConfig, params, frames, *, train=True, remat=True,
           unroll=False):
    """frames: (B, F, prefix_dim) -> memory (B, F, D)."""
    x = (frames.astype(params["frame_proj"].dtype) @ params["frame_proj"]
         ).astype(jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp["attn"], cfg, positions)
        o = gqa_attention(q, k, v, causal=False, window=None,
                          q_positions=positions, kv_positions=positions)
        x = x + attn_out(o, lp["attn"], cfg)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return x, None

    if unroll:
        for l in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[l], params["encoder"]))
    else:
        body_fn = jax.checkpoint(body) if (train and remat) else body
        x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.rms_eps)


def _dec_layer(cfg, x, lp, memory, positions):
    h = rms_norm(x, lp["ln1"], cfg.rms_eps)
    q, k, v = attn_qkv(h, lp["self_attn"], cfg, positions)
    o = gqa_attention(q, k, v, causal=True, window=None,
                      q_positions=positions, kv_positions=positions)
    x = x + attn_out(o, lp["self_attn"], cfg)
    h = rms_norm(x, lp["ln_x"], cfg.rms_eps)
    B, Sm = memory.shape[:2]
    mem_pos = jnp.broadcast_to(jnp.arange(Sm, dtype=jnp.int32)[None], (B, Sm))
    q, _, _ = attn_qkv(h, lp["cross_attn"], cfg, positions, rope=False)
    _, k, v = attn_qkv(memory, lp["cross_attn"], cfg, mem_pos, rope=False)
    o = gqa_attention(q, k, v, causal=False, window=None)
    x = x + attn_out(o, lp["cross_attn"], cfg)
    h = rms_norm(x, lp["ln2"], cfg.rms_eps)
    return x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])


def forward(cfg: ModelConfig, params, batch, *, train=True, attn_impl="ref",
            remat=True, unroll=False):
    params = cast_params_for_compute(cfg, params)
    memory = encode(cfg, params, batch["frames"], train=train, remat=remat,
                    unroll=unroll)
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        return _dec_layer(cfg, x, lp, memory, positions), None

    if unroll:
        for l in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[l], params["decoder"]))
    else:
        body_fn = jax.checkpoint(body) if (train and remat) else body
        x, _ = jax.lax.scan(body_fn, x, params["decoder"])
    h = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return h, {"moe_aux": jnp.zeros(()), "n_prefix": 0}


def loss_fn(cfg: ModelConfig, params, batch, *, attn_impl="ref", remat=True,
            xent_chunk: int = 512, unroll=False):
    h, _ = forward(cfg, params, batch, train=True, remat=remat, unroll=unroll)
    nll = chunked_cross_entropy(h, params["lm_head"], batch["labels"], chunk=xent_chunk)
    return nll, {"nll": nll, "ppl": jnp.exp(nll)}


# ---------------------------------------------------------------------------
# decode: self-attn KV cache + precomputed cross-attn KV
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int,
               n_frames: int | None = None):
    dt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    Fm = n_frames if n_frames is not None else cfg.n_prefix_tokens
    return {
        "k": jnp.zeros((L, batch_size, cache_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((L, batch_size, cache_len, cfg.n_kv_heads, hd), dt),
        "cross_k": jnp.zeros((L, batch_size, Fm, cfg.n_kv_heads, hd), dt),
        "cross_v": jnp.zeros((L, batch_size, Fm, cfg.n_kv_heads, hd), dt),
        "kv_pos": jnp.full((cache_len,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def prepare_cross_cache(cfg: ModelConfig, params, memory):
    """Precompute per-layer cross-attention K/V from encoder memory."""
    B, Sm = memory.shape[:2]
    mem_pos = jnp.broadcast_to(jnp.arange(Sm, dtype=jnp.int32)[None], (B, Sm))

    def body(_, lp):
        _, k, v = attn_qkv(memory, lp["cross_attn"], cfg, mem_pos, rope=False)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    return ks, vs


def decode_step(cfg: ModelConfig, params, cache, tokens, *, window=None,
                attn_impl="ref", unroll=False):
    params = cast_params_for_compute(cfg, params)
    B = tokens.shape[0]
    pos = cache["pos"]
    C = cache["k"].shape[2]
    slot = pos % C
    kv_pos = cache["kv_pos"].at[slot].set(pos)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    kv_positions = jnp.broadcast_to(kv_pos[None], (B, C))
    kv_mask = kv_positions >= 0
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.compute_dtype))

    def body(x, xs):
        lp, kc, vc, ck, cv = xs
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp["self_attn"], cfg, positions)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        o = gqa_attention(q, kc, vc, causal=True, window=window,
                          q_positions=positions, kv_positions=kv_positions,
                          kv_mask=kv_mask)
        x = x + attn_out(o, lp["self_attn"], cfg)
        h = rms_norm(x, lp["ln_x"], cfg.rms_eps)
        q, _, _ = attn_qkv(h, lp["cross_attn"], cfg, positions, rope=False)
        o = gqa_attention(q, ck, cv, causal=False, window=None)
        x = x + attn_out(o, lp["cross_attn"], cfg)
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return x, (kc, vc)

    if unroll:
        ks_l, vs_l = [], []
        for l in range(cfg.n_layers):
            xs_l = jax.tree.map(lambda a: a[l],
                                (params["decoder"], cache["k"], cache["v"],
                                 cache["cross_k"], cache["cross_v"]))
            x, (kc, vc) = body(x, xs_l)
            ks_l.append(kc)
            vs_l.append(vc)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    else:
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
    h = rms_norm(x[:, 0], params["final_norm"], cfg.rms_eps)
    logits = h.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    new_cache = dict(cache, k=ks, v=vs, kv_pos=kv_pos, pos=pos + 1)
    return logits, new_cache
