"""Common model primitives: RMSNorm, RoPE, SwiGLU, attention (GQA / qk-norm /
sliding-window / KV-cache decode), chunked cross-entropy.

Everything is functional: params are plain pytrees of jnp arrays; layer params are
stacked along a leading layer axis so the decoder stacks can `lax.scan` over depth
(O(1)-in-depth compile time — essential for the 126-layer dry-runs).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope / mlp
# ---------------------------------------------------------------------------


def cast_params_for_compute(cfg: ModelConfig, params):
    """AMP policy (paper §IV: bf16 compute, f32 master weights): cast float params to
    the compute dtype at forward entry. Matmul accumulations stay f32 via
    preferred_element_type / explicit f32 islands (norms, softmax, scans)."""
    compute = jnp.dtype(cfg.compute_dtype)

    def cast(a):
        return a.astype(compute) if jnp.issubdtype(a.dtype, jnp.floating) else a

    return jax.tree.map(cast, params)


def rms_norm(x, weight, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def gqa_attention(q, k, v, *, causal: bool, window: Optional[int],
                  q_positions=None, kv_positions=None, kv_mask=None):
    """Reference GQA attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). H % KV == 0.
    window: sliding window size (attend to keys with q_pos - k_pos < window).
    kv_mask: (B, Sk) bool validity mask (decode caches / padded encoders).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    qh = q.reshape(B, Sq, KV, group, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale            # (B,KV,g,Sq,Sk)
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(Sk)[None, :]
    qp = q_positions[:, None, None, :, None]                       # (B,1,1,Sq,1)
    kp = kv_positions[:, None, None, None, :]                      # (B,1,1,1,Sk)
    mask = jnp.ones((B, 1, 1, Sq, Sk), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    if kv_mask is not None:
        mask &= kv_mask[:, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def init_attn_params(key, cfg: ModelConfig, n_layers: int, dtype):
    hd = cfg.resolved_head_dim
    D, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (n_layers, D, H * hd), dtype, fan_in=D),
        "wk": dense_init(ks[1], (n_layers, D, KV * hd), dtype, fan_in=D),
        "wv": dense_init(ks[2], (n_layers, D, KV * hd), dtype, fan_in=D),
        "wo": dense_init(ks[3], (n_layers, H * hd, D), dtype, fan_in=H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n_layers, hd), dtype)
        p["k_norm"] = jnp.ones((n_layers, hd), dtype)
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((n_layers, H * hd), dtype)
        p["bk"] = jnp.zeros((n_layers, KV * hd), dtype)
        p["bv"] = jnp.zeros((n_layers, KV * hd), dtype)
        p["bo"] = jnp.zeros((n_layers, D), dtype)
    return p


def attn_qkv(x, lp, cfg: ModelConfig, positions, *, rope: bool = True):
    """Project to q/k/v for one layer (lp = per-layer slice of stacked params)."""
    hd = cfg.resolved_head_dim
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.attn_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = _split_heads(q, cfg.n_heads, hd)
    k = _split_heads(k, cfg.n_kv_heads, hd)
    v = _split_heads(v, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.rms_eps)
        k = rms_norm(k, lp["k_norm"], cfg.rms_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(o, lp, cfg: ModelConfig):
    B, S = o.shape[:2]
    y = o.reshape(B, S, -1) @ lp["wo"]
    if cfg.attn_bias:
        y = y + lp["bo"]
    return y


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materializes the full (B,S,V) logits)
# ---------------------------------------------------------------------------


def chunked_cross_entropy(h, lm_head, labels, *, chunk: int = 512):
    """h: (B, S, D) final hidden states; lm_head: (D, V); labels: (B, S) int32.

    Computes mean token NLL by scanning over sequence chunks so peak memory is
    O(B * chunk * V) instead of O(B * S * V) — the 256k-vocab archs need this.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def chunk_nll(hc, lc):
        logits = (hc.astype(jnp.float32) @ lm_head.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return lse - gold                                          # (B, chunk)

    def body(carry, xs):
        hc, lc = xs
        return carry + jnp.sum(chunk_nll(hc, lc)), None

    hs = h[:, :n * chunk].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    if rem:
        total = total + jnp.sum(chunk_nll(h[:, n * chunk:], labels[:, n * chunk:]))
    return total / (B * S)
