"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent blocks and
local-MQA attention blocks interleaved by `cfg.block_pattern` (1 attn : 2 lru).

Residual block = pre-norm temporal mixer (+residual) then pre-norm SwiGLU MLP
(+residual). Recurrent mixer:
    u = gelu(x W_gate);  z = conv1d_causal(x W_in, width 4);  h = RGLRU(z)
    y = (u * h) W_out
RG-LRU:  r,i = sigm(z W_a + b_a), sigm(z W_x + b_x)
         log a_t = -c * softplus(Lambda) * r_t          (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * z_t)
Train/prefill uses jax.lax.associative_scan (parallel over T — TPU-friendly) or the
Pallas chunked kernel (kernels/rglru_scan); decode carries (h, conv tail) — O(1) per
token, so long_500k is native. Layer stacking: `lax.scan` over pattern groups,
remainder blocks unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (attn_out, attn_qkv, chunked_cross_entropy, dense_init,
                                 embed_init, gqa_attention, init_attn_params, rms_norm,
                                 swiglu)
from repro.models.layers import cast_params_for_compute

CONV_WIDTH = 4
LRU_C = 8.0


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _init_mlp(key, n, D, F, dtype):
    ks = jax.random.split(key, 3)
    return {"w_gate": dense_init(ks[0], (n, D, F), dtype, fan_in=D),
            "w_up": dense_init(ks[1], (n, D, F), dtype, fan_in=D),
            "w_down": dense_init(ks[2], (n, F, D), dtype, fan_in=F)}


def _init_rglru_mixer(key, n, D, dtype):
    ks = jax.random.split(key, 6)
    return {
        "w_gate_br": dense_init(ks[0], (n, D, D), dtype, fan_in=D),
        "w_in": dense_init(ks[1], (n, D, D), dtype, fan_in=D),
        "w_out": dense_init(ks[2], (n, D, D), dtype, fan_in=D),
        "conv_w": dense_init(ks[3], (n, CONV_WIDTH, D), dtype, fan_in=CONV_WIDTH),
        "conv_b": jnp.zeros((n, D), dtype),
        "wa": dense_init(ks[4], (n, D, D), dtype, fan_in=D),
        "ba": jnp.zeros((n, D), dtype),
        "wx": dense_init(ks[5], (n, D, D), dtype, fan_in=D),
        "bx": jnp.zeros((n, D), dtype),
        # Lambda init so that a^c = sigma(Lambda)^c in [0.9, 0.999] roughly
        "lam": jnp.full((n, D), 0.7, dtype),
    }


def _init_block(key, cfg: ModelConfig, kind: str, n: int, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    mixer = (_init_rglru_mixer(ks[0], n, D, dtype) if kind == "rglru"
             else init_attn_params(ks[0], cfg, n, dtype))
    return {"kind_attn": kind == "attn", "mixer": mixer,
            "mlp": _init_mlp(ks[1], n, D, F, dtype),
            "ln1": jnp.ones((n, D), dtype), "ln2": jnp.ones((n, D), dtype)}


def _pattern_counts(cfg: ModelConfig):
    P = len(cfg.block_pattern)
    n_groups = cfg.n_layers // P
    rem = tuple(cfg.block_pattern[: cfg.n_layers % P])
    return n_groups, rem


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    n_groups, rem = _pattern_counts(cfg)
    ks = jax.random.split(key, len(cfg.block_pattern) + len(rem) + 2)
    layers = {f"p{j}": {k: v for k, v in
                        _init_block(ks[j], cfg, kind, n_groups, dtype).items()
                        if k != "kind_attn"}
              for j, kind in enumerate(cfg.block_pattern)}
    rem_blocks = [{k: v for k, v in
                   _init_block(ks[len(cfg.block_pattern) + j], cfg, kind, 1, dtype).items()
                   if k != "kind_attn"}
                  for j, kind in enumerate(rem)]
    return {
        "embed": embed_init(ks[-2], (cfg.vocab, cfg.d_model), dtype),
        "layers": layers,
        "rem": rem_blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[-1], (cfg.d_model, cfg.vocab), dtype,
                              fan_in=cfg.d_model),
    }


# ---------------------------------------------------------------------------
# RG-LRU + conv primitives
# ---------------------------------------------------------------------------


def causal_conv1d(z, w, b, state=None):
    """Depthwise causal conv. z: (B,T,D); w: (W,D); state: (B,W-1,D) carry-in.
    Returns (out (B,T,D), new_state (B,W-1,D))."""
    B, T, D = z.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, D), z.dtype)
    zp = jnp.concatenate([state, z], axis=1)               # (B, T+W-1, D)
    out = sum(zp[:, i:i + T] * w[i] for i in range(W)) + b
    return out.astype(z.dtype), zp[:, -(W - 1):]


def rglru(z, mixer, h0=None, impl="ref"):
    """z: (B,T,D) conv output. Returns (h (B,T,D), h_last (B,D) f32)."""
    zf = z.astype(jnp.float32)
    r = jax.nn.sigmoid(zf @ mixer["wa"].astype(jnp.float32) + mixer["ba"])
    i = jax.nn.sigmoid(zf @ mixer["wx"].astype(jnp.float32) + mixer["bx"])
    log_a = -LRU_C * jax.nn.softplus(mixer["lam"].astype(jnp.float32)) * r  # (B,T,D)
    gated = i * zf
    b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * gated
    a = jnp.exp(log_a)
    if impl == "kernel":
        from repro.kernels.rglru_scan import ops as lru_ops
        h = lru_ops.lru_scan(a, b, h0)
    else:
        if h0 is not None:
            # fold carry-in into the first step: h_1 = a_1 h_0 + b_1
            b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(z.dtype), h[:, -1].astype(jnp.float32)


def rglru_mixer_apply(cfg, x, mixer, state=None, impl="ref"):
    """state: None (train) or {"conv": (B,W-1,D), "h": (B,D)}."""
    u = jax.nn.gelu(x @ mixer["w_gate_br"])
    z = x @ mixer["w_in"]
    z, conv_state = causal_conv1d(z, mixer["conv_w"], mixer["conv_b"],
                                  None if state is None else state["conv"])
    h, h_last = rglru(z, mixer, None if state is None else state["h"], impl=impl)
    y = (u * h) @ mixer["w_out"]
    return y, {"conv": conv_state, "h": h_last}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(cfg, x, bp, kind, positions, attn_impl, lru_impl):
    h = rms_norm(x, bp["ln1"], cfg.rms_eps)
    if kind == "attn":
        q, k, v = attn_qkv(h, bp["mixer"], cfg, positions)
        o = gqa_attention(q, k, v, causal=True, window=cfg.attn_window,
                          q_positions=positions, kv_positions=positions)
        x = x + attn_out(o, bp["mixer"], cfg)
    else:
        y, _ = rglru_mixer_apply(cfg, h, bp["mixer"], impl=lru_impl)
        x = x + y
    h = rms_norm(x, bp["ln2"], cfg.rms_eps)
    return x + swiglu(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])


def forward(cfg: ModelConfig, params, batch, *, train=True, attn_impl="ref",
            remat=True, lru_impl="ref", unroll=False):
    params = cast_params_for_compute(cfg, params)
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    n_groups, rem = _pattern_counts(cfg)

    def group_body(x, glp):
        for j, kind in enumerate(cfg.block_pattern):
            x = _block_apply(cfg, x, glp[f"p{j}"], kind, positions, attn_impl,
                             lru_impl)
        return x, None

    if unroll:  # roofline probes
        for g in range(n_groups):
            x, _ = group_body(x, jax.tree.map(lambda a: a[g], params["layers"]))
    else:
        body_fn = jax.checkpoint(group_body) if (train and remat) else group_body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
    for j, kind in enumerate(rem):
        bp = jax.tree.map(lambda a: a[0], params["rem"][j])
        x = _block_apply(cfg, x, bp, kind, positions, attn_impl, lru_impl)
    h = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return h, {"moe_aux": jnp.zeros(()), "n_prefix": 0}


def loss_fn(cfg: ModelConfig, params, batch, *, attn_impl="ref", remat=True,
            xent_chunk: int = 512, unroll=False):
    h, _ = forward(cfg, params, batch, train=True, attn_impl=attn_impl, remat=remat,
                   unroll=unroll)
    nll = chunked_cross_entropy(h, params["lm_head"], batch["labels"], chunk=xent_chunk)
    return nll, {"nll": nll, "ppl": jnp.exp(nll)}


# ---------------------------------------------------------------------------
# decode — O(1) state (recurrent) + ring-buffer window cache (attn blocks)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int):
    dt = jnp.dtype(cfg.compute_dtype)
    D, hd = cfg.d_model, cfg.resolved_head_dim
    C = min(cache_len, cfg.attn_window)   # local attention never needs more
    n_groups, rem = _pattern_counts(cfg)

    def block_cache(kind, n):
        if kind == "attn":
            return {"k": jnp.zeros((n, batch_size, C, cfg.n_kv_heads, hd), dt),
                    "v": jnp.zeros((n, batch_size, C, cfg.n_kv_heads, hd), dt)}
        return {"conv": jnp.zeros((n, batch_size, CONV_WIDTH - 1, D), dt),
                "h": jnp.zeros((n, batch_size, D), jnp.float32)}

    return {
        "groups": {f"p{j}": block_cache(kind, n_groups)
                   for j, kind in enumerate(cfg.block_pattern)},
        "rem": [block_cache(kind, 1) for kind in rem],
        "kv_pos": jnp.full((C,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _decode_block(cfg, x, bp, kind, cache, slot, positions, kv_positions, kv_mask,
                  lru_impl):
    h = rms_norm(x, bp["ln1"], cfg.rms_eps)
    if kind == "attn":
        q, k, v = attn_qkv(h, bp["mixer"], cfg, positions)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        o = gqa_attention(q, kc, vc, causal=True, window=cfg.attn_window,
                          q_positions=positions, kv_positions=kv_positions,
                          kv_mask=kv_mask)
        x = x + attn_out(o, bp["mixer"], cfg)
        new_cache = {"k": kc, "v": vc}
    else:
        y, new_cache = rglru_mixer_apply(cfg, h, bp["mixer"], state=cache,
                                         impl=lru_impl)
        x = x + y
    h = rms_norm(x, bp["ln2"], cfg.rms_eps)
    x = x + swiglu(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
    return x, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, *, window=None,
                attn_impl="ref", lru_impl="ref", unroll=False):
    params = cast_params_for_compute(cfg, params)
    B = tokens.shape[0]
    pos = cache["pos"]
    C = cache["kv_pos"].shape[0]
    slot = pos % C
    kv_pos = cache["kv_pos"].at[slot].set(pos)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    kv_positions = jnp.broadcast_to(kv_pos[None], (B, C))
    kv_mask = kv_positions >= 0
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    n_groups, rem = _pattern_counts(cfg)

    def group_body(x, xs):
        glp, gcache = xs
        new_caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            x, nc = _decode_block(cfg, x, glp[f"p{j}"], kind, gcache[f"p{j}"], slot,
                                  positions, kv_positions, kv_mask, "ref")
            new_caches[f"p{j}"] = nc
        return x, new_caches

    if unroll:
        caches_l = []
        for g in range(n_groups):
            xs_g = jax.tree.map(lambda a: a[g], (params["layers"], cache["groups"]))
            x, nc = group_body(x, xs_g)
            caches_l.append(nc)
        new_group_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_l)
    else:
        x, new_group_caches = jax.lax.scan(group_body, x,
                                           (params["layers"], cache["groups"]))
    new_rem = []
    for j, kind in enumerate(rem):
        bp = jax.tree.map(lambda a: a[0], params["rem"][j])
        bc = jax.tree.map(lambda a: a[0], cache["rem"][j])
        x, nc = _decode_block(cfg, x, bp, kind, bc, slot, positions, kv_positions,
                              kv_mask, "ref")
        new_rem.append(jax.tree.map(lambda a: a[None], nc))
    h = rms_norm(x[:, 0], params["final_norm"], cfg.rms_eps)
    logits = h.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return logits, {"groups": new_group_caches, "rem": new_rem, "kv_pos": kv_pos,
                    "pos": pos + 1}
