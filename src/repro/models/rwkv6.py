"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free SSM family.

Per layer: time-mix block (data-dependent token-shift ddlerp + data-dependent decay
WKV recurrence with matrix-valued per-head state) and channel-mix block (squared-ReLU
MLP with receptance gate). Matches the Finch formulation; LayerNorms are RMSNorms
(simplification noted in DESIGN.md).

The WKV recurrence per head (state S in R^{hd x hd}):
    o_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
Train/prefill uses a chunked form (kernels/rwkv6_scan on TPU, jnp scan ref here);
decode carries S directly — O(1) per token, which is why long_500k is native.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import chunked_cross_entropy, dense_init, embed_init, rms_norm
from repro.models.layers import cast_params_for_compute

LORA_RANK = 32


def _lora_rank(cfg: ModelConfig) -> int:
    return min(LORA_RANK, max(4, cfg.d_model // 16))


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    H = D // cfg.rwkv_head_dim
    r = _lora_rank(cfg)
    ks = jax.random.split(key, 24)
    i = iter(range(24))
    tm = {
        # ddlerp static mix coefficients (mu_x plus one per r,w,k,v,g)
        "mu_x": jnp.zeros((L, D), dtype),
        "mu":   jnp.zeros((L, 5, D), dtype),
        # data-dependent lerp loras: (5, D, r) and (5, r, D)
        "lora_a": dense_init(ks[next(i)], (L, 5, D, r), dtype, fan_in=D),
        "lora_b": jnp.zeros((L, 5, r, D), dtype),
        # decay: w = exp(-exp(w0 + tanh(xw @ wa) @ wb))
        "w0": jnp.full((L, D), -6.0, dtype),
        "wa": dense_init(ks[next(i)], (L, D, r), dtype, fan_in=D),
        "wb": jnp.zeros((L, r, D), dtype),
        "u":  jnp.zeros((L, D), dtype),          # bonus for current token
        "wr": dense_init(ks[next(i)], (L, D, D), dtype, fan_in=D),
        "wk": dense_init(ks[next(i)], (L, D, D), dtype, fan_in=D),
        "wv": dense_init(ks[next(i)], (L, D, D), dtype, fan_in=D),
        "wg": dense_init(ks[next(i)], (L, D, D), dtype, fan_in=D),
        "wo": dense_init(ks[next(i)], (L, D, D), dtype, fan_in=D),
        "gn": jnp.ones((L, D), dtype),           # per-head group norm scale
    }
    cm = {
        "mu_k": jnp.zeros((L, D), dtype),
        "mu_r": jnp.zeros((L, D), dtype),
        "wk": dense_init(ks[next(i)], (L, D, F), dtype, fan_in=D),
        "wv": dense_init(ks[next(i)], (L, F, D), dtype, fan_in=F),
        "wr": dense_init(ks[next(i)], (L, D, D), dtype, fan_in=D),
    }
    return {
        "embed": embed_init(ks[next(i)], (V, D), dtype),
        "layers": {"tm": tm, "cm": cm,
                   "ln1": jnp.ones((L, D), dtype), "ln2": jnp.ones((L, D), dtype)},
        "final_norm": jnp.ones((D,), dtype),
        "lm_head": dense_init(ks[next(i)], (D, V), dtype, fan_in=D),
    }


# ---------------------------------------------------------------------------
# wkv recurrence (reference; the Pallas kernel lives in kernels/rwkv6_scan)
# ---------------------------------------------------------------------------


def wkv_scan_ref(r, k, v, w, u, s0=None):
    """r,k,v,w: (B,T,H,hd); u: (H,hd). Returns (o: (B,T,H,hd), sT: (B,H,hd,hd))."""
    B, T, H, hd = r.shape
    s0 = s0 if s0 is not None else jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs                       # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        o = jnp.einsum("bhi,bhij->bhj", rt, s + u[None] [..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), sT


def _group_norm(o, scale, eps):
    # o: (B,T,H,hd): normalize per head
    of = o.astype(jnp.float32)
    mu = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    of = (of - mu) * jax.lax.rsqrt(var + eps)
    B, T, H, hd = o.shape
    return (of.reshape(B, T, H * hd) * scale.astype(jnp.float32)).astype(o.dtype)


def _ddlerp(x, x_prev, tm):
    """Finch data-dependent token-shift. x,x_prev: (B,T,D). Returns 5 mixed streams
    (r,w,k,v,g) each (B,T,D)."""
    dx = x_prev - x
    xx = x + dx * tm["mu_x"]
    # (B,T,5,r) = tanh(xx @ lora_a); (B,T,5,D) = @ lora_b
    z = jnp.tanh(jnp.einsum("btd,ndr->btnr", xx, tm["lora_a"]))
    dyn = jnp.einsum("btnr,nrd->btnd", z, tm["lora_b"])
    mix = tm["mu"][None, None] + dyn                           # (B,T,5,D)
    return tuple(x + dx * mix[:, :, j] for j in range(5))


def time_mix(cfg: ModelConfig, x, x_prev, tm, s0=None, wkv_impl="ref"):
    """x: (B,T,D); x_prev: x shifted right by one (first slot = carry-in).
    Returns (y, sT)."""
    B, T, D = x.shape
    H, hd = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xr, xw, xk, xv, xg = _ddlerp(x, x_prev, tm)
    r = (xr @ tm["wr"]).reshape(B, T, H, hd)
    kk = (xk @ tm["wk"]).reshape(B, T, H, hd)
    vv = (xv @ tm["wv"]).reshape(B, T, H, hd)
    g = xg @ tm["wg"]
    logw = tm["w0"][None, None] + jnp.einsum(
        "btd,dr->btr", jnp.tanh(xw), tm["wa"]) @ tm["wb"]
    w = jnp.exp(-jnp.exp(logw.astype(jnp.float32))).reshape(B, T, H, hd)
    u = tm["u"].reshape(H, hd).astype(jnp.float32)
    if wkv_impl == "kernel":
        from repro.kernels.rwkv6_scan import ops as wkv_ops
        o, sT = wkv_ops.wkv_scan(r, kk, vv, w.astype(r.dtype), u, s0)
    else:
        o, sT = wkv_scan_ref(r, kk, vv, w.astype(r.dtype), u, s0)
    o = _group_norm(o, tm["gn"], cfg.rms_eps)
    y = (o * jax.nn.silu(g)) @ tm["wo"]
    return y, sT


def channel_mix(x, x_prev, cm):
    dx = x_prev - x
    xk = x + dx * cm["mu_k"]
    xr = x + dx * cm["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ cm["wk"]))
    return jax.nn.sigmoid(xr @ cm["wr"]) * (k @ cm["wv"])


def _shift(x, carry_in=None):
    """token shift: y[:, t] = x[:, t-1]; y[:, 0] = carry_in (or 0)."""
    y = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if carry_in is not None:
        y = y.at[:, 0].set(carry_in)
    return y


def forward(cfg: ModelConfig, params, batch, *, train=True, attn_impl="ref",
            remat=True, wkv_impl="ref", unroll=False):
    params = cast_params_for_compute(cfg, params)
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.compute_dtype))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        y, _ = time_mix(cfg, h, _shift(h), lp["tm"], wkv_impl=wkv_impl)
        x = x + y
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + channel_mix(h, _shift(h), lp["cm"])
        return x, jnp.zeros((), jnp.float32)

    if unroll:  # roofline probes
        for l in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[l], params["layers"]))
    else:
        body_fn = jax.checkpoint(body) if (train and remat) else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return h, {"moe_aux": jnp.zeros(()), "n_prefix": 0}


def loss_fn(cfg: ModelConfig, params, batch, *, attn_impl="ref", remat=True,
            xent_chunk: int = 512, unroll=False):
    h, _ = forward(cfg, params, batch, train=True, remat=remat, unroll=unroll)
    nll = chunked_cross_entropy(h, params["lm_head"], batch["labels"], chunk=xent_chunk)
    return nll, {"nll": nll, "ppl": jnp.exp(nll)}


# ---------------------------------------------------------------------------
# decode — O(1) state per token
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int):
    """cache_len is irrelevant for an SSM (constant-size state); kept for API parity."""
    D = cfg.d_model
    H, hd = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    L = cfg.n_layers
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "x_prev_tm": jnp.zeros((L, batch_size, D), dt),
        "x_prev_cm": jnp.zeros((L, batch_size, D), dt),
        "s": jnp.zeros((L, batch_size, H, hd, hd), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, *, window=None,
                attn_impl="ref", unroll=False):
    params = cast_params_for_compute(cfg, params)
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.compute_dtype))

    def body(x, xs):
        lp, xp_tm, xp_cm, s = xs
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        y, sT = time_mix(cfg, h, xp_tm[:, None, :], lp["tm"], s0=s)
        new_xp_tm = h[:, 0]
        x = x + y
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + channel_mix(h, xp_cm[:, None, :], lp["cm"])
        return x, (new_xp_tm, h[:, 0], sT)

    if unroll:
        outs = []
        for l in range(cfg.n_layers):
            xs_l = jax.tree.map(lambda a: a[l], (params["layers"],
                                cache["x_prev_tm"], cache["x_prev_cm"], cache["s"]))
            x, out = body(x, xs_l)
            outs.append(out)
        xp_tm, xp_cm, s = (jnp.stack([o[i] for o in outs]) for i in range(3))
    else:
        x, (xp_tm, xp_cm, s) = jax.lax.scan(
            body, x,
            (params["layers"], cache["x_prev_tm"], cache["x_prev_cm"], cache["s"]))
    h = rms_norm(x[:, 0], params["final_norm"], cfg.rms_eps)
    logits = h.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return logits, {"x_prev_tm": xp_tm, "x_prev_cm": xp_cm, "s": s,
                    "pos": cache["pos"] + 1}
