"""LR schedules. Paper §IV: linear warmup (1000 steps) then cosine decay."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / max(1, warmup_steps)
    progress = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                        0.0, 1.0)
    cos = final_frac * base_lr + (1 - final_frac) * base_lr * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, cos)
