"""Inner optimizer: AdamW (paper §IV: lr 4e-4, weight decay 0.1), pure-pytree,
no external deps. Decoupled weight decay, bias-corrected moments, global-norm clip.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: object
    nu: object
    count: jax.Array


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    """moment_dtype=bf16 halves optimizer memory (used for the 400B-class dry-run
    fit; f32 default matches the paper's training setup)."""
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=moment_dtype), params)
    return AdamWState(mu=zeros(), nu=zeros(), count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, lr, *,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_state). lr may be a traced scalar (schedule)."""
    count = state.count + 1
    if clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32)
                      + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: (b2 * v.astype(jnp.float32)
                      + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
        state.nu, grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / c1
        vhat = v.astype(jnp.float32) / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count)
