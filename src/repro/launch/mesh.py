"""Production meshes (TPU v5e target).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — `pod` is the cross-region
axis: each pod is one CoCoDC worker/datacenter; fragment all-reduces are the only
collectives that cross it.

Functions, not module constants — importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """1-chip-per-axis mesh for CPU smoke tests of the sharded step functions."""
    n = jax.device_count()
    if multi_pod and n >= 2:
        return jax.make_mesh((2, 1, max(1, n // 2)), ("pod", "data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a == "data")


def has_pod(mesh) -> bool:
    return "pod" in mesh.axis_names
