"""End-to-end cross-region training driver.

    PYTHONPATH=src python -m repro.launch.train --arch paper_150m --method cocodc \
        --steps 400 --workers 4 --local-batch 4 --seq-len 64

Runs the full stack: synthetic non-IID per-worker data -> worker-stacked inner
AdamW -> protocol engine (DiLoCo / Streaming DiLoCo / CoCoDC) -> periodic
consensus-model eval -> checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.configs import CoCoDCConfig, get_config
from repro.core.network import (MESH_PROFILES, SCENARIOS, generate_mesh,
                                make_scenario)
from repro.core.trainer import CrossRegionTrainer, TrainerConfig


def build(args):
    mcfg = get_config(args.arch)
    if args.reduced:
        mcfg = mcfg.reduced()
    ccfg = CoCoDCConfig(
        num_workers=args.workers, local_steps=args.H,
        num_fragments=args.fragments, overlap_depth=args.tau,
        comp_lambda=args.comp_lambda, net_utilization=args.gamma,
        mixing_alpha=args.alpha, link_pricing=args.link_pricing,
        fragment_strategy=args.fragment_strategy,
        routing=args.routing, hub_failover=args.hub_failover,
        adaptive_resync=args.adaptive_resync)
    tcfg = TrainerConfig(
        method=args.method, local_batch=args.local_batch, seq_len=args.seq_len,
        total_steps=args.steps, warmup_steps=max(10, args.steps // 20),
        seed=args.seed, inner_lr=args.lr, engine_impl=args.engine_impl,
        loop=args.loop)
    network = None
    if args.mesh is not None:
        if args.topology is not None:
            raise SystemExit("--mesh and --topology are mutually exclusive")
        network = generate_mesh(args.workers, args.mesh, seed=args.mesh_seed,
                                step_time_s=args.step_time)
    elif args.topology is not None:
        # "paper" keeps the calibrated-symmetric default (network=None) so the
        # fragment-size calibration in CrossRegionTrainer still applies
        if args.topology != "paper":
            network = make_scenario(args.topology, num_workers=args.workers,
                                    step_time_s=args.step_time)
    return CrossRegionTrainer(mcfg, ccfg, tcfg, network=network,
                              dynamics=args.dynamics,
                              dynamics_seed=args.mesh_seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_150m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke variant of the arch (CPU-friendly)")
    ap.add_argument("--method", default="cocodc",
                    choices=["diloco", "streaming", "cocodc", "local"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--H", type=int, default=100)
    ap.add_argument("--fragments", type=int, default=4)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--comp-lambda", type=float, default=0.5)
    ap.add_argument("--gamma", type=float, default=0.4)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=4e-4)
    ap.add_argument("--local-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--topology", default=None, choices=sorted(SCENARIOS),
                    help="heterogeneous WAN scenario (default: calibrated "
                         "symmetric paper network)")
    ap.add_argument("--mesh", default=None, choices=sorted(MESH_PROFILES),
                    help="generated N-region mesh profile (N = --workers); "
                         "mutually exclusive with --topology")
    ap.add_argument("--mesh-seed", type=int, default=0,
                    help="seed for --mesh generation and --dynamics draws")
    ap.add_argument("--dynamics", default=None,
                    help="time-varying link dynamics spec, e.g. "
                         "'diurnal:period=240:depth=0.6,hub_failure:start=100:"
                         "dur=50,jitter:frac=0.05' (see "
                         "repro.core.network.parse_dynamics)")
    ap.add_argument("--fragment-strategy", default="",
                    choices=["", "strided", "contiguous", "skewed"],
                    help="model fragmentation strategy ('' = strided)")
    ap.add_argument("--step-time", type=float, default=1.0,
                    help="T_c seconds per local step for --topology/--mesh "
                         "scenarios")
    ap.add_argument("--engine-impl", default="jit", choices=["jit", "host"],
                    help="jitted EngineState transitions vs eager host path")
    ap.add_argument("--loop", default="segment", choices=["segment", "per_step"],
                    help="segment-scanned execution engine (one lax.scan "
                         "dispatch per inter-event segment) vs the legacy "
                         "one-dispatch-per-step loop")
    ap.add_argument("--link-pricing", action="store_true",
                    help="Algorithm-2 link-aware fragment pricing (R_p/T_s,p)")
    ap.add_argument("--routing", default="static",
                    choices=["static", "routed"],
                    help="routed communication plans: every collective runs "
                         "over deterministic multi-hop min-cost routes "
                         "computed against the CURRENT link state, re-planned "
                         "at each dynamics edge (static = fixed "
                         "ring/hierarchical formulas, bitwise PR 3 behavior)")
    ap.add_argument("--hub-failover", action="store_true",
                    help="with --routing routed: re-elect the next-best-"
                         "connected region as hub while the declared hub's "
                         "links are out (restored on recovery); fully dark "
                         "regions drop out of the collective")
    ap.add_argument("--adaptive-resync", action="store_true",
                    help="re-derive Eq. 9's target sync count N (and Eq. "
                         "10's h) each outer round from measured transfer "
                         "durations (cocodc)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="atomically checkpoint the FULL run state to --ckpt "
                         "every N steps (segment boundaries)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint to resume from: a trainer_state_v1 "
                         "checkpoint restores the full run (exact trajectory); "
                         "a legacy dict restores theta_g/momentum only")
    ap.add_argument("--stop-at", type=int, default=None,
                    help="pause the run at this absolute step (the LR schedule "
                         "still spans --steps); checkpoint with --ckpt and "
                         "continue later with --resume")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)
    if args.ckpt_every and not args.ckpt:
        ap.error("--ckpt-every requires --ckpt (nowhere to save)")

    trainer = build(args)
    if args.resume:
        from repro.checkpoint import load_pytree
        from repro.core.trainer import CKPT_FORMAT
        state = load_pytree(args.resume)
        if isinstance(state, dict) and state.get("format") == CKPT_FORMAT:
            trainer.restore_checkpoint(args.resume, state=state)
            print(f"resumed full run state from {args.resume} "
                  f"(step {trainer.step}, wall {trainer.engine.wall_clock:.0f}s)")
        else:
            # legacy partial checkpoint: consensus model + outer momentum only
            import jax
            import jax.numpy as jnp
            trainer.engine.theta_g = jax.tree.map(
                lambda a, b: b.astype(a.dtype) if hasattr(b, "astype") else b,
                trainer.engine.theta_g, state["theta_g"])
            trainer.engine.momentum = jax.tree.map(
                lambda a, b: b.astype(a.dtype) if hasattr(b, "astype") else b,
                trainer.engine.momentum, state["momentum"])
            # workers restart from the restored consensus
            trainer.params_stack = jax.tree.map(
                lambda g: jnp.broadcast_to(
                    g[None], (trainer.ccfg.num_workers,) + g.shape).copy(),
                trainer.engine.theta_g)
            print(f"resumed (legacy: theta_g/momentum only) from {args.resume} "
                  f"(step {state.get('step')})")
    t0 = time.time()
    hist = trainer.run(steps=args.stop_at, eval_every=args.eval_every,
                       log=lambda s: print(s, flush=True),
                       ckpt_path=args.ckpt, ckpt_every=args.ckpt_every)
    dt = time.time() - t0
    stats = trainer.engine.stats()
    link_stats = trainer.engine.link_stats()
    print(f"done in {dt:.1f}s host-time; simulated wall {stats['wall_clock_s']:.0f}s;"
          f" comm hidden {stats['overlap_ratio']*100:.0f}%", flush=True)
    if stats.get("stall_seconds"):
        print(f"dynamic links: stalled {stats['stall_seconds']:.1f}s "
              f"({stats['stall_fraction']*100:.0f}% of WAN time), "
              f"{int(stats['n_retries'])} outage retries", flush=True)
    if args.routing == "routed":
        print(f"routed planner: {int(stats['reroutes'])} reroutes, "
              f"{int(stats['hub_elections'])} hub elections", flush=True)
    if link_stats["links"]:
        print("per-link WAN traffic:", flush=True)
        for link, rec in sorted(link_stats["links"].items()):
            print(f"  {link:32s} {rec['bytes']/1e9:9.3f} GB "
                  f"busy {rec['busy_seconds']:8.1f}s", flush=True)
        print(f"  busiest link: {link_stats['busiest_link']}", flush=True)
    if args.ckpt:
        trainer.save_checkpoint(args.ckpt)
        print(f"checkpoint (full run state) -> {args.ckpt}")
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump({"args": vars(args), "history": hist, "stats": stats,
                       "link_stats": link_stats}, f, indent=1)
        print(f"history -> {args.history_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
