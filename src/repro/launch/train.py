"""End-to-end cross-region training driver.

    PYTHONPATH=src python -m repro.launch.train --arch paper_150m --method cocodc \
        --steps 400 --workers 4 --local-batch 4 --seq-len 64

Every run is defined by a declarative `ExperimentSpec` (repro.api): the CLI
flags map onto spec fields, `--spec path.json` launches from a saved spec
(explicit flags override its fields), and `--print-spec` emits the composed
spec as JSON without training — feed it back via `--spec` to reproduce the
run bitwise. The trainer itself is always constructed through
`repro.api.build_experiment`.

Runs the full stack: synthetic non-IID per-worker data -> worker-stacked inner
AdamW -> protocol engine (any registered sync method) -> periodic
consensus-model eval -> checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.api import ExperimentSpec, build_experiment, registered_methods
from repro.core.network import MESH_PROFILES, SCENARIOS


def spec_from_args(args) -> ExperimentSpec:
    """Map CLI flags onto an ExperimentSpec. With --spec, the file is the
    base and explicitly-passed flags override its fields; without, the spec
    dataclass defaults are the CLI defaults. (Every flag defaults to None =
    "not passed"; boolean flags are three-state — `--x` / `--no-x` / unset —
    so a spec-file boolean can be cleared from the CLI, e.g.
    `--spec routed.json --method streaming --no-adaptive-resync`.)"""
    spec = (ExperimentSpec.from_json_file(args.spec) if args.spec
            else ExperimentSpec())

    def over(obj, **kw):
        kw = {k: v for k, v in kw.items() if v is not None}
        return dataclasses.replace(obj, **kw) if kw else obj

    model = over(spec.model, arch=args.arch, reduced=args.reduced)
    ext = over(spec.method.extensions,
               fragment_strategy=args.fragment_strategy,
               link_pricing=args.link_pricing,
               adaptive_resync=args.adaptive_resync,
               wire_codec=args.wire_codec,
               codec_block=args.codec_block,
               codec_error_feedback=args.codec_error_feedback,
               fused_updates=args.fused_updates)
    method = over(spec.method, name=args.method, num_workers=args.workers,
                  local_steps=args.H, num_fragments=args.fragments,
                  overlap_depth=args.tau, comp_lambda=args.comp_lambda,
                  net_utilization=args.gamma, mixing_alpha=args.alpha)
    method = dataclasses.replace(method, extensions=ext)
    network = over(spec.network, topology=args.topology, mesh=args.mesh,
                   mesh_seed=args.mesh_seed, dynamics=args.dynamics,
                   step_time_s=args.step_time, routing=args.routing,
                   hub_failover=args.hub_failover,
                   channel_scheduler=args.channel_scheduler,
                   multipath_k=args.multipath_k,
                   concurrent_collectives=args.concurrent_collectives)
    run = over(spec.run, steps=args.steps, seed=args.seed, inner_lr=args.lr,
               local_batch=args.local_batch, seq_len=args.seq_len,
               eval_every=args.eval_every, ckpt_every=args.ckpt_every,
               engine_impl=args.engine_impl, loop=args.loop)
    return dataclasses.replace(spec, model=model, method=method,
                               network=network, run=run)


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Cross-region training driver. Flag defaults are the "
                    "ExperimentSpec defaults (shown in --print-spec); with "
                    "--spec, flags you pass explicitly override the file.")
    ap.add_argument("--spec", default=None,
                    help="launch from a saved ExperimentSpec JSON "
                         "(experiments/specs/*.json); explicit flags override")
    ap.add_argument("--print-spec", action="store_true",
                    help="print the composed ExperimentSpec as JSON and exit "
                         "(feed it back via --spec to reproduce the run)")
    ap.add_argument("--arch", default=None, help="architecture config id "
                    "(default paper_150m)")
    ap.add_argument("--reduced", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="use the reduced smoke variant of the arch (CPU-friendly)")
    ap.add_argument("--method", default=None,
                    choices=sorted(registered_methods()),
                    help="registered sync method (default cocodc)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--H", type=int, default=None, help="local steps per round")
    ap.add_argument("--fragments", type=int, default=None)
    ap.add_argument("--tau", type=int, default=None)
    ap.add_argument("--comp-lambda", type=float, default=None)
    ap.add_argument("--gamma", type=float, default=None)
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--local-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--eval-every", type=int, default=None)
    ap.add_argument("--topology", default=None, choices=sorted(SCENARIOS),
                    help="heterogeneous WAN scenario (default: calibrated "
                         "symmetric paper network)")
    ap.add_argument("--mesh", default=None, choices=sorted(MESH_PROFILES),
                    help="generated N-region mesh profile (N = --workers); "
                         "mutually exclusive with --topology")
    ap.add_argument("--mesh-seed", type=int, default=None,
                    help="seed for --mesh generation and --dynamics draws")
    ap.add_argument("--dynamics", default=None,
                    help="time-varying link dynamics spec, e.g. "
                         "'diurnal:period=240:depth=0.6,hub_failure:start=100:"
                         "dur=50,jitter:frac=0.05' (see "
                         "repro.core.network.parse_dynamics)")
    ap.add_argument("--fragment-strategy", default=None,
                    choices=["", "strided", "contiguous", "skewed"],
                    help="model fragmentation strategy ('' = strided)")
    ap.add_argument("--step-time", type=float, default=None,
                    help="T_c seconds per local step for --topology/--mesh "
                         "scenarios")
    ap.add_argument("--engine-impl", default=None, choices=["jit", "host"],
                    help="jitted EngineState transitions vs eager host path")
    ap.add_argument("--loop", default=None, choices=["segment", "per_step"],
                    help="segment-scanned execution engine (one lax.scan "
                         "dispatch per inter-event segment) vs the legacy "
                         "one-dispatch-per-step loop")
    ap.add_argument("--link-pricing", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="Algorithm-2 link-aware fragment pricing (R_p/T_s,p)")
    ap.add_argument("--routing", default=None,
                    choices=["static", "routed"],
                    help="routed communication plans: every collective runs "
                         "over deterministic multi-hop min-cost routes "
                         "computed against the CURRENT link state, re-planned "
                         "at each dynamics edge (static = fixed "
                         "ring/hierarchical formulas)")
    ap.add_argument("--hub-failover", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="with --routing routed: re-elect the next-best-"
                         "connected region as hub while the declared hub's "
                         "links are out (restored on recovery); fully dark "
                         "regions drop out of the collective")
    ap.add_argument("--channel-scheduler", default=None,
                    choices=["serial", "fairshare"],
                    help="WAN traffic plane: serial = fixed channel queue "
                         "(bitwise-pinned default); fairshare = max-min "
                         "water-filling bandwidth sharing over all in-flight "
                         "transfers (links as shared resources)")
    ap.add_argument("--multipath-k", default=None, type=int,
                    help="with --routing routed: split each logical link's "
                         "payload across up to k edge-disjoint min-cost "
                         "paths (inverse-cost byte shares; default 1)")
    ap.add_argument("--concurrent-collectives", default=None, type=int,
                    help="serial scheduler's WAN channel pool size "
                         "(explicit topologies/meshes only; default 1)")
    ap.add_argument("--adaptive-resync", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="re-derive Eq. 9's target sync count N (and Eq. "
                         "10's h) each outer round from measured transfer "
                         "durations (cocodc)")
    ap.add_argument("--wire-codec", default=None,
                    choices=["none", "int8", "int4"],
                    help="quantize pseudo-gradient deltas before the WAN "
                         "(per-block absmax, kernels/delta_codec); none "
                         "keeps the raw f32/sync_dtype wire bitwise")
    ap.add_argument("--codec-block", default=None, type=int,
                    help="elements per quantization block (one f32 scale "
                         "ships per block; default 256)")
    ap.add_argument("--codec-error-feedback", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="keep quantization residuals locally and fold them "
                         "into the next initiation of the same elements "
                         "(EF-SGD; default on)")
    ap.add_argument("--fused-updates", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="route protocol transitions through the flat "
                         "fragment plane + fused outer-update kernels (one "
                         "Pallas dispatch per fragment per stage; default "
                         "off = per-leaf path, bitwise vs prior releases)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="atomically checkpoint the FULL run state to --ckpt "
                         "every N steps (segment boundaries)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint to resume from: a trainer_state_v1 "
                         "checkpoint restores the full run (exact trajectory); "
                         "a legacy dict restores theta_g/momentum only")
    ap.add_argument("--stop-at", type=int, default=None,
                    help="pause the run at this absolute step (the LR schedule "
                         "still spans the spec's steps); checkpoint with "
                         "--ckpt and continue later with --resume")
    ap.add_argument("--history-out", default=None)
    return ap


def main(argv=None):
    ap = make_parser()
    args = ap.parse_args(argv)
    try:
        spec = spec_from_args(args).validate()
    except (ValueError, OSError) as e:
        ap.error(str(e))
    if args.print_spec:
        print(spec.to_json())
        return 0
    if spec.run.ckpt_every and not args.ckpt:
        ap.error("--ckpt-every requires --ckpt (nowhere to save)")

    trainer = build_experiment(spec)
    if args.resume:
        from repro.checkpoint import load_pytree
        from repro.core.trainer import CKPT_FORMAT
        state = load_pytree(args.resume)
        if isinstance(state, dict) and state.get("format") == CKPT_FORMAT:
            trainer.restore_checkpoint(args.resume, state=state)
            print(f"resumed full run state from {args.resume} "
                  f"(step {trainer.step}, wall {trainer.engine.wall_clock:.0f}s)")
        else:
            # legacy partial checkpoint: consensus model + outer momentum only
            import jax
            import jax.numpy as jnp
            trainer.engine.theta_g = jax.tree.map(
                lambda a, b: b.astype(a.dtype) if hasattr(b, "astype") else b,
                trainer.engine.theta_g, state["theta_g"])
            trainer.engine.momentum = jax.tree.map(
                lambda a, b: b.astype(a.dtype) if hasattr(b, "astype") else b,
                trainer.engine.momentum, state["momentum"])
            # workers restart from the restored consensus
            trainer.params_stack = jax.tree.map(
                lambda g: jnp.broadcast_to(
                    g[None], (trainer.ccfg.num_workers,) + g.shape).copy(),
                trainer.engine.theta_g)
            print(f"resumed (legacy: theta_g/momentum only) from {args.resume} "
                  f"(step {state.get('step')})")
    t0 = time.time()
    hist = trainer.run(steps=args.stop_at, eval_every=spec.run.eval_every,
                       log=lambda s: print(s, flush=True),
                       ckpt_path=args.ckpt, ckpt_every=spec.run.ckpt_every)
    dt = time.time() - t0
    stats = trainer.engine.stats()
    link_stats = trainer.engine.link_stats()
    print(f"done in {dt:.1f}s host-time; simulated wall {stats['wall_clock_s']:.0f}s;"
          f" comm hidden {stats['overlap_ratio']*100:.0f}%", flush=True)
    if stats.get("stall_seconds"):
        print(f"dynamic links: stalled {stats['stall_seconds']:.1f}s "
              f"({stats['stall_fraction']*100:.0f}% of WAN time), "
              f"{int(stats['n_retries'])} outage retries", flush=True)
    if spec.network.routing == "routed":
        print(f"routed planner: {int(stats['reroutes'])} reroutes, "
              f"{int(stats['hub_elections'])} hub elections", flush=True)
    if spec.network.channel_scheduler == "fairshare" or \
            spec.network.multipath_k > 1:
        print(f"traffic plane ({spec.network.channel_scheduler}): transfer "
              f"sojourn mean {stats['transfer_mean_s']:.2f}s "
              f"p95 {stats['transfer_p95_s']:.2f}s, "
              f"{int(stats['multipath_splits'])} multipath splits", flush=True)
    if link_stats["links"]:
        print("per-link WAN traffic:", flush=True)
        for link, rec in sorted(link_stats["links"].items()):
            print(f"  {link:32s} {rec['bytes']/1e9:9.3f} GB "
                  f"busy {rec['busy_seconds']:8.1f}s "
                  f"({rec['busy_fraction']*100:4.1f}%)", flush=True)
        print(f"  busiest link: {link_stats['busiest_link']}", flush=True)
    if args.ckpt:
        trainer.save_checkpoint(args.ckpt)
        print(f"checkpoint (full run state) -> {args.ckpt}")
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump({"args": vars(args), "spec": spec.to_dict(),
                       "history": hist, "stats": stats,
                       "link_stats": link_stats}, f, indent=1)
        print(f"history -> {args.history_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
