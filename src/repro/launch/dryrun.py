"""Multi-pod dry-run: prove every (architecture x input shape x mesh) combination
lowers and compiles on the production meshes, and harvest roofline inputs.

MUST be run as a fresh process (device count is locked at first jax init):
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, CoCoDCConfig, get_config
from repro.core.fragments import make_fragmenter
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models import api

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# archs where f32 AdamW moments cannot fit a v5e pod: use bf16 moments (DESIGN.md)
BF16_MOMENT_ARCHS = {"llama3-405b"}


def collective_bytes(hlo_text: str):
    """Sum PER-DEVICE operand bytes of every collective op in post-SPMD HLO.
    Returns (total_bytes, per_op_kind dict, op_count)."""
    dtype_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                   "pred": 1, "c64": 8}
    per_kind = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    ty_re = re.compile(r"(\w+)\[([\d,]*)\]")

    def nbytes(ty, dims):
        n = dtype_bytes.get(ty, 4)
        for d in dims.split(","):
            if d:
                n *= int(d)
        return n

    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = .+? ([\w\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(1)
        kind = next((k for k in COLLECTIVE_OPS
                     if op == k or op.startswith(k + "-")), None)
        if kind is None or op.endswith("-done"):
            continue
        # operand types appear inside the call parens; fall back to output type
        paren = stripped[stripped.index(op + "("):]
        operand_tys = ty_re.findall(paren)
        if operand_tys:
            b = sum(nbytes(t, d) for t, d in operand_tys)
        else:
            out_ty = ty_re.search(stripped.split("=", 1)[1])
            b = nbytes(*out_ty.groups()) if out_ty else 0
        per_kind[kind] += b
        counts[kind] += 1
    total = sum(per_kind.values())
    return total, per_kind, counts


def pod_collective_present(hlo_text: str, mesh, *, ops=None) -> bool:
    """Pod-axis collectives have replica groups joining device ids that differ by
    the pod stride (=256 on the (2,16,16) mesh, pod-major). `ops` restricts the
    scan to specific op names (e.g. reductions); None = any collective line.

    Semantics note: a pod-spanning ALL-GATHER can be a benign GSPMD reshard
    (replicate-then-repartition preserves each pod's values); a pod-spanning
    ALL-REDUCE/REDUCE-SCATTER would MIX the pods' diverged replicas — that is the
    invariant the dry-run asserts on train/serve steps."""
    import numpy as np
    stride = mesh.devices.size // mesh.devices.shape[0]

    def group_spans_pods(groups) -> bool:
        return any(max(g) - min(g) >= stride for g in groups if len(g) >= 2)

    def line_matches(line: str) -> bool:
        if "replica_groups" not in line:
            return False
        if ops is None:
            return True
        if not any(f" {op}" in line or f"%{op}" in line or f"= {op}(" in line
                   or op + "(" in line for op in ops):
            return False
        # GSPMD lowers gather/scatter reshard fallbacks ("involuntary full
        # rematerialization") as masked all-reduce SUMS of disjoint per-pod
        # contributions — data movement, not semantic mixing. Exclude them.
        m = re.search(r'op_name="([^"]*)"', line)
        if m and any(k in m.group(1) for k in ("gather", "scatter",
                                               "dynamic")):
            return False
        return True

    for line in hlo_text.splitlines():
        if not line_matches(line):
            continue
        # explicit list format: replica_groups={{0,256},{1,257},...}
        m = re.search(r"replica_groups=\{\{(.*?)\}\}", line)
        if m:
            groups = [[int(x) for x in re.findall(r"\d+", grp)]
                      for grp in m.group(1).split("},{")]
            if group_spans_pods(groups):
                return True
        # iota format: replica_groups=[G,S]<=[d0,d1,...]T(p0,p1,...)
        m = re.search(
            r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
            line)
        if m:
            G, S = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            ids = np.arange(int(np.prod(dims)))
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.reshape(dims).transpose(perm).reshape(-1)
            if group_spans_pods(ids.reshape(G, S).tolist()):
                return True
    return False


def probe_config(cfg, depth_units: int):
    """Reduced-DEPTH (full-width) variant for roofline probes. depth_units is in
    layers (dense/moe/ssm), pattern groups (hybrid), or enc+dec layer pairs
    (audio). Probes are lowered UNROLLED so XLA cost analysis sees every layer
    (scan bodies are otherwise counted once — see EXPERIMENTS.md §Roofline)."""
    import dataclasses
    if cfg.block_pattern:
        n = depth_units * len(cfg.block_pattern)
        return dataclasses.replace(cfg, n_layers=n)
    if cfg.n_enc_layers:
        return dataclasses.replace(cfg, n_layers=depth_units,
                                   n_enc_layers=depth_units)
    return dataclasses.replace(cfg, n_layers=depth_units)


def depth_units_of(cfg) -> int:
    """Total depth units in the full config (matching probe_config scaling)."""
    if cfg.block_pattern:
        return cfg.n_layers // len(cfg.block_pattern)
    return cfg.n_layers


MOE_MEGATRON_OVERRIDES = [
    # §Perf iteration 3: Megatron row/column MoE sharding — contract over the
    # UNSHARDED d_model, shard d_ff; one all-reduce after w_down instead of
    # partial-sum ARs after w_gate AND w_up.
    (r".*moe/w_(gate|up)$", [__import__("jax").sharding.PartitionSpec(
        None, "model", None, "data")]),
    (r".*moe/w_down$", [__import__("jax").sharding.PartitionSpec(
        None, "model", "data", None)]),
]


def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool,
                include_sync: bool = True, verbose: bool = True,
                probe_depth: int | None = None, profile: str = "2d",
                moe_megatron: bool = False, sync_dtype: str = "float32",
                seq_parallel: bool = False):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    unroll = probe_depth is not None
    if unroll:
        cfg = probe_config(cfg, probe_depth)
    mesh = make_production_mesh(multi_pod=multi_pod)
    pods = mesh.devices.shape[0] if multi_pod else 0
    n_chips = mesh.devices.size
    moment_dtype = jnp.bfloat16 if cfg.name in BF16_MOMENT_ARCHS else jnp.float32

    result = {"arch": get_config(arch).name, "shape": shape_name,
              "mesh": "multi_pod" if multi_pod else "single_pod",
              "chips": n_chips, "status": "ok"}
    if unroll:
        result["probe_depth"] = probe_depth
        result["probe_layers"] = cfg.n_layers

    if shape.kind == "decode" and shape_name == "long_500k" and not cfg.supports_long_decode:
        result["status"] = "skipped"
        result["reason"] = "full-attention enc-dec: no sub-quadratic decode (DESIGN.md)"
        return result

    overrides = MOE_MEGATRON_OVERRIDES if moe_megatron else None
    if profile != "2d":
        result["profile"] = profile
    if moe_megatron:
        result["moe_megatron"] = True
    if seq_parallel:
        result["seq_parallel"] = True
    sds = steps_lib.input_specs(cfg, shape, pods=pods, moment_dtype=moment_dtype)
    shards = steps_lib.shardings_for(cfg, shape, mesh, pods=pods,
                                     moment_dtype=moment_dtype, profile=profile,
                                     overrides=overrides)

    t0 = time.time()
    with mesh:
        if shape.kind == "decode":
            window = cfg.long_decode_window if shape_name == "long_500k" else None
            fn = (steps_lib.make_pod_serve_step(cfg, window=window, unroll=unroll)
                  if multi_pod
                  else steps_lib.make_serve_step(cfg, window=window,
                                                 unroll=unroll))
            jf = jax.jit(fn, in_shardings=(shards["params"], shards["cache"],
                                           shards["tokens"]))
            lowered = jf.lower(sds["params"], sds["cache"], sds["tokens"])
        else:
            remat = shape.kind == "train"
            train = shape.kind == "train"
            remat = remat and not unroll   # probes measure the un-remat program
            if train:
                fn = (steps_lib.make_pod_train_step(cfg, remat=remat,
                                                    unroll=unroll,
                                                    seq_parallel=seq_parallel)
                      if multi_pod
                      else steps_lib.make_train_step(cfg, remat=remat,
                                                     unroll=unroll,
                                                     seq_parallel=seq_parallel))
                jf = jax.jit(fn, in_shardings=(shards["params"],
                                               shards["opt_state"],
                                               shards["batch"], shards["lr"]))
                lowered = jf.lower(sds["params"], sds["opt_state"], sds["batch"],
                                   sds["lr"])
            else:  # prefill: forward only (inference)
                def prefill_fn(params, batch):
                    h, aux = api.forward(cfg, params, batch, train=False,
                                         remat=False, unroll=unroll)
                    return h

                if multi_pod:
                    prefill_run = jax.vmap(prefill_fn, in_axes=(0, 0))
                else:
                    prefill_run = prefill_fn
                jf = jax.jit(prefill_run, in_shardings=(shards["params"],
                                                        shards["batch"]))
                batch_sds = {k: v for k, v in sds["batch"].items()
                             if k != "labels"}
                batch_shards = {k: v for k, v in shards["batch"].items()
                                if k != "labels"}
                jf = jax.jit(prefill_run, in_shardings=(shards["params"],
                                                        batch_shards))
                lowered = jf.lower(sds["params"], batch_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    cbytes, per_kind, counts = collective_bytes(hlo)
    result.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": float(cbytes),
        "collective_breakdown": {k: float(v) for k, v in per_kind.items() if v},
        "collective_counts": {k: v for k, v in counts.items() if v},
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
    })
    if multi_pod:
        result["pod_reduction_in_step"] = pod_collective_present(
            hlo, mesh, ops=("all-reduce", "reduce-scatter"))
        result["pod_reshard_in_step"] = pod_collective_present(hlo, mesh)

    # multi-pod: prove the segment-scanned execution engine's fused multi-step
    # program (lax.scan over the pod-vmapped train step) lowers and stays
    # pod-local, exactly like the single step it fuses
    if multi_pod and include_sync and shape.kind == "train" and not unroll:
        seg_n = 4
        seg_batch_sds = steps_lib.stack_sds(sds["batch"], seg_n)
        seg_batch_shards = jax.tree.map(
            lambda ns: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(None, *ns.spec)),
            shards["batch"])
        lrs_sds = jax.ShapeDtypeStruct((seg_n,), jnp.float32)
        seg_fn = steps_lib.make_pod_segment_step(cfg, remat=True)
        t0 = time.time()
        with mesh:
            jf = jax.jit(seg_fn, in_shardings=(shards["params"],
                                               shards["opt_state"],
                                               seg_batch_shards,
                                               jax.sharding.NamedSharding(
                                                   mesh,
                                                   jax.sharding.PartitionSpec())))
            seg_lowered = jf.lower(sds["params"], sds["opt_state"],
                                   seg_batch_sds, lrs_sds)
            seg_compiled = seg_lowered.compile()
        seg_hlo = seg_compiled.as_text()
        result["segment_steps"] = seg_n
        result["segment_compile_s"] = round(time.time() - t0, 1)
        result["segment_pod_reduction_in_step"] = pod_collective_present(
            seg_hlo, mesh, ops=("all-reduce", "reduce-scatter"))

    # multi-pod: also lower the CoCoDC fragment sync step (the cross-region
    # collective) and verify the pod all-reduce is present there
    if multi_pod and include_sync and shape.kind == "train" and not unroll:
        ccfg = CoCoDCConfig(num_workers=pods, sync_dtype=sync_dtype)
        params_sds = steps_lib.abstract_params(cfg)
        frag = make_fragmenter(cfg, params_sds, ccfg.num_fragments)
        sync = steps_lib.make_sync_step(cfg, ccfg, frag, 0)
        from repro.launch import sharding as shd
        pspec = shd.param_specs(params_sds, mesh)
        pstack = shd.named(mesh, shd.stack_spec(pspec))
        psingle = shd.named(mesh, pspec)
        stack_sds = steps_lib.stack_sds(params_sds, pods)
        snap_sds = jax.eval_shape(
            lambda t: frag.extract(t, 0, worker_axis=True), stack_sds)
        snap_shards = frag.extract_meta(pstack, 0)
        with mesh:
            jf = jax.jit(sync, in_shardings=(pstack, snap_shards, psingle,
                                             psingle))
            lowered_sync = jf.lower(stack_sds, snap_sds, params_sds, params_sds)
            compiled_sync = lowered_sync.compile()
        sync_hlo = compiled_sync.as_text()
        sbytes, skind, scount = collective_bytes(sync_hlo)
        result["sync_collective_bytes_per_device"] = float(sbytes)
        result["sync_pod_collective"] = pod_collective_present(
            sync_hlo, mesh, ops=("all-reduce", "reduce-scatter"))
        result["sync_collective_counts"] = {k: v for k, v in scount.items() if v}

    if verbose:
        print(json.dumps(result), flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="write JSONL results here")
    ap.add_argument("--probe", action="store_true",
                    help="also lower depth-1/2 unrolled probes (roofline FLOPs)")
    ap.add_argument("--profile", default="2d", choices=["2d", "dp"],
                    help="intra-pod sharding profile (perf iterations)")
    ap.add_argument("--moe-megatron", action="store_true",
                    help="Megatron row/column MoE expert sharding (perf iter)")
    ap.add_argument("--sync-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="WAN pseudo-gradient payload dtype (perf iter)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel residual sharding (perf iter)")
    args = ap.parse_args()

    pairs = []
    archs = [a for a in ARCH_IDS if a != "paper_150m"] if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                pairs.append((arch, shape, mp))

    jobs = []
    for arch, shape, mp in pairs:
        jobs.append((arch, shape, mp, None))
        if args.probe and not mp:
            jobs.append((arch, shape, mp, 1))
            jobs.append((arch, shape, mp, 2))
    results = []
    for arch, shape, mp, probe in jobs:
        try:
            r = dryrun_pair(arch, shape, multi_pod=mp, probe_depth=probe,
                            profile=args.profile,
                            moe_megatron=args.moe_megatron,
                            sync_dtype=args.sync_dtype,
                            seq_parallel=args.seq_parallel)
        except Exception as e:  # noqa: BLE001 — report, don't die mid-sweep
            r = {"arch": arch, "shape": shape,
                 "mesh": "multi_pod" if mp else "single_pod",
                 "status": "error", "error": f"{type(e).__name__}: {e}",
                 "trace": traceback.format_exc()[-2000:]}
            print(json.dumps({k: r[k] for k in ("arch", "shape", "mesh", "status",
                                                "error")}), flush=True)
        results.append(r)

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = f"{archs[0] if len(archs)==1 else 'all'}"
        path = os.path.join(args.out, f"dryrun_{tag}_{int(time.time())}.jsonl")
        with open(path, "w") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
        print(f"wrote {path}", file=sys.stderr)
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skipped")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"# dryrun: {ok} ok, {skip} skipped, {err} errors", file=sys.stderr)
    return 1 if err else 0


if __name__ == "__main__":
    sys.exit(main())
