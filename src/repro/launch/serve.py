"""Batched serving driver: prefill a batch of prompts, then decode with the
ring-buffer KV cache (the decode_32k / long_500k serve_step path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 4 --prompt-len 32 --gen-len 32

Loads params from --ckpt (theta_g of a training run) or random-inits. For SSM /
hybrid archs (no transformer prefill) the prompt is consumed token-by-token
through decode_step — O(1) state makes that the native path anyway.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api, transformer


def load_params(cfg, ckpt):
    if ckpt:
        from repro.checkpoint import load_pytree
        state = load_pytree(ckpt)
        if isinstance(state, dict) and state.get("format") == "trainer_state_v1":
            # full-run checkpoint (launch/train --ckpt): consensus model lives
            # in the serialized EngineState
            params = state["trainer_state"]["engine"]["theta_g"]
        else:
            params = state["theta_g"] if "theta_g" in state else state
        return jax.tree.map(jnp.asarray, params)
    return api.init_params(cfg, jax.random.PRNGKey(0))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = load_params(cfg, args.ckpt)
    B, P, G = args.batch, args.prompt_len, args.gen_len
    key = jax.random.PRNGKey(args.seed)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)

    cache_len = api.decode_cache_len(cfg, P + G)
    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    t0 = time.time()
    if cfg.family in ("dense", "moe", "vlm"):
        logits, cache = transformer.prefill(cfg, params, {"tokens": prompts},
                                            cache_len=max(cache_len, P + G))
    else:
        cache = api.init_cache(cfg, B, max(cache_len, P + G))
        for t in range(P):
            logits, cache = decode(params, cache, prompts[:, t])
    t_prefill = time.time() - t0
    print(f"prefill {B}x{P} tokens in {t_prefill:.2f}s "
          f"({B*P/max(t_prefill,1e-9):.0f} tok/s)")

    def sample(logits, key):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(key, logits / args.temperature).astype(
            jnp.int32)

    toks = sample(logits, key)
    outs = [toks]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(params, cache, toks)
        toks = sample(logits, jax.random.fold_in(key, i))
        outs.append(toks)
    dt = time.time() - t0
    gen = jnp.stack(outs, axis=1)
    print(f"decode {B}x{G} tokens in {dt:.2f}s ({B*G/max(dt,1e-9):.1f} tok/s)")
    for b in range(min(B, 4)):
        print(f"  seq{b}: {list(map(int, gen[b][:16]))}{'...' if G > 16 else ''}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
