"""Serving driver over the continuous-batching engine (`repro.serve`).

    PYTHONPATH=src python -m repro.launch.serve --arch bench_tiny \
        --mode continuous --slots 8 --requests 32 --temperature 0.8

Transformer families (dense/moe) run on `ServeEngine`: slotted KV cache,
chunked prefill interleaved with one jitted decode step over the full slot
plane, requests joining/leaving with zero recompiles. `--mode static` keeps
the old lock-step wave batching as a baseline. SSM / hybrid / audio archs
(no transformer prefill) keep the legacy token-by-token lock-step path —
O(1) state makes that the native path anyway.

Loads params from --ckpt (theta_g of a training run) or random-inits.
Fused-mode checkpoints (`fused_updates=True`) store theta_g as ONE flat
fragment plane — `load_params` rebuilds the run's Fragmenter from checkpoint
meta and unpacks the plane back into the per-leaf pytree.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api, transformer


def _unflatten_theta(cfg, theta, meta):
    """Fused-mode checkpoints serialize theta_g as a flat ``(total_rows,
    LANES)`` f32 fragment plane (engine_state stores every engine buffer
    that way). Rebuild the run's Fragmenter from checkpoint meta and unpack
    the plane into the per-leaf parameter pytree."""
    from repro.core.flatplane import LANES
    from repro.core.fragments import make_fragmenter

    theta = jnp.asarray(theta)
    if theta.ndim != 2 or theta.shape[-1] != LANES:
        raise ValueError(
            f"fused checkpoint theta_g has shape {theta.shape}, expected a "
            f"(total_rows, {LANES}) flat fragment plane")
    shape = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    frag = make_fragmenter(cfg, shape, int(meta.get("num_fragments", 1)),
                           strategy=meta.get("fragment_strategy", "strided"))
    if frag.flat.total_rows != theta.shape[0]:
        raise ValueError(
            f"flat theta_g has {theta.shape[0]} rows but arch "
            f"{cfg.name!r} with num_fragments={meta.get('num_fragments')} "
            f"strategy={meta.get('fragment_strategy')!r} needs "
            f"{frag.flat.total_rows} — checkpoint/arch mismatch")
    template = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shape)
    return frag.flat.unpack_full(template, theta)


def load_params(cfg, ckpt):
    if ckpt:
        from repro.checkpoint import load_pytree
        state = load_pytree(ckpt)
        if isinstance(state, dict) and state.get("format") == "trainer_state_v1":
            # full-run checkpoint (launch/train --ckpt): consensus model lives
            # in the serialized EngineState
            meta = state.get("meta", {})
            arch = meta.get("arch")
            if arch and arch != cfg.name:
                raise ValueError(f"checkpoint was trained on arch {arch!r}, "
                                 f"serving requested {cfg.name!r}")
            params = state["trainer_state"]["engine"]["theta_g"]
            if meta.get("fused_updates") and not isinstance(params, dict):
                return _unflatten_theta(cfg, params, meta)
        else:
            params = state["theta_g"] if "theta_g" in state else state
        return jax.tree.map(jnp.asarray, params)
    return api.init_params(cfg, jax.random.PRNGKey(0))


def _serve_engine(cfg, params, args):
    """Transformer serving on the slot-plane engine (continuous or static)."""
    from repro.serve import Request, ServeEngine

    rng = np.random.default_rng(args.seed)
    reqs = []
    t = 0.0
    for i in range(args.requests):
        t += float(rng.exponential(1.0 / max(args.rps, 1e-9)))
        P = int(rng.integers(max(2, args.prompt_len // 2), args.prompt_len + 1))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=P).astype(np.int32),
            max_new_tokens=int(rng.integers(max(1, args.gen_len // 2),
                                            args.gen_len + 1)),
            arrival_s=t))

    cache_len = max(args.cache_len,
                    api.decode_cache_len(cfg, args.prompt_len + args.gen_len))
    eng = ServeEngine(cfg, params, n_slots=args.slots, cache_len=cache_len,
                      max_prompt=args.prompt_len,
                      prefill_chunk=args.prefill_chunk, mode=args.mode,
                      temperature=args.temperature, seed=args.seed,
                      attn_impl=args.attn_impl)
    recs = eng.run_trace(reqs)
    s = eng.stats()
    print(f"mode={args.mode} slots={args.slots} completed={s['completed']}"
          f"/{len(reqs)}")
    print(f"  virtual: {s['tok_per_s']:.1f} tok/s  occupancy "
          f"{s['occupancy']:.2f}  ttft p50/p99 {s['ttft_p50_s']*1e3:.0f}/"
          f"{s['ttft_p99_s']*1e3:.0f} ms  tok-latency p99 "
          f"{s['tok_latency_p99_s']*1e3:.1f} ms")
    print(f"  dispatches: {s['decode_dispatches']} decode "
          f"(traced {eng.decode_trace_count()}x), "
          f"{s['prefill_dispatches']} prefill; wall {s['wall_s']:.2f}s")
    for rec in recs[:4]:
        head = rec.tokens[:16]
        print(f"  req{rec.rid}: {head}{'...' if len(rec.tokens) > 16 else ''}")
    return 0


def _serve_lockstep(cfg, params, args):
    """Legacy lock-step path for archs without transformer prefill: batch of
    identical-length prompts, token-by-token through decode_step."""
    B, P, G = args.slots, args.prompt_len, args.gen_len
    key = jax.random.PRNGKey(args.seed)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
    cache_len = api.decode_cache_len(cfg, P + G)
    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    t0 = time.time()
    cache = api.init_cache(cfg, B, max(cache_len, P + G))
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t])
    t_prefill = time.time() - t0
    print(f"prefill {B}x{P} tokens in {t_prefill:.2f}s "
          f"({B*P/max(t_prefill,1e-9):.0f} tok/s)")

    # a dedicated sampling stream, never the key that generated the prompts
    sample_key = jax.random.fold_in(key, 0x5A17)

    def sample(logits, i):
        k = jax.random.fold_in(sample_key, i)
        if args.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(k, logits / args.temperature).astype(
            jnp.int32)

    toks = sample(logits, 0)
    outs = [toks]
    t0 = time.time()
    for i in range(1, G):
        logits, cache = decode(params, cache, toks)
        toks = sample(logits, i)
        outs.append(toks)
    dt = time.time() - t0
    gen = jnp.stack(outs, axis=1)
    print(f"decode {B}x{G} tokens in {dt:.2f}s ({B*G/max(dt,1e-9):.1f} tok/s)")
    for b in range(min(B, 4)):
        print(f"  seq{b}: {list(map(int, gen[b][:16]))}"
              f"{'...' if G > 16 else ''}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots (batch lanes)")
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rps", type=float, default=4.0,
                    help="mean request arrival rate on the virtual clock")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "ref", "flash"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = load_params(cfg, args.ckpt)
    if cfg.family in ("dense", "moe"):
        return _serve_engine(cfg, params, args)
    return _serve_lockstep(cfg, params, args)


if __name__ == "__main__":
    sys.exit(main())
