"""Rule-based parameter/activation sharding.

Intra-pod strategy: 2-D sharded weights — FSDP over `data` x tensor-parallel over
`model` (MaxText-style), expert-parallel MoE over `model`, vocab-parallel
embeddings/head. Per-leaf rules are ordered candidate PartitionSpecs; the first
whose sharded dims divide the mesh axis sizes wins (covers the non-power-of-two
oddballs: 40 experts, kv=10 heads, 256206 vocab).

The worker/pod axis is NOT assigned here: `stack_spec` prepends P('pod') for
worker-stacked pytrees (each pod = one diverged CoCoDC replica).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# (path regex, [candidate specs]) — specs given for the array WITHOUT the worker
# axis; trailing dims beyond the spec are replicated. "$L" marks the stacked layer
# axis (always replicated).
_RULES = [
    # attention projections (stacked): (L, D, H*hd) / (L, H*hd, D)
    (r".*(attn|self_attn|cross_attn)/w[qkv]$", [P(None, "data", "model"),
                                                P(None, None, "model"),
                                                P(None, "data", None)]),
    (r".*(attn|self_attn|cross_attn)/wo$", [P(None, "model", "data"),
                                            P(None, "model", None),
                                            P(None, None, "data")]),
    (r".*(attn|self_attn|cross_attn)/b[qkv]$", [P(None, "model"), P(None, None)]),
    (r".*(attn|self_attn|cross_attn)/bo$", [P(None, None)]),
    (r".*(q_norm|k_norm)$", [P(None, None)]),
    # dense MLP
    (r".*mlp/w_(gate|up)$", [P(None, "data", "model"), P(None, None, "model"),
                             P(None, "data", None)]),
    (r".*mlp/w_down$", [P(None, "model", "data"), P(None, "model", None),
                        P(None, None, "data")]),
    # MoE: experts over `model` (expert parallelism), fall back to ffn sharding
    (r".*moe/router$", [P(None, "data", None), P(None, None, None)]),
    (r".*moe/w_(gate|up)$", [P(None, "model", "data", None),
                             P(None, None, "data", "model"),
                             P(None, None, "data", None)]),
    (r".*moe/w_down$", [P(None, "model", None, "data"),
                        P(None, None, "model", "data"),
                        P(None, None, None, "data")]),
    # rwkv6 time/channel mix
    (r".*tm/w[rkvg]$", [P(None, "data", "model"), P(None, "data", None)]),
    (r".*tm/wo$", [P(None, "model", "data"), P(None, None, "data")]),
    (r".*tm/lora_a$", [P(None, None, "data", None)]),
    (r".*tm/lora_b$", [P(None, None, None, "data")]),
    (r".*tm/w[ab]$", [P(None, "data", None)]),
    (r".*cm/wk$", [P(None, "data", "model"), P(None, "data", None)]),
    (r".*cm/wv$", [P(None, "model", "data"), P(None, None, "data")]),
    (r".*cm/wr$", [P(None, "data", "model"), P(None, "data", None)]),
    # rglru mixer
    (r".*mixer/(w_gate_br|w_in|wa|wx)$", [P(None, "data", "model"),
                                          P(None, "data", None)]),
    (r".*mixer/w_out$", [P(None, "model", "data"), P(None, None, "data")]),
    (r".*mixer/conv_w$", [P(None, None, "model"), P(None, None, None)]),
    (r".*mixer/(conv_b|ba|bx|lam)$", [P(None, "model"), P(None, None)]),
    # embeddings / heads. The embedding table shards on d_model only: a gather
    # from a vocab-sharded table triggers GSPMD's "involuntary full
    # rematerialization" (replicate-then-repartition across the whole mesh,
    # including pod) — sharding the non-gathered dim keeps the lookup local.
    # (d_model over `model` ONLY: adding `data` conflicts with the batch-dim
    # sharding of the gather output and makes GSPMD replicate the batch — 7x
    # redundant FLOPs measured; see EXPERIMENTS.md §Perf iteration 1)
    (r"^embed$", [P(None, "model"), P(None, "data"), P(None, None)]),
    (r"^lm_head$", [P("data", "model"), P("model", "data"), P("data", None),
                    P(None, None)]),
    (r"^frame_proj$", [P(None, "model"), P(None, None)]),
    (r"^projector/w[12]$", [P("data", "model"), P(None, "model"), P(None, None)]),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _fits(spec: P, shape, axis_sizes) -> bool:
    if len(spec) > len(shape):
        return False
    for dim, names in zip(shape, spec):
        if names is None:
            continue
        names = names if isinstance(names, tuple) else (names,)
        total = 1
        for n in names:
            total *= axis_sizes.get(n, 1)
        if dim % total != 0:
            return False
    return True


def spec_for_leaf(path: str, shape, axis_sizes) -> P:
    for pat, candidates in _RULES:
        if re.match(pat, path):
            for spec in candidates:
                if _fits(spec, shape, axis_sizes):
                    return spec
            return P()
    # default: replicate small tensors; try to FSDP-shard big 2D+ ones on dim -2/-1.
    # Leaves under a layer stack NEVER shard dim 0 (it is the scan/layer axis and
    # fragment extraction slices it).
    layered = path.split("/")[0] in ("layers", "encoder", "decoder", "rem",
                                     "groups")
    if len(shape) >= 3 or (len(shape) == 2 and not layered):
        for spec in (P(*([None] * (len(shape) - 2) + ["data", "model"])),
                     P(*([None] * (len(shape) - 2) + [None, "model"])),
                     P(*([None] * (len(shape) - 2) + ["data", None]))):
            if _fits(spec, shape, axis_sizes):
                return spec
    elif len(shape) == 2:  # layered vector params (norms, decays, biases)
        for spec in (P(None, "model"), P(None, "data")):
            if _fits(spec, shape, axis_sizes):
                return spec
    return P()


def param_specs(params_shape, mesh, *, profile: str = "2d",
                overrides=None) -> object:
    """Pytree of PartitionSpec matching params (no worker axis).

    profile:
      "2d"  — FSDP('data') x TP('model') weight sharding (default; baseline).
      "dp"  — pure data parallelism: params replicated, batch over BOTH axes.
              Beyond-paper optimization for sub-1B archs where TP=16 makes the
              per-device matmuls tiny and collective-bound (§Perf iteration 2).
    overrides: list of (regex, [candidate specs]) consulted before _RULES —
      used by perf iterations to test alternative layouts without forking the
      rule table.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fn(path, leaf):
        p = _path_str(path)
        if profile == "dp":
            return P()
        if overrides:
            for pat, candidates in overrides:
                if re.match(pat, p):
                    for spec in candidates:
                        if _fits(spec, leaf.shape, axis_sizes):
                            return spec
                    return P()
        return spec_for_leaf(p, leaf.shape, axis_sizes)

    return jax.tree_util.tree_map_with_path(fn, params_shape)


def stack_spec(spec_tree, axis_name: str = "pod"):
    """Prepend the worker/pod axis to every spec (for worker-stacked pytrees)."""
    return jax.tree.map(lambda s: P(axis_name, *s), spec_tree)


def batch_specs(batch_shape, mesh, *, pod: bool = False,
                profile: str = "2d") -> object:
    """Batch-dim sharding over ('pod','data') — or ('pod','data','model') for
    the pure-DP profile — with divisibility fallback."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = ("data", "model") if profile == "dp" else ("data",)

    def fn(leaf):
        b = leaf.shape[1] if pod else leaf.shape[0]
        total = 1
        for a in dp_axes:
            total *= axis_sizes.get(a, 1)
        if b % total == 0:
            body = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        elif b % axis_sizes.get("data", 1) == 0:
            body = "data"
        else:
            body = None
        dims = [body] + [None] * (len(leaf.shape) - (2 if pod else 1))
        if pod:
            return P("pod", *dims)
        return P(*dims)

    return jax.tree.map(fn, batch_shape)


def cache_specs(cache_shape, mesh, *, pod: bool = False) -> object:
    """KV-cache/state sharding: batch dim over `data` when divisible, head/expert
    dims over `model` when divisible, replicate otherwise. Cache layouts:
    (L, B, C, KV, hd) / rwkv (L, B, ...) / scalars."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fn(leaf):
        shape = leaf.shape
        off = 1 if pod else 0
        dims = [None] * len(shape)
        if pod:
            dims[0] = "pod"
        # find the batch dim: axis off+1 for (L,B,...) layouts of rank>=3
        if len(shape) >= off + 3:
            bdim = off + 1
            if shape[bdim] % axis_sizes.get("data", 1) == 0:
                dims[bdim] = "data"
            # shard a trailing "heads-like" dim over model if divisible
            for d in range(len(shape) - 2, bdim, -1):
                if shape[d] % axis_sizes.get("model", 1) == 0 and shape[d] >= axis_sizes.get("model", 1):
                    dims[d] = "model"
                    break
        return P(*dims)

    return jax.tree.map(fn, cache_shape)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def recommended_profile(param_count: int, mesh) -> str:
    """Pick the intra-pod sharding profile (§Perf iteration 2): below ~2B params
    the per-device TP matmuls are too small to amortize the activation
    all-reduces and pure DP wins 84x on the collective term; above that the 2-D
    FSDP x TP layout is required for memory anyway."""
    n_chips = mesh.devices.size if hasattr(mesh, "devices") else 256
    # DP must fit params + f32 AdamW moments replicated: ~16 bytes/param
    fits_replicated = param_count * 16 <= 12e9   # leave ~4 GB for activations
    return "dp" if (param_count < 2e9 and fits_replicated) else "2d"
