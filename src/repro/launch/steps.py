"""Jitted step functions + abstract input specs for launch/dry-run.

  train_step(params, opt_state, batch, lr)      — inner AdamW step (one worker)
  pod_train_step                                — worker-stacked (leading pod axis)
  serve_step(params, cache, tokens)             — one-token decode
  sync_step(params_stack, theta_g, momentum)    — CoCoDC fragment sync: pseudo-
      gradient mean over the pod axis (THE cross-region collective), outer
      Nesterov update, Algorithm-1 delay compensation. Used by the multi-pod
      dry-run to prove the pod-axis collective lowers.

All input specs are ShapeDtypeStructs (no allocation); shardings come from
launch/sharding.py rules.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import CoCoDCConfig, InputShape, ModelConfig
from repro.core import delay_comp as dc_lib
from repro.core import engine_state as es
from repro.core import outer_opt
from repro.launch import sharding as shd
from repro.models import api
from repro.optim import adamw_init, adamw_update


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(api.init_params, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(params_sds, moment_dtype=jnp.float32):
    return jax.eval_shape(
        functools.partial(adamw_init, moment_dtype=moment_dtype), params_sds)


def abstract_batch(cfg: ModelConfig, shape: InputShape,
                   batch_override: Optional[int] = None):
    shapes = api.batch_shapes(cfg, shape, batch_override)
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int):
    cache_len = api.decode_cache_len(cfg, seq_len)
    return jax.eval_shape(
        functools.partial(api.init_cache, cfg, batch, cache_len))


def stack_sds(tree, m: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((m,) + s.shape, s.dtype), tree)


def input_specs(cfg: ModelConfig, shape: InputShape, *, pods: int = 0,
                moment_dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for one (arch x input-shape) dry-run.
    pods=0 -> single-pod (no worker axis). Returns dict by step kind."""
    params = abstract_params(cfg)
    if shape.kind == "decode":
        per_pod_batch = shape.global_batch if pods == 0 else max(
            1, shape.global_batch // pods)
        cache = abstract_cache(cfg, per_pod_batch, shape.seq_len)
        tokens = jax.ShapeDtypeStruct((per_pod_batch,), jnp.int32)
        if pods:
            params = stack_sds(params, pods)
            cache = stack_sds(cache, pods)
            tokens = jax.ShapeDtypeStruct((pods, per_pod_batch), jnp.int32)
        return {"params": params, "cache": cache, "tokens": tokens}
    batch_override = None if pods == 0 else max(1, shape.global_batch // pods)
    batch = abstract_batch(cfg, shape, batch_override)
    opt = abstract_opt_state(params, moment_dtype)
    if pods:
        params = stack_sds(params, pods)
        opt = stack_sds(opt, pods)
        batch = stack_sds(batch, pods)
    return {"params": params, "opt_state": opt, "batch": batch,
            "lr": jax.ShapeDtypeStruct((), jnp.float32)}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, *, weight_decay: float = 0.1,
                    xent_chunk: int = 512, remat: bool = True,
                    unroll: bool = False, seq_parallel: bool = False):
    kw = {"seq_parallel": True} if seq_parallel else {}

    def train_step(params, opt_state, batch, lr):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss_fn(cfg, p, batch, remat=remat,
                                  xent_chunk=xent_chunk, unroll=unroll, **kw),
            has_aux=True)(params)
        params, opt_state = adamw_update(grads, opt_state, params, lr,
                                         weight_decay=weight_decay)
        return params, opt_state, loss

    return train_step


def make_pod_train_step(cfg: ModelConfig, **kw):
    """Worker-stacked train step: vmap over the leading pod axis. Pod-local by
    construction — the dry-run asserts its HLO has no pod-axis collective."""
    step = make_train_step(cfg, **kw)
    return jax.vmap(step, in_axes=(0, 0, 0, None))


def make_segment_step(cfg: ModelConfig, **kw):
    """Scan-compatible multi-step transition (one worker): runs every inner
    step of a segment under one `lax.scan`, carrying (params, opt_state) and
    consuming a step-major batch segment (leaves (n, ...)) plus a per-step LR
    array (n,). This is the fused program the segment-scanned execution engine
    dispatches between protocol events."""
    step = make_train_step(cfg, **kw)

    def segment_step(params, opt_state, batch_seg, lrs):
        def body(carry, xs):
            batch, lr = xs
            p, o, loss = step(carry[0], carry[1], batch, lr)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), (batch_seg, lrs))
        return params, opt_state, losses

    return segment_step


def make_pod_segment_step(cfg: ModelConfig, **kw):
    """Worker-stacked fused segment: vmap of the scanned segment over the pod
    axis. Batch segments are step-major with the pod axis second — leaves
    (n, pods, B, T) — matching data/pipeline.stacked_segment; LR is shared.
    Pod-local like the single step (dry-run asserts no pod-axis reduction)."""
    seg = make_segment_step(cfg, **kw)
    return jax.vmap(seg, in_axes=(0, 0, 1, None))


def make_serve_step(cfg: ModelConfig, *, window: Optional[int] = None,
                    unroll: bool = False):
    def serve_step(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens, window=window,
                               unroll=unroll)

    return serve_step


def make_pod_serve_step(cfg: ModelConfig, **kw):
    return jax.vmap(make_serve_step(cfg, **kw), in_axes=(0, 0, 0))


def make_sync_step(cfg: ModelConfig, ccfg: CoCoDCConfig, fragmenter, frag_id: int):
    """One fragment synchronization (initiate+deliver fused for lowering):
      delta   = mean_pods(theta^m_p - theta^g_p)        <- pod all-reduce
      theta^g = Nesterov(theta^g, delta)
      theta^m = DelayComp(theta^m_now, theta^m_snap, theta^g)   (Algorithm 1)
    params_snapshot is the t_p worker-local fragment state."""

    def sync_step(params_stack, params_snapshot_frag, theta_g, momentum):
        frag_now = fragmenter.extract(params_stack, frag_id, worker_axis=True)
        g_frag = fragmenter.extract(theta_g, frag_id)
        m_frag = fragmenter.extract(momentum, frag_id)
        # pseudo-gradients cross the WAN in ccfg.sync_dtype (bf16 halves the
        # cross-region payload); accumulation back in f32. barrier=True keeps
        # the collective itself in sync_dt: without it XLA hoists the f32
        # upcast ahead of the all-reduce (convert-of-sum == sum-of-converts)
        # and the wire format silently stays f32.
        delta_avg = es.pseudograd_mean(
            frag_now, g_frag, jnp.ones((ccfg.num_workers,), jnp.float32),
            sync_dtype=ccfg.sync_dtype, topk_frac=ccfg.sync_topk_frac,
            barrier=jnp.dtype(ccfg.sync_dtype) != jnp.float32)
        new_g, new_m = outer_opt.nesterov_update(
            g_frag, m_frag, delta_avg, lr=ccfg.outer_lr, mu=ccfg.outer_momentum)
        compensated = dc_lib.compensate(
            frag_now, params_snapshot_frag,
            jax.tree.map(lambda g: None if g is None else g[None], new_g,
                         is_leaf=lambda x: x is None),
            tau=float(ccfg.overlap_depth), lam=ccfg.comp_lambda,
            H=float(ccfg.local_steps), sign=ccfg.eq4_sign, impl="ref")
        params_stack = fragmenter.insert(params_stack, frag_id, compensated,
                                         worker_axis=True)
        theta_g = fragmenter.insert(theta_g, frag_id, new_g)
        momentum = fragmenter.insert(momentum, frag_id, new_m)
        return params_stack, theta_g, momentum

    return sync_step


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------


def shardings_for(cfg: ModelConfig, shape: InputShape, mesh, *,
                  pods: int = 0, moment_dtype=jnp.float32, profile: str = "2d",
                  overrides=None):
    """NamedSharding pytrees for the step inputs (matching input_specs)."""
    pod = pods > 0
    params_sds = abstract_params(cfg)
    pspec = shd.param_specs(params_sds, mesh, profile=profile,
                            overrides=overrides)
    if pod:
        pspec = shd.stack_spec(pspec)
    out = {}
    if shape.kind == "decode":
        per_pod_batch = shape.global_batch if pods == 0 else max(
            1, shape.global_batch // pods)
        cache_sds = abstract_cache(cfg, per_pod_batch, shape.seq_len)
        if pod:
            cache_sds = stack_sds(cache_sds, pods)
        cspec = shd.cache_specs(cache_sds, mesh, pod=pod)
        tok_spec = P("pod", None) if pod else P(None)
        out = {"params": pspec, "cache": cspec, "tokens": tok_spec}
    else:
        batch_override = None if pods == 0 else max(1, shape.global_batch // pods)
        batch_sds = abstract_batch(cfg, shape, batch_override)
        if pod:
            batch_sds = stack_sds(batch_sds, pods)
        bspec = shd.batch_specs(batch_sds, mesh, pod=pod, profile=profile)
        ospec = jax.eval_shape(
            functools.partial(adamw_init, moment_dtype=moment_dtype), params_sds)
        ospec = jax.tree.map(lambda s: P(), ospec)  # overwritten below
        # optimizer moments shard exactly like params; count is replicated
        pspec_noworker = shd.param_specs(params_sds, mesh, profile=profile,
                                         overrides=overrides)
        mspec = {"mu": pspec_noworker, "nu": pspec_noworker, "count": P()}
        if pod:
            mspec = {"mu": shd.stack_spec(mspec["mu"]),
                     "nu": shd.stack_spec(mspec["nu"]), "count": P()}
        from repro.optim.adamw import AdamWState
        opt_spec = AdamWState(mu=mspec["mu"], nu=mspec["nu"], count=mspec["count"])
        out = {"params": pspec, "opt_state": opt_spec, "batch": bspec,
               "lr": P()}
    return jax.tree.map(lambda s: NamedSharding(mesh, s), out,
                        is_leaf=lambda x: isinstance(x, P))
