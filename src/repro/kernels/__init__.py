"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (handles padding/reshapes, interpret fallback)
  ref.py    — pure-jnp oracle used by tests and as the CPU execution path

Kernels:
  delay_comp      — fused CoCoDC Algorithm-1 update (the paper's per-sync hot-spot)
  flash_attention — blockwise causal/sliding-window GQA attention
  rglru_scan      — chunked RG-LRU linear recurrence (Griffin/RecurrentGemma)
  rwkv6_scan      — chunked RWKV-6 WKV recurrence (matrix-valued head state)
  rms_norm        — fused RMSNorm (one HBM pass)
  flash_decode    — one-token GQA attention over ring-buffer KV caches (serving)
"""
