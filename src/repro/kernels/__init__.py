"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (handles padding/reshapes, interpret fallback)
  ref.py    — pure-jnp oracle used by tests and as the CPU execution path

Kernels:
  delay_comp      — fused CoCoDC Algorithm-1 update (the paper's per-sync hot-spot)
  flash_attention — blockwise causal/sliding-window GQA attention
  rglru_scan      — chunked RG-LRU linear recurrence (Griffin/RecurrentGemma)
  rwkv6_scan      — chunked RWKV-6 WKV recurrence (matrix-valued head state)
  rms_norm        — fused RMSNorm (one HBM pass)
  flash_decode    — one-token GQA attention over ring-buffer KV caches (serving)
  delta_codec     — fused per-block absmax int8/int4 quantize+pack and
                    dequantize+unpack for the WAN delta wire format
  outer_update    — fused outer Nesterov step + fused delivery (Eq. 3 blend /
                    Algorithm-1 compensation + offline masking) over the flat
                    fragment plane — one dispatch per protocol transition

`tpu_compiler_params` papers over the Pallas API rename: the TPU compiler-params
class is `pltpu.TPUCompilerParams` up to jax 0.4.x and `pltpu.CompilerParams`
from jax 0.5+. Kernels import the alias instead of naming either directly.
"""
from jax.experimental.pallas import tpu as _pltpu

# version-compatible alias (TPUCompilerParams was renamed to CompilerParams)
tpu_compiler_params = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def is_cpu() -> bool:
    """True when the default JAX backend is CPU — every kernel wrapper uses
    this single probe to pick interpret mode (and the big-array oracle
    shortcut) instead of re-implementing its own backend check."""
    import jax
    return jax.default_backend() == "cpu"


def stream_kernel_specs() -> "list[dict]":
    """Analytic per-element cost model of every PROTOCOL STREAM kernel — the
    single-pass HBM streams the engine dispatches per transition (delta wire
    codec, fused outer update/delivery). benchmarks/roofline.py and
    benchmarks/kernels.py iterate THIS list instead of hardcoding entries, so
    a new stream kernel lands on the roofline by registering here.

    Each entry: kernel name, flops_per_elem, bytes_per_elem (HBM read+write
    per processed element, f32 operands unless stated). All entries sit far
    left of the v5e ridge (~241 flop/B) — these kernels are bandwidth, not
    compute."""
    from repro.kernels.delta_codec.ops import CODEC_BITS
    specs = []
    for codec, bits in sorted(CODEC_BITS.items()):
        block = 256
        # ~3 flops/elem: absmax-reduce share, scale multiply, round/clip
        specs.append({"kernel": f"delta_codec_{codec}_encode",
                      "flops_per_elem": 3.0,
                      "bytes_per_elem": 4 + bits / 8 + 4 / block})
        specs.append({"kernel": f"delta_codec_{codec}_decode",
                      "flops_per_elem": 3.0,
                      "bytes_per_elem": bits / 8 + 4 / block + 4})
    # outer_update/nesterov: read theta+momentum+delta, write theta'+momentum'
    # (4 flops: mu*m, +d, d+mu*m_new -> *lr, +theta ~ 5 mul/add)
    specs.append({"kernel": "outer_update_nesterov",
                  "flops_per_elem": 5.0,
                  "bytes_per_elem": 3 * 4 + 2 * 4})
    # outer_update/deliver, per worker-stacked element: blend reads local +
    # the broadcast global fragment, writes local' (3 flops + select);
    # compensate additionally streams the initiation snapshot (~8 flops:
    # 2 sub, 2 div-as-stream, 3 mul, 2 add, select)
    specs.append({"kernel": "outer_update_deliver_blend",
                  "flops_per_elem": 4.0,
                  "bytes_per_elem": 2 * 4 + 4})
    specs.append({"kernel": "outer_update_deliver_compensate",
                  "flops_per_elem": 9.0,
                  "bytes_per_elem": 3 * 4 + 4})
    return specs
