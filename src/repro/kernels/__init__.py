"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (handles padding/reshapes, interpret fallback)
  ref.py    — pure-jnp oracle used by tests and as the CPU execution path

Kernels:
  delay_comp      — fused CoCoDC Algorithm-1 update (the paper's per-sync hot-spot)
  flash_attention — blockwise causal/sliding-window GQA attention
  rglru_scan      — chunked RG-LRU linear recurrence (Griffin/RecurrentGemma)
  rwkv6_scan      — chunked RWKV-6 WKV recurrence (matrix-valued head state)
  rms_norm        — fused RMSNorm (one HBM pass)
  flash_decode    — one-token GQA attention over ring-buffer KV caches (serving)
  delta_codec     — fused per-block absmax int8/int4 quantize+pack and
                    dequantize+unpack for the WAN delta wire format

`tpu_compiler_params` papers over the Pallas API rename: the TPU compiler-params
class is `pltpu.TPUCompilerParams` up to jax 0.4.x and `pltpu.CompilerParams`
from jax 0.5+. Kernels import the alias instead of naming either directly.
"""
from jax.experimental.pallas import tpu as _pltpu

# version-compatible alias (TPUCompilerParams was renamed to CompilerParams)
tpu_compiler_params = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def is_cpu() -> bool:
    """True when the default JAX backend is CPU — every kernel wrapper uses
    this single probe to pick interpret mode (and the big-array oracle
    shortcut) instead of re-implementing its own backend check."""
    import jax
    return jax.default_backend() == "cpu"
