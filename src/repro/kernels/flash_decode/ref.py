"""Oracle: one-token GQA attention over a (ring-buffer) KV cache, via the shared
reference attention."""
from __future__ import annotations

from repro.models.layers import gqa_attention


def flash_decode_ref(q, k_cache, v_cache, kv_positions, q_position, *,
                     window=None):
    """q: (B, H, hd); caches: (B, C, KV, hd); kv_positions: (C,) int32 (-1 =
    empty slot); q_position: scalar int32. Returns (B, H, hd)."""
    import jax.numpy as jnp
    B = q.shape[0]
    C = k_cache.shape[1]
    q4 = q[:, None]                                     # (B, 1, H, hd)
    qpos = jnp.broadcast_to(q_position[None, None], (B, 1)).astype(jnp.int32)
    kvpos = jnp.broadcast_to(kv_positions[None], (B, C))
    out = gqa_attention(q4, k_cache, v_cache, causal=True, window=window,
                        q_positions=qpos, kv_positions=kvpos,
                        kv_mask=kvpos >= 0)
    return out[:, 0]
