"""Oracle: one-token GQA attention over a (ring-buffer) KV cache, via the shared
reference attention. Accepts shared (C,)/() or per-slot (B, C)/(B,) positions,
like the kernel wrapper."""
from __future__ import annotations

from repro.models.layers import gqa_attention


def flash_decode_ref(q, k_cache, v_cache, kv_positions, q_position, *,
                     window=None):
    """q: (B, H, hd); caches: (B, C, KV, hd); kv_positions: (C,) or (B, C)
    int32 (-1 = empty slot); q_position: () or (B,) int32. Returns (B, H, hd)."""
    import jax.numpy as jnp
    B = q.shape[0]
    C = k_cache.shape[1]
    q4 = q[:, None]                                     # (B, 1, H, hd)
    qpos = jnp.asarray(q_position, jnp.int32)
    if qpos.ndim == 0:
        qpos = jnp.broadcast_to(qpos[None], (B,))
    kvpos = jnp.asarray(kv_positions, jnp.int32)
    if kvpos.ndim == 1:
        kvpos = jnp.broadcast_to(kvpos[None], (B, C))
    out = gqa_attention(q4, k_cache, v_cache, causal=True, window=window,
                        q_positions=qpos[:, None], kv_positions=kvpos,
                        kv_mask=kvpos >= 0)
    return out[:, 0]
