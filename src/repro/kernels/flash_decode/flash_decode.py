"""Pallas TPU kernel: one-token (decode) GQA attention over a ring-buffer KV
cache — the serving hot-spot for decode_32k / long_500k.

Per (batch, kv-head): all `group = H/KV` query heads that share the kv head are
processed TOGETHER as a (group, hd) panel so the cache is read from HBM exactly
once per kv head. The grid walks KV-cache blocks SEQUENTIALLY (`arbitrary`)
carrying online-softmax stats (m, l, acc) in VMEM scratch; validity/causality/
window masking is computed from the cache's position map (ring buffers leave
stale or empty slots — masked via kv_pos).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params

BLOCK_C = 512
NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref, m_scr, l_scr,
            acc_scr, *, bc, n_c, window, scale):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * scale          # (G, hd)
    k = k_ref[...].astype(jnp.float32)                  # (BC, hd)
    v = v_ref[...].astype(jnp.float32)
    kv_pos = pos_ref[...][0]                            # (BC,) int32
    qpos = qpos_ref[pl.program_id(0)]

    s = q @ k.T                                         # (G, BC)
    valid = (kv_pos >= 0) & (kv_pos <= qpos)
    if window is not None:
        valid &= (qpos - kv_pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(valid[None, :], jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(ci == n_c - 1)
    def _fin():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bc", "interpret"))
def flash_decode_bkv(q, k_cache, v_cache, kv_positions, q_position, *,
                     window=None, bc=BLOCK_C, interpret=False):
    """q: (B, KV, G, hd) — query heads grouped by kv head;
    caches: (B, KV, C, hd); kv_positions: (B, C) int32 (-1 = empty slot);
    q_position: (B,) int32 — each batch lane carries its OWN position map, so
    a slotted serving cache can decode requests at different depths in one
    dispatch. C % bc == 0. Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    C = k_cache.shape[2]
    bc = min(bc, C)
    n_c = C // bc
    scale = 1.0 / math.sqrt(hd)
    grid = (B, KV, n_c)

    q_spec = pl.BlockSpec((1, 1, G, hd), lambda b, h, c: (b, h, 0, 0))
    kv_spec = pl.BlockSpec((1, 1, bc, hd), lambda b, h, c: (b, h, c, 0))
    pos_spec = pl.BlockSpec((1, bc), lambda b, h, c: (b, c))

    def squeeze(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref, m, l, acc):
        _kernel(qpos_ref, q_ref.at[0, 0], k_ref.at[0, 0], v_ref.at[0, 0],
                pos_ref, o_ref.at[0, 0], m, l, acc,
                bc=bc, n_c=n_c, window=window, scale=scale)

    return pl.pallas_call(
        squeeze,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  q_spec, kv_spec, kv_spec, pos_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_decode_gqa",
    )(jnp.asarray(q_position, jnp.int32), q, k_cache, v_cache, kv_positions)
