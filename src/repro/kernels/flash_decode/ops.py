"""Public wrapper: models' (B, C, KV, hd) cache layout -> kernel layout, padding,
interpret mode on CPU.

Positions come in two flavors:
  * shared     — kv_positions (C,), q_position ()   : the classic lock-step
                 batch where every lane decodes the same step;
  * per-slot   — kv_positions (B, C), q_position (B,): the serving slot plane,
                 where each lane holds an independent request at its own depth
                 (ragged occupancy, holes from slot recycling).
Shared positions are broadcast to the per-slot form; the kernel only sees the
per-slot layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.flash_decode.flash_decode import BLOCK_C, flash_decode_bkv
from repro.kernels.flash_decode.ref import flash_decode_ref


def flash_decode(q, k_cache, v_cache, kv_positions, q_position, *, window=None,
                 bc=BLOCK_C, impl: str = "auto"):
    """q: (B, H, hd); caches: (B, C, KV, hd); kv_positions: (C,) or (B, C)
    int32 (-1 = empty); q_position: () or (B,) int32. Returns (B, H, hd).
    `impl`: "ref" = pure-jnp oracle; "auto"/"pallas" = Pallas kernel
    (interpret mode on CPU)."""
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}; options: auto|pallas|ref")
    if impl == "ref":
        return flash_decode_ref(q, k_cache, v_cache, kv_positions, q_position,
                                window=window)
    B, H, hd = q.shape
    C, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    interpret = is_cpu()
    bc = min(bc, max(C, 8))
    pad = (-C) % bc
    kt = jnp.moveaxis(k_cache, 2, 1)                    # (B, KV, C, hd)
    vt = jnp.moveaxis(v_cache, 2, 1)
    pos = jnp.asarray(kv_positions, jnp.int32)
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], (B, C))
    qpos = jnp.asarray(q_position, jnp.int32)
    if qpos.ndim == 0:
        qpos = jnp.broadcast_to(qpos[None], (B,))
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)  # masked out
    qg = q.reshape(B, KV, G, hd)
    o = flash_decode_bkv(qg, kt, vt, pos, qpos, window=window, bc=bc,
                         interpret=interpret)
    return o.reshape(B, H, hd)
