"""Public wrapper: models' (B, C, KV, hd) cache layout -> kernel layout, padding,
interpret mode on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.flash_decode.flash_decode import BLOCK_C, flash_decode_bkv


def flash_decode(q, k_cache, v_cache, kv_positions, q_position, *, window=None,
                 bc=BLOCK_C):
    """q: (B, H, hd); caches: (B, C, KV, hd); kv_positions: (C,) int32 (-1 =
    empty); q_position: () int32. Returns (B, H, hd)."""
    B, H, hd = q.shape
    C, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    interpret = is_cpu()
    bc = min(bc, max(C, 8))
    pad = (-C) % bc
    kt = jnp.moveaxis(k_cache, 2, 1)                    # (B, KV, C, hd)
    vt = jnp.moveaxis(v_cache, 2, 1)
    pos = kv_positions
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, (0, pad), constant_values=-1)  # masked out
    qg = q.reshape(B, KV, G, hd)
    o = flash_decode_bkv(qg, kt, vt, pos, q_position, window=window, bc=bc,
                         interpret=interpret)
    return o.reshape(B, H, hd)
