"""Pure-jnp oracle: GQA attention with causal / sliding-window masking.
Delegates to the shared reference implementation in models/layers.py so the kernel
is validated against exactly what the models use."""
from __future__ import annotations

from repro.models.layers import gqa_attention


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    return gqa_attention(q, k, v, causal=causal, window=window)
