"""Public wrapper: (B, S, H, hd) layout in/out, padding to block multiples,
interpret-mode on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.flash_attention.flash_attention import (DEFAULT_BK, DEFAULT_BQ,
                                                           flash_attention_bhsd)
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal=True, window=None, bq=DEFAULT_BQ,
                    bk=DEFAULT_BK, impl: str = "auto"):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) — the models' layout.
    Pads S to block multiples, transposes to (B, H, S, hd) for the kernel.
    `impl`: "ref" = pure-jnp oracle; "auto"/"pallas" = Pallas kernel
    (interpret mode on CPU)."""
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}; options: auto|pallas|ref")
    if impl == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    interpret = is_cpu()
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys sit at positions >= Sk; causal masking from real queries
        # (pos < Sq <= Sk) removes them as long as causal=True. For non-causal use
        # with padding, mask via window instead — asserted here.
        assert causal, "non-causal flash path requires Sk % bk == 0"
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=interpret)
    if pad_q:
        o = o[:, :, :Sq]
    return jnp.moveaxis(o, 1, 2)
