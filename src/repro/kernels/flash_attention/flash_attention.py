"""Pallas TPU kernel: blockwise (flash) GQA attention with causal and
sliding-window masking — the prefill/train compute hot-spot.

TPU-native design (vs the CUDA flash-attention formulation):
  * grid = (batch, q_heads, Sq/BQ) with a `fori_loop` over KV blocks inside the
    kernel; online-softmax stats (m, l) and the accumulator live in VMEM scratch.
  * BQ/BK default to 128 so the q@k^T and p@v contractions are MXU-shaped
    (128 x head_dim x 128); masks are built from iota on the VPU.
  * GQA is handled in the BlockSpec index_map: q head h reads kv head
    h // (H // KV) — no head replication through HBM.
  * causal + window: KV blocks that are fully masked are skipped by clamping the
    loop bounds (lo = first in-window block, hi = q-diagonal block), giving the
    O(S·W) sliding-window complexity rather than O(S²) with masking.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq, bk, sk, causal, window, scale):
    # refs arrive squeezed to (S, hd) via None block dims, so every access is
    # a single NDIndexer (interpret-mode discharge supports exactly one
    # indexer per load/store in this jax version)
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale                 # (BQ, hd)

    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    n_kv = sk // bk
    # last kv block that any query in this q block can see
    hi = jnp.minimum(n_kv, (q_start + bq + bk - 1) // bk) if causal else n_kv
    if window is not None:
        # first kv block with any key in-window for the FIRST query of the block
        lo_pos = jnp.maximum(q_start - (window - 1), 0)
        lo = lo_pos // bk
    else:
        lo = 0

    def body(ki, _):
        k_start = ki * bk
        k = pl.load(k_ref, (pl.ds(k_start, bk), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(k_start, bk), slice(None))).astype(jnp.float32)
        s = q @ k.T                                            # (BQ, BK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)              # (BQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new
        return ()

    jax.lax.fori_loop(lo, hi, body, ())
    l = l_scr[...]
    l = jnp.where(l == 0.0, 1.0, l)                            # fully-masked rows
    o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=None,
                         bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd). Sq % bq == Sk % bk == 0.
    Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    grid = (B, H, Sq // bq)

    # None block dims squeeze batch/head away inside the kernel
    q_spec = pl.BlockSpec((None, None, bq, hd), lambda b, h, i: (b, h, i, 0))
    kv_spec = pl.BlockSpec((None, None, Sk, hd),
                           lambda b, h, i: (b, h // group, 0, 0))

    kernel = functools.partial(_kernel, bq=bq, bk=bk, sk=Sk, causal=causal,
                               window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
        name="flash_attention_gqa",
    )(q, k, v)
