"""Pallas TPU kernels: fused outer-update plane (Nesterov + delivery).

Two memory-bound kernels over the flat fragment plane (core/flatplane.py —
``(rows, LANES)`` f32 buffers, fragment-contiguous):

  * `nesterov_2d`   — the outer Nesterov step fused into ONE pass: reads
    theta/momentum/delta once, writes theta'/momentum' once (the per-leaf
    loop in core/outer_opt.py touches each leaf twice per output).
  * `deliver_2d`    — the whole delivery stage fused into ONE pass over the
    worker-stacked fragment: Eq. 3 blending OR Algorithm-1 delay
    compensation, plus offline-worker masking, selected by a STATIC `mode`
    (the blend variant never streams the snapshot operand).

Tiling mirrors kernels/delay_comp: (BLOCK_ROWS, 1024) f32 VMEM tiles
(8-sublane x 128-lane aligned); scalars ride in SMEM. `deliver_2d` adds a
worker grid axis — block (1, block, LANES) indexed (w, i) — and reads the
(M,) availability vector from SMEM at `pl.program_id(0)`.

Arithmetic matches ref.py operation-for-operation (~1 ulp; FMA contraction
varies between compilations); every divisor is a runtime scalar, so no
const-division trap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

LANES = 1024            # 8 * 128
BLOCK_ROWS = 256


def _nesterov_kernel(scalars_ref, t_ref, m_ref, d_ref, t_out_ref, m_out_ref):
    lr = scalars_ref[0]
    mu = scalars_ref[1]
    t = t_ref[...]
    m = m_ref[...]
    d = d_ref[...]
    m_new = mu * m + d
    m_out_ref[...] = m_new
    t_out_ref[...] = t + lr * (d + mu * m_new)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nesterov_2d(theta, momentum, delta, scalars, *, interpret=False):
    """theta/momentum/delta: (rows, LANES) f32; scalars: (2,) f32 [lr, mu].
    Returns (theta_new, momentum_new)."""
    rows = theta.shape[0]
    block = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, block),)
    spec = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _nesterov_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(theta.shape, theta.dtype),
                   jax.ShapeDtypeStruct(momentum.shape, momentum.dtype)],
        interpret=interpret,
        name="outer_nesterov",
    )(scalars, theta, momentum, delta)


def _blend_kernel(scalars_ref, avail_ref, l_ref, g_ref, out_ref):
    alpha = scalars_ref[0]
    keep = avail_ref[pl.program_id(0)] != 0
    l = l_ref[...]
    new = (jnp.float32(1.0) - alpha) * l + alpha * g_ref[...][None]
    out_ref[...] = jnp.where(keep, new, l)


def _compensate_kernel(scalars_ref, avail_ref, l_ref, s_ref, g_ref, out_ref):
    tau = scalars_ref[1]
    lam = scalars_ref[2]
    h = scalars_ref[3]
    sign = scalars_ref[4]
    keep = avail_ref[pl.program_id(0)] != 0
    l = l_ref[...]
    s = s_ref[...]
    gb = g_ref[...][None]
    gr = sign * (l - s) / tau
    gc = gr + lam * gr * gr * (gb - s) / h
    out_ref[...] = jnp.where(keep, gb + gc * tau, l)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def deliver_2d(local, snapshot, g, avail, scalars, *, mode: str,
               interpret=False):
    """local/snapshot: (M, rows, LANES) f32 (snapshot ignored for blend);
    g: (rows, LANES) f32; avail: (M,) f32 (0 = offline); scalars: (5,) f32
    [alpha, tau, lam, H, sign]. Static `mode` picks the formula."""
    m, rows = local.shape[0], local.shape[1]
    block = min(BLOCK_ROWS, rows)
    grid = (m, pl.cdiv(rows, block))
    wspec = pl.BlockSpec((1, block, LANES), lambda w, i: (w, i, 0))
    gspec = pl.BlockSpec((block, LANES), lambda w, i: (i, 0))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    if mode == "blend":
        kernel, in_specs, args = (
            _blend_kernel, [smem, smem, wspec, gspec], (local, g))
    elif mode == "compensate":
        kernel, in_specs, args = (
            _compensate_kernel, [smem, smem, wspec, wspec, gspec],
            (local, snapshot, g))
    else:
        raise ValueError(f"unknown deliver mode {mode!r}")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=wspec,
        out_shape=jax.ShapeDtypeStruct(local.shape, local.dtype),
        interpret=interpret,
        name=f"outer_deliver_{mode}",
    )(scalars, avail, *args)
