"""Public wrappers for the fused outer-update kernels.

These operate on flat-plane buffers (core/flatplane.py) directly — callers
(the `fused_updates` engine path, tests, benchmarks) pack once per
transition, so unlike the other kernel families there is NO per-leaf
ravel/pad/reshape here: inputs are already (rows, LANES)-shaped.

Implementation policy (`impl`), same contract as delay_comp/delta_codec:
  "ref"    — pure-jnp oracle (ref.py)
  "pallas" — the fused kernel (interpret mode on CPU)
  "auto"   — oracle on CPU (interpret mode is python-per-tile and these sit
             on the engine's per-delivery hot path), kernel elsewhere
The kernel matches the oracle to ~1 ulp (allclose-pinned by
tests/test_outer_update.py); on CPU "auto" = oracle, which is what makes
the fused engine path bitwise-deterministic in the trajectory tests.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import is_cpu as _is_cpu
from repro.kernels.outer_update.outer_update import (LANES, deliver_2d,
                                                     nesterov_2d)
from repro.kernels.outer_update.ref import (DELIVER_MODES, deliver_ref,
                                            nesterov_ref)


def _use_ref(impl: str) -> bool:
    if impl == "ref":
        return True
    if impl == "pallas":
        return False
    return _is_cpu()


def outer_nesterov(theta, momentum, delta, *, lr, mu, impl: str = "auto"):
    """Fused Nesterov outer step on (rows, LANES) f32 buffers.
    Returns (theta_new, momentum_new)."""
    if _use_ref(impl):
        return nesterov_ref(theta, momentum, delta, lr=lr, mu=mu)
    scalars = jnp.asarray([jnp.float32(lr), jnp.float32(mu)], jnp.float32)
    out = nesterov_2d(theta, momentum, delta, scalars, interpret=_is_cpu())
    return out[0], out[1]


def fused_deliver(local, snapshot, g, avail, *, mode: str, alpha=0.0,
                  tau=1.0, lam=0.0, H=1.0, sign=1.0, impl: str = "auto"):
    """Fused delivery (blend|compensate + offline-worker mask) over the
    worker-stacked fragment buffer. `local`/`snapshot`: (M, rows, LANES);
    `g`: (rows, LANES); `avail`: (M,). tau may be a traced scalar (the
    engine's ACTUAL overlap depth). Returns the new local stack."""
    if mode not in DELIVER_MODES:
        raise ValueError(f"unknown deliver mode {mode!r}; "
                         f"options: {DELIVER_MODES}")
    if _use_ref(impl):
        return deliver_ref(local, snapshot, g, avail, mode=mode, alpha=alpha,
                           tau=tau, lam=lam, H=H, sign=sign)
    scalars = jnp.asarray([jnp.float32(alpha), jnp.float32(tau),
                           jnp.float32(lam), jnp.float32(H),
                           jnp.float32(sign)], jnp.float32)
    availf = jnp.asarray(avail).astype(jnp.float32)
    return deliver_2d(local, snapshot if mode == "compensate" else local,
                      g, availf, scalars, mode=mode, interpret=_is_cpu())
