"""Pure-jnp oracles for the fused outer-update kernels.

Arithmetic is written ONCE here in exactly the per-element order the Pallas
kernels use (multiplies and divides in the same sequence), so the kernel
tracks the oracle to ~1 ulp — residual differences are XLA FMA-contraction
choices that vary between compilations, same as the other six kernel
families (validated allclose at rtol 1e-5 in tests). No reciprocal-multiply
trick is needed: every divisor (tau, H) is a runtime scalar operand in both
paths, so XLA cannot constant-fold either side differently. The engine's
BITWISE determinism contract on CPU rests on ops.py impl="auto" routing to
these oracles there.
"""
from __future__ import annotations

import jax.numpy as jnp

DELIVER_MODES = ("blend", "compensate")


def nesterov_ref(theta, momentum, delta, *, lr, mu):
    """One fused outer Nesterov step on same-shaped f32 arrays:

        m_new = mu * m + d
        t_new = t + lr * (d + mu * m_new)

    Returns ``(theta_new, momentum_new)``.
    """
    lr = jnp.float32(lr)
    mu = jnp.float32(mu)
    m_new = mu * momentum + delta
    t_new = theta + lr * (delta + mu * m_new)
    return t_new, m_new


def deliver_ref(local, snapshot, g, avail, *, mode: str, alpha=0.0,
                tau=1.0, lam=0.0, H=1.0, sign=1.0):
    """Fused delivery: fold the outer-updated global fragment `g` into every
    worker's local fragment, then mask offline workers — one pass.

      local    — (M, rows, LANES) worker-local fragment now
      snapshot — (M, rows, LANES) initiation-time snapshot (compensate only)
      g        — (rows, LANES) freshly outer-updated global fragment
      avail    — (M,) worker availability (bool or 0/1)

    mode="blend" (Streaming DiLoCo Eq. 3, also the DiLoCo reset at alpha=1):
        new = (1 - alpha) * local + alpha * g
    mode="compensate" (CoCoDC Algorithm 1, Eqs. 4-8):
        gr  = sign * (local - snapshot) / tau
        gc  = gr + lam * gr * gr * (g - snapshot) / H
        new = g + gc * tau
    Offline workers keep `local` unchanged (they re-sync on return).
    """
    if mode not in DELIVER_MODES:
        raise ValueError(f"unknown deliver mode {mode!r}; "
                         f"options: {DELIVER_MODES}")
    gb = g[None]
    if mode == "blend":
        alpha = jnp.float32(alpha)
        new = (jnp.float32(1.0) - alpha) * local + alpha * gb
    else:
        tau = jnp.float32(tau)
        lam = jnp.float32(lam)
        h = jnp.float32(H)
        sign = jnp.float32(sign)
        gr = sign * (local - snapshot) / tau
        gc = gr + lam * gr * gr * (gb - snapshot) / h
        new = gb + gc * tau
    keep = jnp.asarray(avail).astype(jnp.float32) != 0
    return jnp.where(keep.reshape((-1, 1, 1)), new, local)
