"""Pure-jnp oracle for the diagonal linear recurrence h_t = a_t*h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_scan_ref(a, b, h0=None):
    """a, b: (B, T, D) f32. Returns h: (B, T, D)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
