from repro.kernels.rglru_scan.ops import lru_scan  # noqa: F401
