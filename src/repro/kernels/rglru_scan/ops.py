"""Public wrapper for the chunked RG-LRU scan kernel (padding + interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.rglru_scan.ref import lru_scan_ref
from repro.kernels.rglru_scan.rglru_scan import BLOCK_D, BLOCK_T, lru_scan_btd


def lru_scan(a, b, h0=None, *, bt=BLOCK_T, bd=BLOCK_D, impl: str = "auto"):
    """a, b: (B, T, D) — h_t = a_t h_{t-1} + b_t. Returns h: (B, T, D) f32.
    `impl`: "ref" = pure-jnp oracle; "auto"/"pallas" = chunked Pallas scan
    (interpret mode on CPU)."""
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}; options: auto|pallas|ref")
    if impl == "ref":
        return lru_scan_ref(a, b, h0)
    B, T, D = a.shape
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    h0 = jnp.zeros((B, D), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    interpret = is_cpu()
    bt = min(bt, T)
    bd = min(bd, D)
    pad_t = (-T) % bt
    pad_d = (-D) % bd
    if pad_t or pad_d:
        # a=1, b=0 padding keeps the carried state unchanged on pad rows
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_d)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d)))
    h = lru_scan_btd(a, b, h0, bt=bt, bd=bd, interpret=interpret)
    return h[:, :T, :D]
