"""Pallas TPU kernel: chunked diagonal linear recurrence (RG-LRU core).

    h_t = a_t * h_{t-1} + b_t        (a, b, h: per-channel)

TPU-native adaptation: instead of a 1-step-per-iteration scan through HBM (T round
trips) or a T-wide associative scan (log T full-tensor passes), the grid walks time
chunks SEQUENTIALLY (`arbitrary` dimension semantics) while channels/batch are
parallel; the running state h lives in a VMEM scratch carried across grid steps.
Within a chunk, the recurrence runs on registers/VMEM with a `fori_loop` over the
chunk's rows — one HBM read of (a, b) and one write of h total.

Blocks: (BT, BD) with BD=128-lane aligned; channel dim is the minor (lane) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params

BLOCK_T = 256
BLOCK_D = 128


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, bt):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)      # (BT, BD)
    b = b_ref[...].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]                 # (BD,)
        o_ref[t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_scr[0])
    h_scr[...] = h[None]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def lru_scan_btd(a, b, h0, *, bt=BLOCK_T, bd=BLOCK_D, interpret=False):
    """a, b: (B, T, D); h0: (B, D). T % bt == 0, D % bd == 0. Returns h (B, T, D)."""
    B, T, D = a.shape
    bt = min(bt, T)
    bd = min(bd, D)
    grid = (B, D // bd, T // bt)
    data_spec = pl.BlockSpec((1, bt, bd), lambda bi, di, ti: (bi, ti, di))
    h0_spec = pl.BlockSpec((1, 1, bd), lambda bi, di, ti: (bi, 0, di))

    def squeeze(a_ref, b_ref, h0_ref, o_ref, h_scr):
        _kernel(a_ref.at[0], b_ref.at[0], h0_ref.at[0], o_ref.at[0], h_scr, bt=bt)

    return pl.pallas_call(
        squeeze,
        grid=grid,
        in_specs=[data_spec, data_spec, h0_spec],
        out_specs=data_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rglru_scan",
    )(a, b, h0[:, None, :])
