"""Oracle: the models' own RMSNorm (models/layers.py)."""
from repro.models.layers import rms_norm as rms_norm_ref  # noqa: F401
