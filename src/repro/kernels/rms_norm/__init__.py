from repro.kernels.rms_norm.ops import rms_norm  # noqa: F401
