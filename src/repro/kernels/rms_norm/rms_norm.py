"""Pallas TPU kernel: fused RMSNorm.

Unfused, the norm costs 3 HBM passes (square-mean reduce, rsqrt-scale, weight
mul); fused it is one read + one write per row block. Rows (tokens) tile the
grid; the feature dim stays resident in VMEM (d_model <= 16384 f32 = 64 KiB —
fine). f32 statistics regardless of input dtype, matching the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_ROWS = 256


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                  # (R, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rms_norm_2d(x, w, *, eps=1e-5, interpret=False):
    """x: (R, D); w: (D,). R % BLOCK_ROWS need not hold (grid ceil-div)."""
    R, D = x.shape
    block = min(BLOCK_ROWS, R)
    grid = (pl.cdiv(R, block),)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((block, D), lambda i: (i, 0)),
                  pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
        name="fused_rms_norm",
    )(x, w[None])
