"""Public wrapper: arbitrary leading dims, row padding, CPU interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.rms_norm.ref import rms_norm_ref
from repro.kernels.rms_norm.rms_norm import BLOCK_ROWS, rms_norm_2d


def rms_norm(x, weight, eps: float = 1e-5, *, impl: str = "auto"):
    """x: (..., D); weight: (D,). Fused Pallas RMSNorm. `impl`: "ref" =
    pure-jnp oracle; "auto"/"pallas" = kernel (interpret mode on CPU)."""
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}; options: auto|pallas|ref")
    if impl == "ref":
        return rms_norm_ref(x, weight, eps=eps)
    interpret = is_cpu()
    shape = x.shape
    D = shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    block = min(BLOCK_ROWS, R)
    pad = (-R) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rms_norm_2d(x2, weight, eps=eps, interpret=interpret)
    if pad:
        out = out[:R]
    return out.reshape(shape)
