"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence with matrix-valued head state.

    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          S in R^{hd x hd} per (batch, head)

TPU adaptation: grid = (B*H, T/BT) with the time axis SEQUENTIAL; S is a VMEM
scratch (hd x hd f32) carried across time chunks. Within a chunk the per-step
updates are rank-1 outer products (VPU) plus an (1 x hd)@(hd x hd) matvec on the
MXU. hd=64 keeps the state at 16 KiB — far under VMEM. This replaces the CUDA
warp-per-head formulation with a lane-parallel per-head state resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params

BLOCK_T = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr, *,
            bt, n_t):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)          # (BT, hd)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)          # (1, hd)

    def step(t, s):
        kv = k[t][:, None] * v[t][None, :]      # (hd, hd) rank-1
        o = (r[t][None, :] @ (s + u.T * kv))[0]  # (hd,)
        o_ref[t, :] = o.astype(o_ref.dtype)
        return w[t][:, None] * s + kv

    s = jax.lax.fori_loop(0, bt, step, s_scr[...])
    s_scr[...] = s

    @pl.when(ti == n_t - 1)
    def _fin():
        sT_ref[...] = s.astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv_scan_bht(r, k, v, w, u, s0, *, bt=BLOCK_T, interpret=False):
    """r,k,v,w: (BH, T, hd); u: (BH, hd); s0: (BH, hd, hd) f32.
    T % bt == 0. Returns (o: (BH, T, hd), sT: (BH, hd, hd) f32)."""
    BH, T, hd = r.shape
    bt = min(bt, T)
    n_t = T // bt
    grid = (BH, n_t)
    data_spec = pl.BlockSpec((1, bt, hd), lambda b, t: (b, t, 0))
    u_spec = pl.BlockSpec((1, 1, hd), lambda b, t: (b, 0, 0))
    s_spec = pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0))

    def squeeze(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr):
        _kernel(r_ref.at[0], k_ref.at[0], v_ref.at[0], w_ref.at[0], u_ref.at[0],
                s0_ref.at[0], o_ref.at[0], sT_ref.at[0], s_scr, bt=bt, n_t=n_t)

    return pl.pallas_call(
        squeeze,
        grid=grid,
        in_specs=[data_spec, data_spec, data_spec, data_spec, u_spec, s_spec],
        out_specs=[data_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct(r.shape, r.dtype),
                   jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="rwkv6_wkv_scan",
    )(r, k, v, w, u[:, None, :], s0)
