"""Public wrapper: (B, T, H, hd) layout, fold (B, H) -> grid axis, pad T."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.rwkv6_scan.ref import wkv_scan_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import BLOCK_T, wkv_scan_bht


def wkv_scan(r, k, v, w, u, s0=None, *, bt=BLOCK_T, impl: str = "auto"):
    """r,k,v,w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd) f32 or None.
    Returns (o: (B, T, H, hd), sT: (B, H, hd, hd) f32). `impl`: "ref" =
    pure-jnp oracle; "auto"/"pallas" = Pallas kernel (interpret on CPU)."""
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}; options: auto|pallas|ref")
    if impl == "ref":
        return wkv_scan_ref(r, k, v, w, u, s0)
    B, T, H, hd = r.shape
    interpret = is_cpu()
    bt = min(bt, T)
    pad_t = (-T) % bt

    def fold(a):
        a = jnp.moveaxis(a, 2, 1).reshape(B * H, T, hd)
        if pad_t:
            a = jnp.pad(a, ((0, 0), (0, pad_t), (0, 0)))
        return a

    rf, kf, vf = fold(r), fold(k), fold(v)
    wf = fold(w)
    if pad_t:
        # w=1 on pad rows keeps the state frozen; k=v=0 adds nothing
        wf = wf.at[:, T:].set(1.0)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0f = (jnp.zeros((B * H, hd, hd), jnp.float32) if s0 is None
           else s0.reshape(B * H, hd, hd).astype(jnp.float32))
    o, sT = wkv_scan_bht(rf, kf, vf, wf, uf, s0f, bt=bt, interpret=interpret)
    o = jnp.moveaxis(o[:, :T].reshape(B, H, T, hd), 1, 2)
    return o, sT.reshape(B, H, hd, hd)
