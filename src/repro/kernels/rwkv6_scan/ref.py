"""Pure-jnp oracle for the RWKV-6 WKV recurrence — delegates to the model's own
reference scan so kernel and model are validated against the same semantics."""
from __future__ import annotations

from repro.models.rwkv6 import wkv_scan_ref  # noqa: F401
