from repro.kernels.rwkv6_scan.ops import wkv_scan  # noqa: F401
