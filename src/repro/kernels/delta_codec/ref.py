"""Pure-jnp oracle for the delta wire codec: per-block absmax int8/int4
quantization of pseudo-gradient payloads, with nibble packing for int4.

Wire format (per flat leaf, padded to a whole number of `block`-element
blocks; one row below = one block):

    scale   = absmax(block) / levels        levels = 127 (int8) | 7 (int4)
    codes   = clip(round(x / scale), -levels, levels)        — int8 values
    int8 payload: the codes verbatim, 1 byte/element
    int4 payload: halves-packed — element i of the block's FIRST half rides
        in the low nibble of byte i, element i of the SECOND half in the
        high nibble (contiguous-slice packing, lane-friendly on TPU)

An all-zero block has scale 0 and codes 0; dequantize returns exact zeros.
Scales ship as one f32 per block (the +4/block bytes in the wire-format
accounting, `ops.wire_bytes`).
"""
from __future__ import annotations

import jax.numpy as jnp

LEVELS = {8: 127, 4: 7}


def quantize_ref(x2d, *, bits: int):
    """(nblocks, block) f32 -> (codes int8 (nblocks, block), scales (nblocks,))."""
    levels = LEVELS[bits]
    x = x2d.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1)
    # explicit f32 reciprocal multiply: XLA rewrites division-by-constant into
    # this form inside jit, so spelling it out keeps the eager oracle and the
    # jitted kernel bitwise-identical
    scale = absmax * jnp.float32(1.0 / levels)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -levels, levels)
    return q.astype(jnp.int8), scale


def pack_ref(codes, *, bits: int):
    """int8 codes -> wire bytes; int4 packs the block halves into nibbles."""
    if bits == 8:
        return codes
    half = codes.shape[1] // 2
    lo = codes[:, :half].astype(jnp.int32)
    hi = codes[:, half:].astype(jnp.int32)
    return ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.int8)


def _sext4(nibble):
    """Sign-extend a 4-bit two's-complement value held in an int32."""
    return ((nibble & 0xF) ^ 8) - 8


def unpack_ref(packed, *, bits: int):
    if bits == 8:
        return packed
    b = packed.astype(jnp.int32)
    lo = _sext4(b)
    hi = _sext4(b >> 4)
    return jnp.concatenate([lo, hi], axis=1).astype(jnp.int8)


def dequantize_ref(codes, scales):
    return codes.astype(jnp.float32) * scales[:, None]


def encode_ref(x2d, *, bits: int):
    """Fused quantize+pack: (nblocks, block) f32 -> (packed int8, scales f32)."""
    codes, scales = quantize_ref(x2d, bits=bits)
    return pack_ref(codes, bits=bits), scales


def decode_ref(packed, scales, *, bits: int):
    """Fused dequantize+unpack: inverse of `encode_ref` (up to quantization)."""
    return dequantize_ref(unpack_ref(packed, bits=bits), scales)


def roundtrip_ref(x2d, *, bits: int):
    """What the receiver reconstructs: decode(encode(x))."""
    packed, scales = encode_ref(x2d, bits=bits)
    return decode_ref(packed, scales, bits=bits)
