"""Public wrappers for the delta wire codec (per-block absmax int8/int4).

Array-level API (used by tests/benchmarks):
  encode_array(x)          -> (packed int8, scales f32)   quantize+pack
  decode_array(packed, ..) -> x_hat                       dequantize+unpack
  codec_roundtrip_array(x) -> x_hat                       what the receiver sees

Pytree-level API (used by the engine transitions):
  codec_roundtrip(tree)    — per-leaf round trip, None-leaf aware

Leaves are raveled and zero-padded to a whole number of `block`-element
blocks (one row per block); padding never perturbs a block's absmax, so the
oracle on the unpadded layout and the kernel on the padded one agree bitwise.

Implementation policy (`impl`):
  "ref"    — pure-jnp oracle
  "pallas" — the fused kernel (interpret mode on CPU); requires
             block % 256 == 0 so the int4 halves-packing matches the oracle's
             wire bytes exactly
  "auto"   — oracle on CPU (interpret mode is python-per-tile and the codec
             sits on the engine's per-initiation hot path), kernel elsewhere;
             also falls back to the oracle when the kernel's block-alignment
             requirement is unmet

`wire_bytes` is the ONE place the compressed payload size is computed —
`ProtocolEngine._wire_bytes` calls it so transfer times, link pricing and the
Eq. 9 cadence all see the real (smaller) payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu
from repro.kernels.delta_codec.delta_codec import (LANES, dequantize_unpack_2d,
                                                   quantize_pack_2d)
from repro.kernels.delta_codec import ref as ref_lib

CODEC_BITS = {"int8": 8, "int4": 4}
KERNEL_BLOCK_MULTIPLE = 2 * LANES      # pallas path block-alignment requirement


def wire_bytes(n_elems: int, *, codec: str, block: int) -> int:
    """Bytes on the wire for an `n_elems`-element payload: `bits`-bit codes
    plus one f32 scale per `block` elements."""
    bits = CODEC_BITS[codec]
    payload = (n_elems * bits + 7) // 8
    scales = -(-n_elems // block) * 4
    return payload + scales


def _use_ref(impl: str, block: int) -> bool:
    if impl == "ref":
        return True
    aligned = block % KERNEL_BLOCK_MULTIPLE == 0
    if impl == "pallas":
        if not aligned:
            raise ValueError(
                f"impl='pallas' requires block % {KERNEL_BLOCK_MULTIPLE} == 0 "
                f"(int4 halves-packing lane alignment), got block={block}")
        return False
    return is_cpu() or not aligned


def _blocked(x, block: int):
    """Flat view padded to (nblocks, block); returns (x2d, n)."""
    n = x.size
    nblocks = -(-n // block)
    flat = x.reshape(-1).astype(jnp.float32)
    pad = nblocks * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nblocks, block), n


def encode_array(x, *, codec: str, block: int, impl: str = "auto"):
    """Fused quantize+pack of one array. Returns (packed int8 (nblocks,
    block*bits//8), scales f32 (nblocks,)) over the zero-padded blocks."""
    bits = CODEC_BITS[codec]
    x2d, _ = _blocked(x, block)
    if _use_ref(impl, block):
        return ref_lib.encode_ref(x2d, bits=bits)
    return quantize_pack_2d(x2d, bits=bits, interpret=is_cpu())


def decode_array(packed, scales, shape, dtype, *, codec: str, block: int,
                 impl: str = "auto"):
    """Fused dequantize+unpack back to `shape`/`dtype` (drops block padding)."""
    bits = CODEC_BITS[codec]
    if _use_ref(impl, block):
        x2d = ref_lib.decode_ref(packed, scales, bits=bits)
    else:
        x2d = dequantize_unpack_2d(packed, scales, bits=bits,
                                   interpret=is_cpu())
    n = 1
    for s in shape:
        n *= s
    return x2d.reshape(-1)[:n].reshape(shape).astype(dtype)


def codec_roundtrip_array(x, *, codec: str, block: int, impl: str = "auto"):
    """decode(encode(x)) — the payload the receiver reconstructs."""
    packed, scales = encode_array(x, codec=codec, block=block, impl=impl)
    return decode_array(packed, scales, x.shape, x.dtype, codec=codec,
                        block=block, impl=impl)


def codec_roundtrip(tree, *, codec: str, block: int, impl: str = "auto"):
    """Pytree-level round trip; None leaves (fragment-extracted trees) pass
    through untouched."""
    return jax.tree.map(
        lambda l: None if l is None else codec_roundtrip_array(
            l, codec=codec, block=block, impl=impl),
        tree, is_leaf=lambda l: l is None)
