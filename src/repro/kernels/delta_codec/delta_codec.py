"""Pallas TPU kernels: fused per-block absmax quantize+pack and
dequantize+unpack for the WAN delta wire format.

Both directions are single-pass and bandwidth-bound: encode reads each f32
element once and writes 1 byte (int8) or half a byte (int4) plus one f32
scale per block; decode is the mirror image. The arithmetic is ~3 flops per
element — far below the TPU ridge point — so the roofline is the HBM stream
(see benchmarks/kernels.py and benchmarks/roofline.py).

Tiling: the wrapper reshapes each flat leaf to (nblocks, block) — one row per
quantization block — and pads the block axis to a multiple of 2*LANES so the
int4 halves-packed output keeps a 128-lane-aligned last axis. Each grid step
owns a row-chunk tile; per-row absmax reduces along lanes inside the tile.
Scales are emitted broadcast to (rows, LANES) (lane-aligned f32 stores); the
wrapper keeps column 0. Zero padding never perturbs a block's absmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.delta_codec.ref import LEVELS

LANES = 128
BLOCK_ROWS = 256          # rows per grid step at block == 2*LANES; scaled
                          # down for wider blocks to bound the VMEM tile


def _tile_rows(nblocks: int, block: int) -> int:
    rows = max(8, (BLOCK_ROWS * 2 * LANES) // max(block, 2 * LANES))
    return min(rows, nblocks)


def _quant(x, levels):
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    # reciprocal multiply, matching ref.quantize_ref bitwise (see note there)
    scale = absmax * jnp.float32(1.0 / levels)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -levels, levels).astype(jnp.int32)
    return q, scale


def _encode_kernel(x_ref, packed_ref, scale_ref, *, bits):
    x = x_ref[...].astype(jnp.float32)
    q, scale = _quant(x, LEVELS[bits])
    if bits == 4:
        half = q.shape[1] // 2
        q = (q[:, :half] & 0xF) | ((q[:, half:] & 0xF) << 4)
    packed_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = jnp.broadcast_to(scale, scale_ref.shape)


def _decode_kernel(packed_ref, scale_ref, out_ref, *, bits):
    scale = scale_ref[...][:, :1]
    b = packed_ref[...].astype(jnp.int32)
    if bits == 4:
        lo = ((b & 0xF) ^ 8) - 8            # sign-extend low nibble
        hi = (((b >> 4) & 0xF) ^ 8) - 8     # sign-extend high nibble
        q = jnp.concatenate([lo, hi], axis=1)
    else:
        q = b
    out_ref[...] = q.astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pack_2d(x, *, bits: int, interpret: bool = False):
    """x: (nblocks, block) f32, block a multiple of 2*LANES. Returns
    (packed int8 (nblocks, block*bits//8), scales f32 (nblocks,))."""
    nblocks, block = x.shape
    rows = _tile_rows(nblocks, block)
    grid = (pl.cdiv(nblocks, rows),)
    pb = block * bits // 8
    out = pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, pb), lambda i: (i, 0)),
                   pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nblocks, pb), jnp.int8),
                   jax.ShapeDtypeStruct((nblocks, LANES), jnp.float32)],
        interpret=interpret,
        name=f"delta_codec_encode_int{bits}",
    )(x)
    packed, scales = out
    return packed, scales[:, 0]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def dequantize_unpack_2d(packed, scales, *, bits: int, interpret: bool = False):
    """Inverse of `quantize_pack_2d`: (nblocks, block*bits//8) int8 + (nblocks,)
    f32 scales -> (nblocks, block) f32."""
    nblocks, pb = packed.shape
    block = pb * 8 // bits
    rows = _tile_rows(nblocks, block)
    grid = (pl.cdiv(nblocks, rows),)
    scales2d = jnp.broadcast_to(scales[:, None], (nblocks, LANES))
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, pb), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.float32),
        interpret=interpret,
        name=f"delta_codec_decode_int{bits}",
    )(packed, scales2d)
