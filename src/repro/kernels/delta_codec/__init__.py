"""Fused delta-codec kernels for the WAN wire format (quantize+pack /
dequantize+unpack). See ops.py for the public pytree-level API."""
