from repro.kernels.delay_comp.ops import delay_comp  # noqa: F401
