"""Public wrapper for the fused delay-compensation kernel.

Works on arbitrary pytrees: leaves are raveled, concatenated conceptually (in fact
processed per-leaf), padded to the (rows, 1024) tile and dispatched to the Pallas
kernel. On CPU (this container) the kernel runs in interpret mode; callers who want
the pure-XLA path use the ref oracle via ``impl="ref"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu as _is_cpu
from repro.kernels.delay_comp.delay_comp import LANES, delay_comp_2d
from repro.kernels.delay_comp.ref import delay_comp_ref


def delay_comp_array(theta_tl, theta_tp, theta_g, *, tau, lam, H, sign=1.0,
                     impl: str = "auto"):
    """Single-array fused update. tau/lam/H/sign may be python or jnp scalars."""
    if impl == "ref" or (impl == "auto" and _is_cpu() and theta_tl.size > 1 << 20):
        # interpret mode is pure-python-per-tile; keep big CPU arrays on the oracle
        return delay_comp_ref(theta_tl, theta_tp, theta_g, tau=tau, lam=lam, H=H,
                              sign=sign)
    interpret = _is_cpu()
    shape, dtype = theta_tl.shape, theta_tl.dtype
    n = theta_tl.size
    rows = -(-n // LANES)
    pad = rows * LANES - n

    def prep(a):
        flat = a.reshape(-1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(rows, LANES)

    scalars = jnp.asarray(
        [jnp.float32(tau), jnp.float32(lam), jnp.float32(H), jnp.float32(sign)],
        jnp.float32)
    out = delay_comp_2d(prep(theta_tl), prep(theta_tp), prep(theta_g), scalars,
                        interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def delay_comp(theta_tl, theta_tp, theta_g, *, tau, lam, H, sign=1.0,
               impl: str = "auto"):
    """Pytree-level fused delay compensation (CoCoDC Algorithm 1)."""
    return jax.tree.map(
        lambda tl, tp, tg: delay_comp_array(tl, tp, tg, tau=tau, lam=lam, H=H,
                                            sign=sign, impl=impl),
        theta_tl, theta_tp, theta_g)
