"""Public wrapper for the fused delay-compensation kernel.

Works on arbitrary pytrees: leaves are raveled, concatenated conceptually (in fact
processed per-leaf), padded to the (rows, 1024) tile and dispatched to the Pallas
kernel. On CPU (this container) the kernel runs in interpret mode; callers who want
the pure-XLA path use the ref oracle via ``impl="ref"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import is_cpu as _is_cpu
from repro.kernels.delay_comp.delay_comp import LANES, delay_comp_2d
from repro.kernels.delay_comp.ref import delay_comp_ref


def pack_scalars(tau, lam, H, sign=1.0) -> jax.Array:
    """The kernel's (4,) f32 SMEM operand. Built ONCE per pytree call and
    shared across leaves (the per-leaf `jnp.asarray` rebuild used to add one
    host->device transfer + four casts per leaf per delivery)."""
    return jnp.asarray(
        [jnp.float32(tau), jnp.float32(lam), jnp.float32(H), jnp.float32(sign)],
        jnp.float32)


def delay_comp_array(theta_tl, theta_tp, theta_g, *, tau=None, lam=None,
                     H=None, sign=1.0, impl: str = "auto", scalars=None):
    """Single-array fused update. tau/lam/H/sign may be python or jnp scalars;
    callers looping over a pytree pass a prebuilt `scalars` (pack_scalars)
    instead, so the operand is materialized once, not per leaf."""
    if scalars is None:
        scalars = pack_scalars(tau, lam, H, sign)
    if impl == "ref" or (impl == "auto" and _is_cpu() and theta_tl.size > 1 << 20):
        # interpret mode is pure-python-per-tile; keep big CPU arrays on the oracle
        return delay_comp_ref(theta_tl, theta_tp, theta_g, tau=scalars[0],
                              lam=scalars[1], H=scalars[2], sign=scalars[3])
    interpret = _is_cpu()
    # operands may be mutually broadcastable rather than identical — the
    # engine delivers the global fragment as a (1, ...) leaf against the
    # (M, ...) worker stack; the kernel itself wants equal tiles
    shape = jnp.broadcast_shapes(theta_tl.shape, theta_tp.shape,
                                 theta_g.shape)
    dtype = theta_tl.dtype
    n = 1
    for d in shape:
        n *= d
    rows = -(-n // LANES)
    pad = rows * LANES - n

    def prep(a):
        flat = jnp.broadcast_to(a, shape).reshape(-1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(rows, LANES)

    out = delay_comp_2d(prep(theta_tl), prep(theta_tp), prep(theta_g), scalars,
                        interpret=interpret)
    if pad:
        out = out.reshape(-1)[:n]
    # LANES-aligned leaves skip the flatten+slice copy entirely
    return out.reshape(shape).astype(dtype)


def delay_comp(theta_tl, theta_tp, theta_g, *, tau, lam, H, sign=1.0,
               impl: str = "auto"):
    """Pytree-level fused delay compensation (CoCoDC Algorithm 1)."""
    scalars = pack_scalars(tau, lam, H, sign)
    return jax.tree.map(
        lambda tl, tp, tg: delay_comp_array(tl, tp, tg, impl=impl,
                                            scalars=scalars),
        theta_tl, theta_tp, theta_g)
