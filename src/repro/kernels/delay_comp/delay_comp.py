"""Pallas TPU kernel: fused delay-compensation update (CoCoDC Algorithm 1).

Five elementwise HBM passes (sub, scale, mul, fma, add) fused into ONE read of the
three parameter tensors and one write — this runs over every parameter of the model
at each fragment-sync completion, so at 405B scale it is the protocol's memory-bound
hot-spot (3 reads + 1 write vs 10+ touches unfused).

Tiling: inputs are flattened and padded to (rows, 1024) f32; each grid step owns a
(BLOCK_ROWS, 1024) VMEM tile — 8-sublane × 128-lane aligned. Scalars (tau, lam, H,
sign) ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

LANES = 1024            # 8 * 128
BLOCK_ROWS = 256


def _kernel(scalars_ref, tl_ref, tp_ref, tg_ref, out_ref):
    tau = scalars_ref[0]
    lam = scalars_ref[1]
    h = scalars_ref[2]
    sign = scalars_ref[3]
    tl = tl_ref[...].astype(jnp.float32)
    tp = tp_ref[...].astype(jnp.float32)
    tg = tg_ref[...].astype(jnp.float32)
    g = sign * (tl - tp) / tau
    g_corr = g + lam * g * g * (tg - tp) / h
    out_ref[...] = (tg + g_corr * tau).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def delay_comp_2d(theta_tl, theta_tp, theta_g, scalars, *, interpret=False):
    """theta_*: (rows, LANES) arrays (pre-padded); scalars: (4,) f32 [tau,lam,H,sign]."""
    rows = theta_tl.shape[0]
    block = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, block),)
    spec = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(theta_tl.shape, theta_tl.dtype),
        interpret=interpret,
        name="cocodc_delay_comp",
    )(scalars, theta_tl, theta_tp, theta_g)
