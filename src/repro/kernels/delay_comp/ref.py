"""Pure-jnp oracle for the fused CoCoDC delay-compensation update (Algorithm 1).

    g      = sign * (theta_tl - theta_tp) / tau                 (Eq. 4; sign note in
                                                                 DESIGN.md §5)
    g_corr = g + lam * g*g*(theta_g - theta_tp) / H             (Eq. 7, Hadamard)
    out    = theta_g + g_corr * tau                             (Eq. 8)
"""
from __future__ import annotations

import jax.numpy as jnp


def delay_comp_ref(theta_tl, theta_tp, theta_g, *, tau, lam, H, sign=1.0):
    tl = theta_tl.astype(jnp.float32)
    tp = theta_tp.astype(jnp.float32)
    tg = theta_g.astype(jnp.float32)
    g = sign * (tl - tp) / tau
    g_corr = g + lam * g * g * (tg - tp) / H
    return (tg + g_corr * tau).astype(theta_tl.dtype)
