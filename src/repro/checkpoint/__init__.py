from repro.checkpoint.io import load_pytree, restore_like, save_pytree  # noqa: F401
