"""Checkpointing: msgpack-serialized pytrees (params + inner/outer optimizer +
protocol scheduler state), atomic writes, no external deps beyond msgpack.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_EXT_ND = 1


def _encode(obj):
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(obj)
        if arr.dtype == jnp.bfloat16:
            payload = msgpack.packb(
                ("bfloat16", arr.shape, arr.astype(np.float32).tobytes()))
        else:
            payload = msgpack.packb((arr.dtype.str, arr.shape, arr.tobytes()))
        return msgpack.ExtType(_EXT_ND, payload)
    raise TypeError(f"cannot serialize {type(obj)}")


def _decode(code, data):
    if code == _EXT_ND:
        dtype, shape, buf = msgpack.unpackb(data)
        if dtype == "bfloat16":
            arr = np.frombuffer(buf, np.float32).reshape(shape)
            return jnp.asarray(arr, jnp.bfloat16)
        return np.frombuffer(buf, np.dtype(dtype)).reshape(shape).copy()
    return msgpack.ExtType(code, data)


def save_pytree(path: str, tree: Any):
    """Atomic msgpack dump of a pytree of arrays/scalars/dicts/lists."""
    plain = jax.tree.map(lambda a: np.asarray(a) if hasattr(a, "shape") else a, tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(plain, default=_encode, strict_types=False))
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load_pytree(path: str) -> Any:
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read(), ext_hook=_decode, strict_map_key=False)


def restore_like(ref: Any, loaded: Any) -> Any:
    """Re-type a `load_pytree` result onto the structure of `ref`.

    msgpack round-trips containers as plain dicts/lists, losing NamedTuples and
    registered dataclasses. Given a live reference pytree with the target
    structure, this grafts the loaded leaves back onto it, casting each to the
    reference leaf's dtype (so bf16 leaves saved via the f32 wire format come
    back as bf16). None subtrees must match on both sides (jax flattening
    skips them symmetrically)."""
    ref_leaves, treedef = jax.tree.flatten(ref)
    loaded_leaves = jax.tree.leaves(loaded)
    if len(ref_leaves) != len(loaded_leaves):
        raise ValueError(
            f"checkpoint structure mismatch: reference has {len(ref_leaves)} "
            f"leaves, checkpoint has {len(loaded_leaves)}")
    out = []
    for r, l in zip(ref_leaves, loaded_leaves):
        if hasattr(r, "dtype") and hasattr(r, "shape"):
            a = jnp.asarray(l).astype(r.dtype)
            if a.shape != r.shape:
                raise ValueError(
                    f"checkpoint leaf shape mismatch: {a.shape} vs {r.shape}")
            out.append(a)
        else:
            out.append(type(r)(l))
    return jax.tree.unflatten(treedef, out)
