"""Functional protocol-engine core: all cross-region coordination state as a
single JAX pytree (`EngineState`) plus pure transition functions.

The host-side `ProtocolEngine` (core/protocol.py) owns WHEN things happen
(simulated WAN wall-clock, channel queueing, adaptive schedule); this module
owns WHAT happens to device state — and each transition is a single
`jax.jit`-compiled call (specialized per fragment id, buffers donated where the
backend supports it), so the per-step Python tree-map churn of the old
mutating engine never touches the device hot path.

State layout (fixed capacity, no Python object queue):
  * `theta_g`, `momentum`      — global model + outer Nesterov momentum pytrees
  * `inflight_delta`           — ONE full-model-shaped f32 pytree holding the
    globally-averaged pseudo-gradients of every in-flight fragment at once
    (fragments are disjoint, so their rows never collide)
  * `inflight_snapshot`        — worker-stacked pytree of local fragment state
    at initiation (CoCoDC Algorithm 1 input; None for other methods)
  * `inflight_active/t_init`   — (K,) per-fragment in-flight bookkeeping
  * `delta_norm/last_sync/rate`— (K,) adaptive-transmission state (Eq. 11)
  * `worker_available`         — (M,) partial-participation mask

Transitions (built by `make_engine_fns`, fragment id `p` is static):
  * `initiate(state, t, params_stack, p) -> state`
  * `deliver(state, t, params_stack, p) -> (state, params_stack)`
  * `diloco_round(state, params_stack) -> (state, params_stack)`
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import CoCoDCConfig
from repro.core import outer_opt
from repro.core.fragments import Fragmenter
from repro.core.methods import get_method
from repro.kernels.delta_codec import ops as codec_ops
from repro.kernels.outer_update import ops as ou_ops


def _is_none(x):
    return x is None


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: None if x is None else x + y, a, b,
                        is_leaf=_is_none)


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: None if x is None else x - y, a, b,
                        is_leaf=_is_none)


def tree_broadcast_workers(a, m: int):
    return jax.tree.map(
        lambda x: None if x is None else jnp.broadcast_to(x[None], (m,) + x.shape),
        a, is_leaf=_is_none)


def tree_norm(a) -> jax.Array:
    leaves = [l for l in jax.tree.leaves(a) if l is not None]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def sparsify(d: jax.Array, frac: float) -> jax.Array:
    """Top-k magnitude sparsification of one flat-or-shaped leaf."""
    if frac >= 1.0 or d.size == 0:
        return d
    k = max(1, int(d.size * frac))
    flat = jnp.abs(d.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(d) >= thresh, d, jnp.zeros((), d.dtype))


def pseudograd_mean(frag_stack, theta_g_frag, worker_mask, *, sync_dtype,
                    topk_frac: float = 1.0, barrier: bool = False):
    """The cross-region collective: mean over AVAILABLE workers of the
    pseudo-gradients (theta^m - theta^g). Payload crosses the WAN in
    `sync_dtype` (bf16 compression), optionally top-k-sparsified; accumulation
    returns to f32. `barrier=True` pins the collective itself to sync_dtype in
    the lowered multi-pod path (XLA otherwise hoists the f32 upcast ahead of
    the all-reduce) — used by launch/steps.py."""
    sync_dt = jnp.dtype(sync_dtype)
    maskf = jnp.asarray(worker_mask).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(maskf), 1.0)

    def avg(x, g):
        if x is None:
            return None
        d = (x - g[None]).astype(sync_dt)
        if topk_frac < 1.0:
            d = jax.vmap(lambda v: sparsify(v, topk_frac))(d)
        w = maskf.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        return jnp.sum(d * w, axis=0) / denom.astype(d.dtype)

    out = jax.tree.map(avg, frag_stack, theta_g_frag, is_leaf=_is_none)
    if barrier:
        flat = [d for d in jax.tree.leaves(out, is_leaf=_is_none)
                if d is not None]
        if flat:
            flat = list(jax.lax.optimization_barrier(tuple(flat)))
            it = iter(flat)
            out = jax.tree.map(lambda d: None if d is None else next(it), out,
                               is_leaf=_is_none)
    return jax.tree.map(lambda d: None if d is None else d.astype(jnp.float32),
                        out, is_leaf=_is_none)


def flat_pseudograd_mean(stack_flat, theta_flat, worker_mask, *, sync_dtype,
                         topk_frac: float = 1.0):
    """`pseudograd_mean` over flat-plane buffers: stack (M, rows, LANES) vs
    global (rows, LANES), masked mean in `sync_dtype`, back to f32 — the same
    element-for-element arithmetic, minus the per-leaf tree-map. Top-k
    sparsification ranks the fragment's concatenated (zero-padded) elements
    as ONE pool instead of per leaf — a documented flat-plane semantic."""
    sync_dt = jnp.dtype(sync_dtype)
    maskf = jnp.asarray(worker_mask).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(maskf), 1.0)
    d = (stack_flat - theta_flat[None]).astype(sync_dt)
    if topk_frac < 1.0:
        d = jax.vmap(lambda v: sparsify(v, topk_frac))(d)
    w = maskf.reshape((-1, 1, 1)).astype(d.dtype)
    out = jnp.sum(d * w, axis=0) / denom.astype(d.dtype)
    return out.astype(jnp.float32)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineState:
    theta_g: Any
    momentum: Any
    inflight_delta: Any
    inflight_snapshot: Any
    inflight_active: jax.Array    # (K,) bool
    inflight_t_init: jax.Array    # (K,) int32
    delta_norm: jax.Array         # (K,) f32
    last_sync: jax.Array          # (K,) int32 — t_{p,b} of Eq. 11
    rate: jax.Array               # (K,) f32  — R_p of Eq. 11 (+inf = never)
    worker_available: jax.Array   # (M,) bool
    # wire-codec error-feedback residual: ONE full-model-shaped f32 pytree
    # (fragments are disjoint, so per-fragment residuals never collide); None
    # unless an active codec has error feedback on — the codec-off pytree
    # structure (and every pre-codec checkpoint) is unchanged
    wire_residual: Any = None


jax.tree_util.register_dataclass(
    EngineState,
    data_fields=[f.name for f in dataclasses.fields(EngineState)],
    meta_fields=[])


def init_state(method: str, ccfg: CoCoDCConfig, params_stack,
               frag: Fragmenter | None = None) -> EngineState:
    """Build the initial state from the (identical-per-worker) params stack.
    With `ccfg.fused_updates` EVERY engine-owned buffer — theta_g, momentum,
    in-flight payloads, residual — lives on the flat plane (`frag.flat` row
    layout — `frag` is then required), so transitions touch them through
    static row slices with no pack/unpack copies; pytree views materialize
    only at external boundaries (`ProtocolEngine.theta_g/.momentum`). The
    params stack stays a pytree either way (it is the inner-loop interface)."""
    K, M, H = ccfg.num_fragments, ccfg.num_workers, ccfg.local_steps
    theta_g = jax.tree.map(lambda a: a[0], params_stack)
    impl = get_method(method)
    fused = ccfg.fused_updates
    if fused and frag is None:
        raise ValueError("fused_updates=True needs the Fragmenter (its flat "
                         "plane defines the buffer layout); pass frag=")
    ef_active = ccfg.wire_codec != "none" and ccfg.codec_error_feedback
    if fused:
        # flat plane: fragment-contiguous (total_rows, LANES) f32; fragment
        # addressing is a static row slice, so extract/insert vanish —
        # theta_g/momentum included (one pack at init, none per transition)
        theta_g = frag.flat.pack_full(theta_g)
        momentum = frag.flat.full_zeros()
        inflight_delta = frag.flat.full_zeros() if impl.overlapped else None
        inflight_snapshot = (frag.flat.full_zeros(M)
                             if impl.keeps_snapshot else None)
        wire_residual = frag.flat.full_zeros() if ef_active else None
    else:
        # only overlapped methods park payloads in flight; diloco/local would
        # otherwise carry a dead full-model f32 buffer through every round
        momentum = jax.tree.map(jnp.zeros_like, theta_g)
        inflight_delta = (jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), theta_g)
            if impl.overlapped else None)
        inflight_snapshot = (jax.tree.map(jnp.zeros_like, params_stack)
                             if impl.keeps_snapshot else None)
        wire_residual = (jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), theta_g)
            if ef_active else None)
    return EngineState(
        theta_g=theta_g,
        momentum=momentum,
        inflight_delta=inflight_delta,
        inflight_snapshot=inflight_snapshot,
        inflight_active=jnp.zeros((K,), bool),
        inflight_t_init=jnp.zeros((K,), jnp.int32),
        delta_norm=jnp.zeros((K,), jnp.float32),
        last_sync=jnp.full((K,), -H, jnp.int32),
        rate=jnp.full((K,), jnp.inf, jnp.float32),
        worker_available=jnp.ones((M,), bool),
        wire_residual=wire_residual,
    )


def state_to_dict(state: EngineState) -> dict:
    """EngineState -> plain field dict (checkpoint wire format). Pytree
    structure inside each field is preserved; msgpack can serialize the result
    where it cannot serialize the registered dataclass itself."""
    return {f.name: getattr(state, f.name)
            for f in dataclasses.fields(EngineState)}


def state_from_dict(ref: EngineState, d: dict) -> EngineState:
    """Rebuild an EngineState from `state_to_dict` output, casting every leaf
    to the dtype/shape of the matching leaf in `ref` (a live state from
    `init_state` — guarantees None-fields and bf16 leaves round-trip).

    Fields absent from `d` (e.g. `wire_residual` in a pre-codec checkpoint
    restored into a codec-enabled engine) keep the freshly-initialized `ref`
    value — error feedback simply restarts from a zero residual."""
    from repro.checkpoint.io import restore_like
    fields = {}
    for f in dataclasses.fields(EngineState):
        if f.name in d:
            fields[f.name] = restore_like(getattr(ref, f.name), d[f.name])
        else:
            fields[f.name] = getattr(ref, f.name)
    return EngineState(**fields)


# ---------------------------------------------------------------------------
# pure transitions
# ---------------------------------------------------------------------------


class EngineFns(NamedTuple):
    initiate: Any
    deliver: Any
    diloco_round: Any


# Declared donation per transition (argnums into the functions below). This
# is the contract the static-analysis donation audit enforces against the
# lowered computations (repro.analysis.jaxpr_audit.audit_donation): every
# pytree leaf of a donated arg must carry an aliasing annotation.
ENGINE_DONATION = {
    "initiate": (0,),          # state
    "deliver": (0, 2),         # state, params_stack
    "diloco_round": (0, 1),    # state, params_stack
}


def make_engine_fns(method: str, ccfg: CoCoDCConfig, frag: Fragmenter, *,
                    dc_impl: str = "ref", use_jit: bool = True,
                    fused_impl: str = "auto",
                    donate: bool | None = None) -> EngineFns:
    """Build the transition functions. `use_jit=False` executes the identical
    pure functions eagerly (the legacy host-side path — kept for golden-
    trajectory parity tests and debugging). The method-specific pieces (does
    this method snapshot local state at initiation? how is a delivered global
    fragment folded back into worker-local state?) come from the registered
    `SyncMethod` strategy, not from name branches.

    With `ccfg.fused_updates` the transitions route through the flat fragment
    plane (`frag.flat`) and kernels/outer_update: pack once, ONE fused
    Nesterov dispatch + ONE fused deliver dispatch per fragment transition
    (vs one delay-comp/blend call per leaf per stage), unpack once.
    `fused_impl` is that kernel family's impl policy ("auto" = pure-jnp
    oracle on CPU, Pallas elsewhere; "pallas" forces the kernel, interpret
    mode on CPU — used by the dispatch-count tests)."""
    M = ccfg.num_workers
    impl = get_method(method)
    # wire codec: when active, every outgoing delta is quantized+packed and
    # dequantized+unpacked through kernels/delta_codec at INITIATION — the
    # in-flight buffer then holds exactly what the receiver reconstructs from
    # the wire, and `deliver` reads the post-wire payload. Error feedback
    # (EF-SGD / Streaming DiLoCo style) folds the quantization residual of
    # each element into the same fragment's NEXT initiation, so the residual
    # is computed where compression happens. `wire_codec="none"` traces the
    # exact pre-codec program (no extra ops — bitwise-pinned by tests).
    codec_active = ccfg.wire_codec != "none"

    def _codec_roundtrip(d):
        return codec_ops.codec_roundtrip(d, codec=ccfg.wire_codec,
                                         block=ccfg.codec_block)

    def _mask_offline(new_local, old_local, avail):
        return jax.tree.map(
            lambda n, o: None if n is None else jnp.where(
                avail.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new_local, old_local, is_leaf=_is_none)

    def initiate(state: EngineState, t, params_stack, p: int) -> EngineState:
        """Start fragment p's all-reduce at step t: snapshot the worker-local
        fragment, compute the globally-averaged pseudo-gradient, park both in
        the fixed-capacity in-flight buffers."""
        theta_g_frag = frag.extract(state.theta_g, p)
        frag_stack = frag.extract(params_stack, p, worker_axis=True)
        delta_avg = pseudograd_mean(
            frag_stack, theta_g_frag, state.worker_available,
            sync_dtype=ccfg.sync_dtype, topk_frac=ccfg.sync_topk_frac)
        residual = state.wire_residual
        if codec_active:
            # fold in the fragment's standing EF residual, push the sum
            # through the wire codec (quantize+pack -> dequantize+unpack),
            # and keep what the codec dropped for the next initiation
            if residual is not None:
                d_in = _tree_add(delta_avg, frag.extract(residual, p))
            else:
                d_in = delta_avg
            delta_avg = _codec_roundtrip(d_in)
            if residual is not None:
                residual = frag.insert(residual, p,
                                       _tree_sub(d_in, delta_avg))
        snapshot = state.inflight_snapshot
        if impl.keeps_snapshot:
            snapshot = frag.insert(snapshot, p, frag_stack, worker_axis=True)
        return dataclasses.replace(
            state,
            inflight_delta=frag.insert(state.inflight_delta, p, delta_avg),
            inflight_snapshot=snapshot,
            inflight_active=state.inflight_active.at[p].set(True),
            inflight_t_init=state.inflight_t_init.at[p].set(t),
            delta_norm=state.delta_norm.at[p].set(tree_norm(delta_avg)),
            wire_residual=residual,
        )

    def deliver(state: EngineState, t, params_stack, p: int):
        """Fragment p's all-reduce completed at step t: outer Nesterov update
        of the global fragment, then the strategy's delivery application
        (Eq. 3 blending, Algorithm-1 delay compensation, ...), then the
        Eq. 11 rate update. With an active wire codec the in-flight buffer
        already holds the dequantized post-wire payload (the codec round
        trip runs at initiation, where the EF residual must be computed), so
        the delivered delta is exactly what crossed the WAN."""
        delta_avg = frag.extract(state.inflight_delta, p)
        theta_g_frag = frag.extract(state.theta_g, p)
        mom_frag = frag.extract(state.momentum, p)
        new_g, new_mom = outer_opt.nesterov_update(
            theta_g_frag, mom_frag, delta_avg,
            lr=ccfg.outer_lr, mu=ccfg.outer_momentum)

        local_now = frag.extract(params_stack, p, worker_axis=True)
        g_b = jax.tree.map(lambda g: None if g is None else g[None], new_g,
                           is_leaf=_is_none)
        snap = (frag.extract(state.inflight_snapshot, p, worker_axis=True)
                if impl.keeps_snapshot else None)
        new_local = impl.apply_delivery(
            ccfg, dc_impl, local_now=local_now, snapshot=snap, g_b=g_b,
            t=t, t_init=state.inflight_t_init[p])
        # offline workers keep their local state (they re-sync on return)
        new_local = _mask_offline(new_local, local_now, state.worker_available)

        interval = jnp.maximum(1, t - state.last_sync[p]).astype(jnp.float32)
        new_state = dataclasses.replace(
            state,
            theta_g=frag.insert(state.theta_g, p, new_g),
            momentum=frag.insert(state.momentum, p, new_mom),
            inflight_active=state.inflight_active.at[p].set(False),
            rate=state.rate.at[p].set(state.delta_norm[p] / interval),
            last_sync=state.last_sync.at[p].set(
                jnp.asarray(t, jnp.int32)),
        )
        params_stack = frag.insert(params_stack, p, new_local,
                                   worker_axis=True)
        return new_state, params_stack

    def diloco_round(state: EngineState, params_stack):
        """Blocking full-model round: all-reduce pseudo-gradients, outer
        update, available workers restart from the new theta^g. An active
        wire codec compresses the full-model delta the same way `initiate`
        compresses a fragment's."""
        delta_avg = pseudograd_mean(
            params_stack, state.theta_g, state.worker_available,
            sync_dtype=ccfg.sync_dtype, topk_frac=ccfg.sync_topk_frac)
        residual = state.wire_residual
        if codec_active:
            d_in = (_tree_add(delta_avg, residual) if residual is not None
                    else delta_avg)
            delta_avg = _codec_roundtrip(d_in)
            if residual is not None:
                residual = _tree_sub(d_in, delta_avg)
        new_g, new_mom = outer_opt.nesterov_update(
            state.theta_g, state.momentum, delta_avg,
            lr=ccfg.outer_lr, mu=ccfg.outer_momentum)
        reset = tree_broadcast_workers(new_g, M)
        params_stack = _mask_offline(reset, params_stack,
                                     state.worker_available)
        return (dataclasses.replace(state, theta_g=new_g, momentum=new_mom,
                                    wire_residual=residual),
                params_stack)

    if ccfg.fused_updates:
        if impl.overlapped and not impl.fused_delivery:
            raise ValueError(
                f"fused_updates=True: method {method!r} defines no "
                f"fused_delivery mode (kernels/outer_update supports: "
                f"{ou_ops.DELIVER_MODES}); run it with fused_updates=False")
        flat = frag.flat

        def initiate(state: EngineState, t, params_stack, p: int) -> EngineState:  # noqa: F811
            """Fused initiation: theta is ALREADY flat (a free static row
            slice); pack the worker stack's fragment once, ONE flat
            pseudo-gradient mean, ONE codec round trip over the fragment's
            concatenated elements, park via static row slices."""
            r0, r1 = flat.row_span(p)
            theta_flat = state.theta_g[r0:r1]
            stack_flat = flat.pack_stack(params_stack, p)
            delta = flat_pseudograd_mean(
                stack_flat, theta_flat, state.worker_available,
                sync_dtype=ccfg.sync_dtype, topk_frac=ccfg.sync_topk_frac)
            residual = state.wire_residual
            if codec_active:
                d_in = (delta + residual[r0:r1] if residual is not None
                        else delta)
                delta = codec_ops.codec_roundtrip_array(
                    d_in, codec=ccfg.wire_codec, block=ccfg.codec_block)
                if residual is not None:
                    residual = residual.at[r0:r1].set(d_in - delta)
            snapshot = state.inflight_snapshot
            if impl.keeps_snapshot:
                snapshot = snapshot.at[:, r0:r1].set(stack_flat)
            return dataclasses.replace(
                state,
                inflight_delta=state.inflight_delta.at[r0:r1].set(delta),
                inflight_snapshot=snapshot,
                inflight_active=state.inflight_active.at[p].set(True),
                inflight_t_init=state.inflight_t_init.at[p].set(t),
                delta_norm=state.delta_norm.at[p].set(
                    jnp.sqrt(jnp.sum(jnp.square(delta)))),
                wire_residual=residual,
            )

        def deliver(state: EngineState, t, params_stack, p: int):  # noqa: F811
            """Fused delivery: the in-flight payload is already a flat row
            slice; ONE fused Nesterov dispatch updates theta+momentum, ONE
            fused deliver dispatch chains the method's blend/compensation
            with offline-worker masking over the whole worker stack."""
            r0, r1 = flat.row_span(p)
            delta = state.inflight_delta[r0:r1]
            theta_flat = state.theta_g[r0:r1]
            mom_flat = state.momentum[r0:r1]
            new_g, new_mom = ou_ops.outer_nesterov(
                theta_flat, mom_flat, delta,
                lr=ccfg.outer_lr, mu=ccfg.outer_momentum, impl=fused_impl)
            local_flat = flat.pack_stack(params_stack, p)
            snap = (state.inflight_snapshot[:, r0:r1]
                    if impl.keeps_snapshot else None)
            new_local = ou_ops.fused_deliver(
                local_flat, snap, new_g, state.worker_available,
                mode=impl.fused_delivery, impl=fused_impl,
                **impl.fused_delivery_kwargs(
                    ccfg, t=t, t_init=state.inflight_t_init[p]))
            interval = jnp.maximum(1, t - state.last_sync[p]).astype(
                jnp.float32)
            new_state = dataclasses.replace(
                state,
                theta_g=state.theta_g.at[r0:r1].set(new_g),
                momentum=state.momentum.at[r0:r1].set(new_mom),
                inflight_active=state.inflight_active.at[p].set(False),
                rate=state.rate.at[p].set(state.delta_norm[p] / interval),
                last_sync=state.last_sync.at[p].set(
                    jnp.asarray(t, jnp.int32)),
            )
            params_stack = flat.unpack_stack(params_stack, p, new_local)
            return new_state, params_stack

        def diloco_round(state: EngineState, params_stack):  # noqa: F811
            """Fused blocking round: theta/momentum are already full-model
            flat planes; the worker reset is the fused deliver kernel at
            blend alpha=1 (broadcast + offline mask in one dispatch)."""
            theta_flat = state.theta_g
            stack_flat = flat.pack_full(params_stack, worker_axis=True)
            delta = flat_pseudograd_mean(
                stack_flat, theta_flat, state.worker_available,
                sync_dtype=ccfg.sync_dtype, topk_frac=ccfg.sync_topk_frac)
            residual = state.wire_residual
            if codec_active:
                d_in = delta + residual if residual is not None else delta
                delta = codec_ops.codec_roundtrip_array(
                    d_in, codec=ccfg.wire_codec, block=ccfg.codec_block)
                if residual is not None:
                    residual = d_in - delta
            mom_flat = state.momentum
            new_g, new_mom = ou_ops.outer_nesterov(
                theta_flat, mom_flat, delta,
                lr=ccfg.outer_lr, mu=ccfg.outer_momentum, impl=fused_impl)
            new_local = ou_ops.fused_deliver(
                stack_flat, None, new_g, state.worker_available,
                mode="blend", alpha=1.0, impl=fused_impl)
            return (dataclasses.replace(
                        state,
                        theta_g=new_g,
                        momentum=new_mom,
                        wire_residual=residual),
                    flat.unpack_full(params_stack, new_local,
                                     worker_axis=True))

    if use_jit:
        # donation elides the state/params copies on accelerators; CPU (tests)
        # does not implement donation and would warn on every call. `donate`
        # overrides the backend gate — the donation audit forces it on to
        # inspect the accelerator wiring at lower time without compiling.
        can_donate = (jax.default_backend() != "cpu" if donate is None
                      else donate)
        initiate = jax.jit(
            initiate, static_argnames=("p",),
            donate_argnums=ENGINE_DONATION["initiate"] if can_donate else ())
        deliver = jax.jit(
            deliver, static_argnames=("p",),
            donate_argnums=ENGINE_DONATION["deliver"] if can_donate else ())
        diloco_round = jax.jit(
            diloco_round,
            donate_argnums=(ENGINE_DONATION["diloco_round"] if can_donate
                            else ()))
    return EngineFns(initiate=initiate, deliver=deliver,
                     diloco_round=diloco_round)
