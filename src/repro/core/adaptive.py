"""CoCoDC adaptive transmission (paper §III-B: Eqs. 9-12, Algorithm 2).

Decides how often to initiate fragment syncs (Eq. 9/10) and which fragment goes
next (Algorithm 2). The decision is a pure function of globally shared history
(completed-sync steps and ||Delta^g_p|| metrics), so every worker computes the same
schedule with zero coordination messages — exactly the paper's determinism claim,
and the property test in tests/test_adaptive.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional


@dataclasses.dataclass
class AdaptiveState:
    """Shared (deterministically replicated) scheduler state."""
    K: int
    H: int
    # last completed-sync step per fragment (t_{p,b}); -inf-ish before first sync
    last_sync: List[int] = None
    # change-rate metric R_p (Eq. 11); fragments never synced get +inf priority
    rate: List[float] = None

    def __post_init__(self):
        if self.last_sync is None:
            self.last_sync = [-self.H] * self.K
        if self.rate is None:
            self.rate = [math.inf] * self.K


def target_syncs(K: int, H: int, t_c: float, t_s: float, gamma: float) -> int:
    """Eq. 9: N = max(K, floor(gamma * H * T_c / T_s))."""
    if t_s <= 0:
        return K
    return max(K, math.floor(gamma * H * t_c / t_s))


def sync_interval(H: int, N: int) -> int:
    """Eq. 10: h = floor(H / N) local steps between initiations."""
    return max(1, H // N)


def update_rate(state: AdaptiveState, p: int, delta_norm: float, t_complete: int):
    """Eq. 11 on sync completion: R_p = ||Delta^g_p||_2 / I_p with
    I_p = t_complete - t_{p,b}."""
    interval = max(1, t_complete - state.last_sync[p])
    state.rate[p] = float(delta_norm) / interval
    state.last_sync[p] = t_complete


def select_fragment(state: AdaptiveState, t_current: int,
                    in_flight: Optional[set] = None,
                    costs: Optional[List[float]] = None) -> int:
    """Algorithm 2. in_flight fragments are excluded (can't double-send one
    fragment's all-reduce on the single WAN channel).

    `costs` (optional) prices fragments per WAN transfer: costs[p] is the
    simulated seconds one sync of fragment p occupies the topology's
    bottleneck links, so the priority becomes change-rate per WAN-second
    (R_p / cost_p) instead of raw R_p. Under a heterogeneous topology this
    prefers cheap fragments when rates are comparable; with uniform costs it
    reduces exactly to Eq. 12."""
    in_flight = in_flight or set()
    candidates = [p for p in range(state.K) if p not in in_flight]
    if not candidates:
        raise ValueError("all fragments in flight")
    # anti-starvation: any fragment idle >= H steps goes first (lowest idx wins,
    # deterministic)
    for p in candidates:
        if t_current - state.last_sync[p] >= state.H:
            return p

    def priority(p: int) -> float:
        r = state.rate[p]
        if costs is None:
            return r
        c = max(costs[p], 1e-12)
        return r / c if math.isfinite(r) else r
    # Eq. 12: argmax R_p [/ cost_p] (ties -> lowest index, deterministic)
    best = max(candidates, key=lambda p: (priority(p), -p))
    return best
