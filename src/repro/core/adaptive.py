"""CoCoDC adaptive transmission (paper §III-B: Eqs. 9-12, Algorithm 2).

Decides how often to initiate fragment syncs (Eq. 9/10) and which fragment goes
next (Algorithm 2). The decision is a pure function of globally shared history
(completed-sync steps and ||Delta^g_p|| metrics), so every worker computes the same
schedule with zero coordination messages — exactly the paper's determinism claim,
and the property test in tests/test_adaptive.py.

``ResyncState`` extends the same contract to a time-varying network: Eq. 9
derives the target sync count N from T_s, but on dynamic links the startup
T_s goes stale (a diurnal trough or outage can double it). The engine feeds
the MEASURED durations of completed transfers — shared history, identical on
every replica — into a bounded window, and re-derives N (and Eq. 10's h) once
per outer round from the window mean.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass
class AdaptiveState:
    """Shared (deterministically replicated) scheduler state."""
    K: int
    H: int
    # last completed-sync step per fragment (t_{p,b}); -inf-ish before first
    # sync. Empty = derive the defaults from K/H below (a dataclass default
    # cannot see sibling fields, so the fill-in happens in __post_init__).
    last_sync: List[int] = dataclasses.field(default_factory=list)
    # change-rate metric R_p (Eq. 11); fragments never synced get +inf priority
    rate: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.last_sync:
            self.last_sync = [-self.H] * self.K
        if not self.rate:
            self.rate = [math.inf] * self.K


def target_syncs(K: int, H: int, t_c: float, t_s: float, gamma: float) -> int:
    """Eq. 9: N = max(K, floor(gamma * H * T_c / T_s))."""
    if t_s <= 0:
        return K
    return max(K, math.floor(gamma * H * t_c / t_s))


def sync_interval(H: int, N: int) -> int:
    """Eq. 10: h = floor(H / N) local steps between initiations."""
    return max(1, H // N)


@dataclasses.dataclass
class ResyncState:
    """Bounded window of MEASURED fragment-transfer durations (wall seconds,
    queueing excluded) used to re-derive Eq. 9's N when link dynamics shift
    the real T_s away from the startup estimate. The window contents are
    shared history (transfer completions every replica observes), so the
    re-derivation inherits Algorithm 2's zero-coordination determinism; the
    engine serializes the window for exact checkpoint/resume."""
    window: int = 8
    measured: List[float] = dataclasses.field(default_factory=list)

    def observe(self, t_s: float):
        """Record one completed transfer's measured duration."""
        self.measured.append(float(t_s))
        del self.measured[:-self.window]

    @property
    def t_s_estimate(self) -> Optional[float]:
        """Window-mean measured T_s; None until the first completion."""
        if not self.measured:
            return None
        return sum(self.measured) / len(self.measured)


def rederive_schedule(resync: ResyncState, K: int, H: int, t_c: float,
                      gamma: float, fallback_t_s: float) -> Tuple[int, int]:
    """Eq. 9/10 against the measured T_s (startup estimate until the first
    transfer completes): returns (N, h) for the next outer round."""
    t_s = resync.t_s_estimate
    if t_s is None:
        t_s = fallback_t_s
    n = target_syncs(K, H, t_c, t_s, gamma)
    return n, sync_interval(H, n)


def update_rate(state: AdaptiveState, p: int, delta_norm: float, t_complete: int):
    """Eq. 11 on sync completion: R_p = ||Delta^g_p||_2 / I_p with
    I_p = t_complete - t_{p,b}."""
    interval = max(1, t_complete - state.last_sync[p])
    state.rate[p] = float(delta_norm) / interval
    state.last_sync[p] = t_complete


def select_fragment(state: AdaptiveState, t_current: int,
                    in_flight: Optional[set] = None,
                    costs: Optional[List[float]] = None) -> int:
    """Algorithm 2. in_flight fragments are excluded (can't double-send one
    fragment's all-reduce on the single WAN channel).

    `costs` (optional) prices fragments per WAN transfer: costs[p] is the
    simulated seconds one sync of fragment p occupies the topology's
    bottleneck links, so the priority becomes change-rate per WAN-second
    (R_p / cost_p) instead of raw R_p. Under a heterogeneous topology this
    prefers cheap fragments when rates are comparable; with uniform costs it
    reduces exactly to Eq. 12."""
    in_flight = in_flight or set()
    candidates = [p for p in range(state.K) if p not in in_flight]
    if not candidates:
        raise ValueError("all fragments in flight")
    # anti-starvation: any fragment idle >= H steps goes first (lowest idx wins,
    # deterministic)
    for p in candidates:
        if t_current - state.last_sync[p] >= state.H:
            return p

    def priority(p: int) -> float:
        r = state.rate[p]
        if costs is None:
            return r
        c = max(costs[p], 1e-12)
        return r / c if math.isfinite(r) else r
    # Eq. 12: argmax R_p [/ cost_p] (ties -> lowest index, deterministic)
    best = max(candidates, key=lambda p: (priority(p), -p))
    return best
