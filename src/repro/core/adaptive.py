"""CoCoDC adaptive transmission (paper §III-B: Eqs. 9-12, Algorithm 2).

Decides how often to initiate fragment syncs (Eq. 9/10) and which fragment goes
next (Algorithm 2). The decision is a pure function of globally shared history
(completed-sync steps and ||Delta^g_p|| metrics), so every worker computes the same
schedule with zero coordination messages — exactly the paper's determinism claim,
and the property test in tests/test_adaptive.py.

``ResyncState`` extends the same contract to a time-varying network: Eq. 9
derives the target sync count N from T_s, but on dynamic links the startup
T_s goes stale (a diurnal trough or outage can double it). The engine feeds
the MEASURED durations of completed transfers — shared history, identical on
every replica — into a bounded window, and re-derives N (and Eq. 10's h) once
per outer round from the window mean.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass
class AdaptiveState:
    """Shared (deterministically replicated) scheduler state."""
    K: int
    H: int
    # last completed-sync step per fragment (t_{p,b}); -inf-ish before first
    # sync. Empty = derive the defaults from K/H below (a dataclass default
    # cannot see sibling fields, so the fill-in happens in __post_init__).
    last_sync: List[int] = dataclasses.field(default_factory=list)
    # change-rate metric R_p (Eq. 11); fragments never synced get +inf priority
    rate: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.last_sync:
            self.last_sync = [-self.H] * self.K
        if not self.rate:
            self.rate = [math.inf] * self.K


def target_syncs(K: int, H: int, t_c: float, t_s: float, gamma: float) -> int:
    """Eq. 9: N = max(K, floor(gamma * H * T_c / T_s))."""
    if t_s <= 0:
        return K
    return max(K, math.floor(gamma * H * t_c / t_s))


def sync_interval(H: int, N: int) -> int:
    """Eq. 10: h = floor(H / N) local steps between initiations."""
    return max(1, H // N)


@dataclasses.dataclass
class ResyncState:
    """Bounded window of MEASURED fragment-transfer durations (wall seconds,
    queueing excluded) used to re-derive Eq. 9's N when link dynamics shift
    the real T_s away from the startup estimate. The window contents are
    shared history (transfer completions every replica observes), so the
    re-derivation inherits Algorithm 2's zero-coordination determinism; the
    engine serializes the window for exact checkpoint/resume."""
    window: int = 8
    measured: List[float] = dataclasses.field(default_factory=list)
    # wire bytes paired with each measured duration (0 = size unknown, e.g.
    # a pre-v6 checkpoint window) — the latency/bandwidth decomposition input
    measured_bytes: List[float] = dataclasses.field(default_factory=list)

    def observe(self, t_s: float, nbytes: float = 0.0):
        """Record one completed transfer's measured duration (and its wire
        bytes, when known)."""
        self.measured.append(float(t_s))
        self.measured_bytes.append(float(nbytes))
        del self.measured[:-self.window]
        del self.measured_bytes[:-self.window]

    @property
    def t_s_estimate(self) -> Optional[float]:
        """Window-mean measured T_s; None until the first completion."""
        if not self.measured:
            return None
        return sum(self.measured) / len(self.measured)

    def decomposed_t_s(self, ref_bytes: float,
                       lat_s: float = 0.0) -> Optional[float]:
        """Latency/bandwidth decomposition of the window: least-squares fit
        ``T ~= a + m * bytes`` over the (bytes, duration) samples and return
        the BANDWIDTH-only cost ``ref_bytes * m`` of a reference payload.
        Eq. 9's gamma budget then prices link occupancy rather than
        propagation delay — under congestion (fair-share contention) the
        slope steepens and the cadence backs off, while pure latency inflation
        no longer suppresses syncs that cost almost no bandwidth.

        The slope needs spread to identify: with < 3 sized samples, < 5%
        byte spread, or a non-positive fitted slope, fall back to anchoring
        the intercept at the KNOWN propagation latency ``lat_s``
        (m = mean((T - lat_s)/bytes)). None when no sample carries a size."""
        pairs = [(b, t) for b, t in zip(self.measured_bytes, self.measured)
                 if b > 0.0]
        if not pairs:
            return None
        n = len(pairs)
        mb = sum(b for b, _ in pairs) / n
        mt = sum(t for _, t in pairs) / n
        var = sum((b - mb) ** 2 for b, _ in pairs)
        slope = None
        spread = max(b for b, _ in pairs) - min(b for b, _ in pairs)
        if n >= 3 and var > 0.0 and spread > 0.05 * mb:
            m = sum((b - mb) * (t - mt) for b, t in pairs) / var
            if m > 0.0:
                slope = m
        if slope is None:
            slope = sum(max(t - lat_s, 0.0) / b for b, t in pairs) / n
        return float(ref_bytes) * slope


def rederive_schedule(resync: ResyncState, K: int, H: int, t_c: float,
                      gamma: float, fallback_t_s: float, *,
                      decompose: bool = False, ref_bytes: float = 0.0,
                      lat_s: float = 0.0) -> Tuple[int, int]:
    """Eq. 9/10 against the measured T_s (startup estimate until the first
    transfer completes): returns (N, h) for the next outer round.

    ``decompose=True`` replaces the raw window mean with the
    latency/bandwidth decomposition (`ResyncState.decomposed_t_s`): T_s
    becomes the bandwidth-only cost of a `ref_bytes` payload, so the derived
    cadence responds to congestion rather than propagation delay. The default
    keeps the window-mean arithmetic byte-for-byte."""
    if decompose:
        t_bw = None if resync is None else resync.decomposed_t_s(ref_bytes,
                                                                 lat_s)
        if t_bw is None:
            t_bw = max(fallback_t_s - lat_s, 0.0)
        # floor keeps N finite on latency-dominated links (t_bw -> 0 would
        # otherwise degenerate Eq. 9 to its K guard)
        n = target_syncs(K, H, t_c, max(t_bw, 1e-9), gamma)
        return n, sync_interval(H, n)
    t_s = resync.t_s_estimate
    if t_s is None:
        t_s = fallback_t_s
    n = target_syncs(K, H, t_c, t_s, gamma)
    return n, sync_interval(H, n)


def update_rate(state: AdaptiveState, p: int, delta_norm: float, t_complete: int):
    """Eq. 11 on sync completion: R_p = ||Delta^g_p||_2 / I_p with
    I_p = t_complete - t_{p,b}."""
    interval = max(1, t_complete - state.last_sync[p])
    state.rate[p] = float(delta_norm) / interval
    state.last_sync[p] = t_complete


def select_fragment(state: AdaptiveState, t_current: int,
                    in_flight: Optional[set] = None,
                    costs: Optional[List[float]] = None) -> int:
    """Algorithm 2. in_flight fragments are excluded (can't double-send one
    fragment's all-reduce on the single WAN channel).

    `costs` (optional) prices fragments per WAN transfer: costs[p] is the
    simulated seconds one sync of fragment p occupies the topology's
    bottleneck links, so the priority becomes change-rate per WAN-second
    (R_p / cost_p) instead of raw R_p. Under a heterogeneous topology this
    prefers cheap fragments when rates are comparable; with uniform costs it
    reduces exactly to Eq. 12."""
    in_flight = in_flight or set()
    candidates = [p for p in range(state.K) if p not in in_flight]
    if not candidates:
        raise ValueError("all fragments in flight")
    # anti-starvation: any fragment idle >= H steps goes first (lowest idx wins,
    # deterministic)
    for p in candidates:
        if t_current - state.last_sync[p] >= state.H:
            return p

    def priority(p: int) -> float:
        r = state.rate[p]
        if costs is None:
            return r
        c = max(costs[p], 1e-12)
        return r / c if math.isfinite(r) else r
    # Eq. 12: argmax R_p [/ cost_p] (ties -> lowest index, deterministic)
    best = max(candidates, key=lambda p: (priority(p), -p))
    return best
