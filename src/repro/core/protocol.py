"""Event-driven cross-region protocol engines: DiLoCo, Streaming DiLoCo, CoCoDC.

The engine owns the *cross-region* coordination state: the global model theta^g,
the outer (Nesterov) momentum, the set of in-flight fragment all-reduces, the
adaptive-transmission scheduler, and the simulated WAN wall-clock. Worker-local
training (inner AdamW steps) happens outside, on a worker-stacked params pytree
(leading axis M, sharded over the `pod` mesh axis in the multi-pod deployment).

Timeline semantics (faithful to the paper):
  * every local step costs T_c;
  * DiLoCo: at t % H == H-1, a BLOCKING full-model all-reduce (wall += T_s_full),
    outer update, and all workers restart from theta^g;
  * Streaming DiLoCo: fragment p's all-reduce is initiated on a fixed round-robin
    schedule (one fragment every H/K steps) and completes tau steps later; on
    completion: outer update of the fragment, then Eq. 3 blending;
  * CoCoDC: initiations every h = H/N steps (Eq. 9/10), fragment chosen by
    Algorithm 2; local fragment snapshot taken at initiation; on completion:
    outer update, then Algorithm 1 delay compensation; R_p updated (Eq. 11).

The cross-pod mean over the worker axis is the ONLY cross-region collective; under
the multi-pod mesh it lowers to an all-reduce over the `pod` axis (verified in the
dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CoCoDCConfig
from repro.core import adaptive as adaptive_lib
from repro.core import delay_comp as dc_lib
from repro.core import outer_opt
from repro.core.fragments import Fragmenter
from repro.core.network import NetworkModel


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: None if x is None else x - y, a, b,
                        is_leaf=lambda x: x is None)


def _tree_worker_mean(a):
    return jax.tree.map(lambda x: None if x is None else jnp.mean(x, axis=0), a,
                        is_leaf=lambda x: x is None)


def _tree_broadcast_workers(a, m):
    return jax.tree.map(
        lambda x: None if x is None else jnp.broadcast_to(x[None], (m,) + x.shape),
        a, is_leaf=lambda x: x is None)


def _tree_norm(a) -> jax.Array:
    leaves = [l for l in jax.tree.leaves(a) if l is not None]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


@dataclasses.dataclass
class InFlight:
    frag: int
    t_init: int
    deliver_at: int
    delta_avg: Any            # globally-averaged pseudo-gradient (the all-reduce)
    snapshot: Any             # worker-stacked local fragment at t_init (CoCoDC)
    delta_norm: jax.Array


class ProtocolEngine:
    """One engine instance per training run. Methods mutate engine state and
    return the (possibly updated) worker-stacked params."""

    def __init__(self, method: str, ccfg: CoCoDCConfig, fragmenter: Fragmenter,
                 network: NetworkModel, params_stack, *, dc_impl: str = "ref"):
        assert method in ("diloco", "streaming", "cocodc", "local")
        self.method = method
        self.cfg = ccfg
        self.frag = fragmenter
        self.net = network
        self.dc_impl = dc_impl
        self.M = ccfg.num_workers
        self.K = ccfg.num_fragments
        self.H = ccfg.local_steps
        self.tau = ccfg.overlap_depth
        # global model starts at the (identical) worker init
        self.theta_g = jax.tree.map(lambda a: a[0], params_stack)
        self.momentum = jax.tree.map(jnp.zeros_like, self.theta_g)
        self.in_flight: List[InFlight] = []
        self.adaptive = adaptive_lib.AdaptiveState(K=self.K, H=self.H)
        # Eq. 9/10 scheduling interval
        mean_frag_bytes = self.frag.total_bytes / self.K
        t_s = network.t_s(int(mean_frag_bytes))
        self.N = adaptive_lib.target_syncs(self.K, self.H, network.t_c, t_s,
                                           ccfg.net_utilization)
        self.h_cocodc = adaptive_lib.sync_interval(self.H, self.N)
        self.h_stream = max(1, self.H // self.K)
        # partial participation (straggler tolerance, beyond-paper): offline
        # workers neither contribute to nor receive fragment syncs
        self.worker_available = [True] * self.M
        # stats
        self.wall_clock = 0.0
        self.comm_seconds = 0.0
        self.bytes_sent = 0
        self.n_syncs = 0
        self._channel_free_at = 0.0

    # ------------------------------------------------------------------ utils

    def set_worker_availability(self, worker: int, available: bool):
        """Mark a datacenter online/offline (WAN partition / maintenance).
        Offline workers are excluded from subsequent syncs until restored."""
        self.worker_available[worker] = available

    def _sparsify(self, d):
        """Top-k magnitude sparsification per leaf (sync_topk_frac < 1)."""
        frac = self.cfg.sync_topk_frac
        if frac >= 1.0 or d.size == 0:
            return d
        k = max(1, int(d.size * frac))
        flat = jnp.abs(d.reshape(-1))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(d) >= thresh, d, jnp.zeros((), d.dtype))

    def _allreduce(self, frag_stack, theta_g_frag):
        """The cross-region collective: mean over the AVAILABLE workers of the
        pseudo-gradients. Under the multi-pod mesh this is the pod all-reduce.
        Payload crosses the WAN in cfg.sync_dtype (bf16 compression is a
        beyond-paper option), optionally top-k-sparsified; accumulation
        returns to f32."""
        sync_dt = jnp.dtype(self.cfg.sync_dtype)
        mask = jnp.asarray(self.worker_available, jnp.float32)
        denom = jnp.maximum(jnp.sum(mask), 1.0)

        def avg(x, g):
            if x is None:
                return None
            d = (x - g[None]).astype(sync_dt)
            if self.cfg.sync_topk_frac < 1.0:
                d = jax.vmap(self._sparsify)(d)
            w = mask.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
            return (jnp.sum(d * w, axis=0) / denom.astype(d.dtype)
                    ).astype(jnp.float32)

        return jax.tree.map(avg, frag_stack, theta_g_frag,
                            is_leaf=lambda x: x is None)

    def _account_transfer(self, nbytes: int):
        if jnp.dtype(self.cfg.sync_dtype).itemsize < 4:
            nbytes = nbytes * jnp.dtype(self.cfg.sync_dtype).itemsize // 4
        if self.cfg.sync_topk_frac < 1.0:
            # sparse wire format: values + indices
            nbytes = int(nbytes * min(1.0, 2 * self.cfg.sync_topk_frac))
        t_s = self.net.t_s(nbytes)
        start = max(self.wall_clock, self._channel_free_at)
        self._channel_free_at = start + t_s
        self.comm_seconds += t_s
        self.bytes_sent += nbytes
        self.n_syncs += 1

    # ------------------------------------------------------------ initiation

    def _initiate(self, t: int, params_stack, p: int):
        theta_g_frag = self.frag.extract(self.theta_g, p)
        frag_stack = self.frag.extract(params_stack, p, worker_axis=True)
        delta_avg = self._allreduce(frag_stack, theta_g_frag)
        self.in_flight.append(InFlight(
            frag=p, t_init=t, deliver_at=t + self.tau, delta_avg=delta_avg,
            snapshot=frag_stack if self.method == "cocodc" else None,
            delta_norm=_tree_norm(delta_avg)))
        self._account_transfer(self.frag.fragment_bytes(p))

    # -------------------------------------------------------------- delivery

    def _deliver(self, t: int, params_stack, ev: InFlight):
        p = ev.frag
        theta_g_frag = self.frag.extract(self.theta_g, p)
        mom_frag = self.frag.extract(self.momentum, p)
        new_g, new_mom = outer_opt.nesterov_update(
            theta_g_frag, mom_frag, ev.delta_avg,
            lr=self.cfg.outer_lr, mu=self.cfg.outer_momentum)
        self.theta_g = self.frag.insert(self.theta_g, p, new_g)
        self.momentum = self.frag.insert(self.momentum, p, new_mom)

        local_now = self.frag.extract(params_stack, p, worker_axis=True)
        avail = jnp.asarray(self.worker_available, bool)
        if self.method == "streaming":
            new_local = dc_lib.blend(
                local_now,
                jax.tree.map(lambda g: None if g is None else g[None], new_g,
                             is_leaf=lambda x: x is None),
                alpha=self.cfg.mixing_alpha)
        else:  # cocodc — Algorithm 1
            tau_actual = max(1, t - ev.t_init)
            new_local = dc_lib.compensate(
                local_now, ev.snapshot,
                jax.tree.map(lambda g: None if g is None else g[None], new_g,
                             is_leaf=lambda x: x is None),
                tau=float(tau_actual), lam=self.cfg.comp_lambda, H=float(self.H),
                sign=self.cfg.eq4_sign, impl=self.dc_impl)
        if not all(self.worker_available):
            # offline workers keep their local state (they re-sync on return)
            new_local = jax.tree.map(
                lambda n, o: None if n is None else jnp.where(
                    avail.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
                new_local, local_now, is_leaf=lambda x: x is None)
        params_stack = self.frag.insert(params_stack, p, new_local,
                                        worker_axis=True)
        # Eq. 11 metric update (identical on all workers: uses the shared delta)
        adaptive_lib.update_rate(self.adaptive, p, float(ev.delta_norm), t)
        return params_stack

    # ------------------------------------------------------------- main hook

    def on_step_end(self, t: int, params_stack):
        """Call after inner step t (0-based). Returns updated params_stack."""
        self.wall_clock += self.net.t_c
        if self.method == "local":
            return params_stack

        if self.method == "diloco":
            if (t + 1) % self.H == 0:
                delta_avg = self._allreduce(params_stack, self.theta_g)
                self.theta_g, self.momentum = outer_opt.nesterov_update(
                    self.theta_g, self.momentum, delta_avg,
                    lr=self.cfg.outer_lr, mu=self.cfg.outer_momentum)
                t_s = self.net.t_s(self.frag.total_bytes)
                self.wall_clock += t_s       # BLOCKING
                self.comm_seconds += t_s
                self.bytes_sent += self.frag.total_bytes
                self.n_syncs += 1
                params_stack = _tree_broadcast_workers(self.theta_g, self.M)
            return params_stack

        # --- overlapped methods: deliveries due at this step ---------------
        due = [ev for ev in self.in_flight if ev.deliver_at <= t]
        for ev in sorted(due, key=lambda e: e.deliver_at):
            params_stack = self._deliver(t, params_stack, ev)
            self.in_flight.remove(ev)

        # --- initiations ----------------------------------------------------
        if self.method == "streaming":
            if t % self.h_stream == 0:
                p = (t // self.h_stream) % self.K
                if all(ev.frag != p for ev in self.in_flight):
                    self._initiate(t, params_stack, p)
        else:  # cocodc
            if t % self.h_cocodc == 0:
                busy = {ev.frag for ev in self.in_flight}
                if len(busy) < self.K:
                    p = adaptive_lib.select_fragment(self.adaptive, t, busy)
                    self._initiate(t, params_stack, p)
        return params_stack

    # ---------------------------------------------------------------- stats

    def stats(self) -> Dict[str, float]:
        return {
            "wall_clock_s": self.wall_clock,
            "comm_seconds": self.comm_seconds,
            "bytes_sent": float(self.bytes_sent),
            "n_syncs": float(self.n_syncs),
            "overlap_ratio": (0.0 if self.wall_clock == 0 else
                              min(1.0, self.comm_seconds / self.wall_clock)),
            "target_syncs_N": float(self.N),
        }
