"""Event-driven cross-region protocol engines: DiLoCo, Streaming DiLoCo, CoCoDC.

The engine is a THIN HOST WRAPPER: all device state (global model theta^g,
outer Nesterov momentum, the fixed-capacity in-flight fragment buffers, the
adaptive-transmission rates, the availability mask) lives in a single
`EngineState` pytree (core/engine_state.py), and every device mutation is one
pure, jit-compiled transition call. The wrapper owns only host-side scalars:
the simulated WAN wall-clock, WAN-channel queueing, per-link traffic matrices,
and the deterministic schedule of WHICH fragment goes WHEN.

Timeline semantics (faithful to the paper):
  * every local step costs T_c;
  * DiLoCo: at t % H == H-1, a BLOCKING full-model all-reduce (wall += T_s_full),
    outer update, and all workers restart from theta^g;
  * Streaming DiLoCo: fragment p's all-reduce is initiated on a fixed round-robin
    schedule (one fragment every H/K steps); on completion: outer update of the
    fragment, then Eq. 3 blending;
  * CoCoDC: initiations every h = H/N steps (Eq. 9/10), fragment chosen by
    Algorithm 2; local fragment snapshot taken at initiation; on completion:
    outer update, then Algorithm 1 delay compensation; R_p updated (Eq. 11).

Delivery times are DERIVED, not fixed: a fragment initiated at step t completes
at the simulated transfer finish time — queueing behind earlier transfers when
all `Topology.concurrent_collectives` WAN channels are busy, and paced by the
slowest inter-region link of the collective (ring or hierarchical). Under the
symmetric paper-calibrated network with a free channel this reduces exactly to
the paper's `t + tau`.

When the topology carries a `LinkDynamics` layer, a transfer's completion is
the time-INTEGRAL of the bottleneck bandwidth factor (diurnal troughs, outage
windows with retry, seeded per-transfer jitter) — see
`Topology.transfer_time`. The engine then also accounts `stall_seconds` (time
lost vs the nominal static cost) and `n_retries`, and owns the jitter draw
counter so checkpoint/resume replays the identical transfer schedule.
`dynamics=None` follows the original static arithmetic bitwise (pinned by
tests/test_network_dynamics.py).

With `CoCoDCConfig.routing="routed"` every collective executes over a
`CommPlan` from the deterministic `RoutePlanner` (core/network.py): multi-hop
min-cost routes over the CURRENT link state, re-planned whenever a
`LinkDynamics.next_change` edge passes, with optional hub failover
(`hub_failover=True`: dark regions drop out of the collective and the
next-best-connected region stands in as hub until recovery). The Algorithm-2
cost vector is refreshed from the active plan on every re-plan, and
`adaptive_resync=True` re-derives Eq. 9's N / Eq. 10's h once per outer round
from the measured durations of completed transfers. `routing="static"`
(default) keeps every pre-routing code path — and the PR 3 golden delivery
schedules — bitwise.

The cross-pod mean over the worker axis is the ONLY cross-region collective;
under the multi-pod mesh it lowers to an all-reduce over the `pod` axis
(verified in the dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import CoCoDCConfig
from repro.core import adaptive as adaptive_lib
from repro.core import engine_state as es
from repro.core.fragments import Fragmenter
from repro.core.methods import get_method
from repro.core.network import (CommPlan, FairShareSim, RoutePlanner, Topology,
                                as_topology)

# Host-scheduler checkpoint schema. One upgrade path
# (`upgrade_scheduler_state`) replaces the `.get(...)`-default sprawl that
# accumulated as PRs added fields:
#   v1 (PR 2) — pending/seq/channel clocks/traffic matrices only
#   v2 (PR 3) — + dynamics clocks (dyn_seq, stall_seconds, n_retries)
#   v3 (PR 4) — + routing/resync blocks, 6-element pending rows (duration)
#   v4 (PR 5) — + explicit schema_version stamp
#   v5 (PR 6) — + wire_bytes_raw (uncompressed payload tally for the
#               wire-codec compression ratio)
#   v6 (PR 7) — + fair-share traffic plane: 8-element pending rows (wire
#               bytes + transfer id), per-transfer sojourn log, in-flight
#               fair-share flow set, per-sample bytes in the resync window,
#               multipath split counter
SCHEDULER_SCHEMA_VERSION = 6

_ROUTING_DEFAULTS = {"plan_time": -1.0, "counted_time": -1.0, "plan_dark": [],
                     "reroutes": 0, "hub_elections": 0}
# N/h None = "keep the engine-derived cadence" (pre-routing checkpoints)
_RESYNC_DEFAULTS = {"measured": [], "measured_bytes": [], "N": None,
                    "h_cocodc": None}


def upgrade_scheduler_state(st: Dict[str, object]) -> Dict[str, object]:
    """Upgrade a serialized host-scheduler dict of ANY prior schema version to
    the current one, filling in exactly what the writing code could not have
    known about. This is the ONLY place checkpoint back-compat defaults live;
    `restore_scheduler` reads the upgraded dict without fallbacks."""
    st = dict(st)
    # v1 -> v2: pre-dynamics checkpoints carry no dynamics clocks (static
    # runs never advance them)
    st.setdefault("dyn_seq", 0)
    st.setdefault("stall_seconds", 0.0)
    st.setdefault("n_retries", 0)
    # v2 -> v3: pre-routing checkpoints have no planner/resync state and
    # 5-element pending rows (no measured duration); v5 -> v6 extends the
    # rows with wire bytes (0 = unknown, excluded from the Eq. 9 byte fit)
    # and the transfer id (-1 = predates the transfer log)
    rows = []
    for r in st["pending"]:
        row = list(r)[:8] + [0.0] * (6 - len(r))
        if len(row) < 7:
            row.append(0)
        if len(row) < 8:
            row.append(-1)
        rows.append(row)
    st["pending"] = rows
    routing = dict(st.get("routing") or {})
    for k, v in _ROUTING_DEFAULTS.items():
        routing.setdefault(k, v)
    st["routing"] = routing
    resync = dict(st.get("resync") or {})
    for k, v in _RESYNC_DEFAULTS.items():
        resync.setdefault(k, v)
    # pre-v6 windows carry durations without payload sizes; pad with zeros so
    # the decomposed fit skips them instead of mispairing
    if len(resync["measured_bytes"]) != len(resync["measured"]):
        resync["measured_bytes"] = [0.0] * len(resync["measured"])
    st["resync"] = resync
    # v4 -> v5: pre-codec checkpoints never tracked the raw (uncompressed)
    # payload tally; defaulting it to bytes_sent resumes with ratio 1.0 and
    # lets the tally diverge from there
    st.setdefault("wire_bytes_raw", st["bytes_sent"])
    # v5 -> v6: fair-share traffic plane (serial checkpoints carry no flow
    # set; the sojourn log starts empty and fills from resume onward)
    st.setdefault("multipath_splits", 0)
    st.setdefault("transfer_log", [])
    st.setdefault("fairshare", None)
    # stamp the version
    st["schema_version"] = SCHEDULER_SCHEMA_VERSION
    return st


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile of an ascending list (0.0 on empty)."""
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    return float(sorted_vals[min(n - 1, max(0, math.ceil(q * n) - 1))])


@dataclasses.dataclass
class PendingSync:
    """Host-side mirror of one in-flight fragment transfer (scheduling only —
    the payload lives in EngineState.inflight_*)."""
    frag: int
    t_init: int
    deliver_at: int        # step index at which the delivery lands
    finish_time: float     # simulated transfer completion (wall seconds)
    seq: int               # initiation order (stable delivery tie-break)
    duration: float = 0.0  # measured transfer seconds (finish - channel start;
                           # queueing excluded) — the Eq. 9 re-derivation input
    wire: int = 0          # wire bytes of this transfer (the Eq. 9 byte-fit
                           # pairs with duration; 0 = pre-v6 checkpoint)
    tid: int = -1          # transfer id (fair-share flow id / sojourn-log key)


class ProtocolEngine:
    """One engine instance per training run. Device state is functional
    (`self.state`); host methods schedule transitions and account wall-clock."""

    def __init__(self, method: str, ccfg: CoCoDCConfig, fragmenter: Fragmenter,
                 network, params_stack, *, dc_impl: str = "ref",
                 engine_impl: str = "jit"):
        # registry lookup — unknown names raise listing registered methods
        self.method_impl = get_method(method)
        assert engine_impl in ("jit", "host")
        self.method = method
        self.cfg = ccfg
        self.frag = fragmenter
        self.topology: Topology = as_topology(network)
        self.net = self.topology          # cost-model view (t_c / t_s)
        self.dc_impl = dc_impl
        self.engine_impl = engine_impl
        self.M = ccfg.num_workers
        self.K = ccfg.num_fragments
        self.H = ccfg.local_steps
        self.tau = ccfg.overlap_depth

        # fused_updates stores theta_g/momentum as flat planes; keep the
        # single-model leaf shapes so the pytree views can materialize at the
        # external boundary (properties below) without touching params
        self._model_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), params_stack)
        self.state = es.init_state(method, ccfg, params_stack,
                                   frag=fragmenter)
        self._fns = es.make_engine_fns(method, ccfg, fragmenter,
                                       dc_impl=dc_impl,
                                       use_jit=(engine_impl == "jit"))

        # Eq. 9/10 scheduling interval. With an active wire codec the startup
        # T_s sees the compressed payload (cheaper syncs -> more of them per
        # round); codec="none" keeps the raw-bytes arithmetic bitwise.
        mean_frag_bytes = self.frag.total_bytes / self.K
        t_s = self.topology.t_s(self._wire_bytes(int(mean_frag_bytes))
                                if ccfg.wire_codec != "none"
                                else int(mean_frag_bytes))
        self._t_s_startup = t_s
        self.N = adaptive_lib.target_syncs(self.K, self.H, self.topology.t_c,
                                           t_s, ccfg.net_utilization)
        self.h_cocodc = adaptive_lib.sync_interval(self.H, self.N)
        self.h_stream = max(1, self.H // self.K)
        # per-fragment WAN price (seconds per sync) for Algorithm 2 link-aware
        # pricing — heterogeneous fragments/links make some syncs cheaper.
        # With routing enabled this vector is refreshed from the ACTIVE plan
        # every re-plan (the startup value goes stale on dynamic links).
        self._frag_cost = [
            self.topology.t_s(self._wire_bytes(self.frag.fragment_bytes(p)))
            for p in range(self.K)]
        # partial participation (straggler tolerance, beyond-paper): offline
        # workers neither contribute to nor receive fragment syncs
        self.worker_available = [True] * self.M

        # routed communication-plan layer (off by default — the static path
        # must stay bitwise-identical to the PR 3 goldens)
        if ccfg.routing not in ("static", "routed"):
            raise ValueError(f"unknown routing mode {ccfg.routing!r} "
                             f"(options: static, routed)")
        if ccfg.hub_failover and ccfg.routing != "routed":
            raise ValueError("hub_failover requires routing='routed'")
        if ccfg.channel_scheduler not in ("serial", "fairshare"):
            raise ValueError(
                f"unknown channel_scheduler {ccfg.channel_scheduler!r} "
                f"(options: serial, fairshare)")
        if ccfg.multipath_k < 1:
            raise ValueError(f"multipath_k must be >= 1, "
                             f"got {ccfg.multipath_k}")
        if ccfg.multipath_k > 1 and ccfg.routing != "routed":
            raise ValueError("multipath_k > 1 requires routing='routed' "
                             "(k-path splitting needs the route planner)")
        self._planner: "RoutePlanner | None" = None
        if ccfg.routing == "routed":
            self._planner = RoutePlanner(
                self.topology, hub_failover=ccfg.hub_failover,
                ref_bytes=self._wire_bytes(int(mean_frag_bytes)),
                multipath_k=ccfg.multipath_k)
        self._plan: "CommPlan | None" = None
        self._plan_time: "float | None" = None
        # regions the PLANNER took offline -> the availability the USER had
        # set beforehand (restored verbatim on recovery)
        self._plan_dark: Dict[int, bool] = {}
        self.reroutes = 0                # plan changes between transfer uses
        self.hub_elections = 0           # hub changes (failover + restore)
        # counters sample plan changes at TRANSFER use only (wall-clock
        # refreshes would make them loop-cadence-dependent); the reference is
        # the last transfer-used plan, re-derivable from its plan time
        self._counted_time: "float | None" = None
        self._counted_key = None
        self._counted_hub: "int | None" = None
        # Eq. 9/10 re-derivation from measured transfer durations (methods
        # with a fixed cadence opt out via the strategy flag)
        self._resync: "adaptive_lib.ResyncState | None" = None
        if ccfg.adaptive_resync and self.method_impl.supports_adaptive_resync:
            self._resync = adaptive_lib.ResyncState()

        # host-side schedule + stats
        self.pending: List[PendingSync] = []
        self._seq = 0
        self.wall_clock = 0.0
        self.comm_seconds = 0.0
        self.bytes_sent = 0
        self.wire_bytes_raw = 0      # uncompressed (f32) payload tally
        self.n_syncs = 0
        # >= 1 is validated at Topology construction — no silent rewrite here
        self._channel_free = [0.0] * self.topology.concurrent_collectives
        m = self.M
        self.link_bytes = np.zeros((m, m), dtype=np.float64)
        self.link_seconds = np.zeros((m, m), dtype=np.float64)
        # dynamic-topology clocks/accounting (stay zero on static topologies)
        self._dyn_seq = 0            # per-transfer jitter draw counter
        self.stall_seconds = 0.0     # time lost vs nominal static transfer cost
        self.n_retries = 0           # outage-interrupted collective restarts
        # fair-share traffic plane: in-flight flows share link capacity via
        # max-min water-filling instead of queueing on channels
        self._fairshare: "FairShareSim | None" = None
        if ccfg.channel_scheduler == "fairshare":
            self._fairshare = FairShareSim(self.topology,
                                           reform_fn=self._fs_reform,
                                           finish_fn=self._fs_finish)
        # per-transfer sojourn (initiation -> finish wall seconds, queueing
        # INCLUDED) keyed by transfer id; fair-share entries hold the current
        # projection until the flow finalizes
        self._transfer_log: Dict[int, float] = {}
        self.multipath_splits = 0    # transfers whose plan split a payload
        # Eq. 9 latency/bandwidth decomposition anchors
        self._ref_wire_bytes = self._wire_bytes(int(mean_frag_bytes))
        self._lat_startup = self.topology.allreduce_time(0)

    # ------------------------------------------------------------ properties

    def _materialize(self, flat_buf):
        """Flat-plane buffer -> single-model pytree (fused_updates only).
        unpack_full writes every fragment's rows, so a zeros template of the
        right shapes/dtypes is sufficient."""
        tmpl = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self._model_sds)
        return self.frag.flat.unpack_full(tmpl, flat_buf)

    @property
    def theta_g(self):
        """Consensus model as a pytree. With `fused_updates` the state holds
        a flat plane; reads materialize a pytree copy (eval/checkpoint-rate
        boundary, not the transition hot path)."""
        if self.cfg.fused_updates:
            return self._materialize(self.state.theta_g)
        return self.state.theta_g

    @theta_g.setter
    def theta_g(self, value):
        if self.cfg.fused_updates:
            value = self.frag.flat.pack_full(value)
        self.state = dataclasses.replace(self.state, theta_g=value)

    @property
    def momentum(self):
        if self.cfg.fused_updates:
            return self._materialize(self.state.momentum)
        return self.state.momentum

    @momentum.setter
    def momentum(self, value):
        if self.cfg.fused_updates:
            value = self.frag.flat.pack_full(value)
        self.state = dataclasses.replace(self.state, momentum=value)

    @property
    def in_flight(self) -> List[PendingSync]:
        """Back-compat view of the in-flight schedule (initiation order)."""
        return list(self.pending)

    @property
    def adaptive(self) -> adaptive_lib.AdaptiveState:
        """Host snapshot of the Eq. 11 scheduler state (reads device arrays)."""
        rate = np.asarray(self.state.rate)
        last = np.asarray(self.state.last_sync)
        return adaptive_lib.AdaptiveState(
            K=self.K, H=self.H,
            last_sync=[int(x) for x in last],
            rate=[float(r) for r in rate])

    # ------------------------------------------------------------------ utils

    def set_worker_availability(self, worker: int, available: bool):
        """Mark a datacenter online/offline (WAN partition / maintenance).
        Offline workers are excluded from subsequent syncs until restored."""
        self.worker_available[worker] = available
        self.state = dataclasses.replace(
            self.state,
            worker_available=self.state.worker_available.at[worker].set(
                bool(available)))

    def _sparsify(self, d):
        """Top-k magnitude sparsification per leaf (sync_topk_frac < 1)."""
        return es.sparsify(d, self.cfg.sync_topk_frac)

    def _wire_bytes(self, nbytes: int) -> int:
        """Bytes that actually cross the WAN for an `nbytes` f32 fragment:
        wire-codec quantization (codes + per-block scales), sync_dtype
        compression and top-k sparsification (values + indices). ONE
        accounting rule for blocking and overlapped paths alike."""
        if self.cfg.wire_codec != "none":
            # quantized wire format: `bits`-bit codes + one f32 scale per
            # codec_block elements (kernels/delta_codec). Subsumes sync_dtype
            # — the codec quantizes whatever dtype the payload was in.
            from repro.kernels.delta_codec import ops as codec_ops
            nbytes = codec_ops.wire_bytes(nbytes // 4,
                                          codec=self.cfg.wire_codec,
                                          block=self.cfg.codec_block)
        elif jnp.dtype(self.cfg.sync_dtype).itemsize < 4:
            nbytes = nbytes * jnp.dtype(self.cfg.sync_dtype).itemsize // 4
        if self.cfg.sync_topk_frac < 1.0:
            # sparse wire format: values + indices
            nbytes = int(nbytes * min(1.0, 2 * self.cfg.sync_topk_frac))
        return int(nbytes)

    # ------------------------------------------------------- routed planning

    def _active_plan(self, t: float) -> CommPlan:
        """The routed plan valid at wall-time t, re-planning when t falls
        outside the cached plan's validity window (either a
        `LinkDynamics.next_change` edge passed, or t precedes a plan a queued
        future transfer fetched — the window check is two-sided so a
        wall-clock query never sees a future plan's state). Applies plan side
        effects (Algorithm-2 cost vector, dark-region availability) on route
        change; counting happens in `_note_plan_use` at transfer use only."""
        if self._plan is not None and \
                self._plan.valid_from <= t < self._plan.valid_until:
            return self._plan
        plan = self._planner.plan_at(t)
        if self._plan is None or plan.route_key() != self._plan.route_key():
            self._apply_plan(plan)
        self._plan = plan
        self._plan_time = float(t)
        return plan

    def _note_plan_use(self, plan: CommPlan, t: float):
        """Count reroutes/hub elections against the last TRANSFER-used plan.
        (Sampling at wall-clock refreshes instead would make the counters
        depend on the host loop's cadence — per-step vs segment-scanned.)"""
        key = plan.route_key()
        if self._counted_key is not None and key != self._counted_key:
            self.reroutes += 1
            if plan.hub != self._counted_hub:
                self.hub_elections += 1
        self._counted_key = key
        self._counted_hub = plan.hub
        self._counted_time = float(t)

    def _transfer_plan_fn(self, t: float) -> CommPlan:
        """Plan fetch for transfer scheduling/integration: the active plan at
        t, with the use counted."""
        plan = self._active_plan(t)
        self._note_plan_use(plan, t)
        return plan

    def _plan_frag_cost(self, plan: CommPlan) -> List[float]:
        return [self.topology.plan_allreduce_time(
                    plan, self._wire_bytes(self.frag.fragment_bytes(p)))
                for p in range(self.K)]

    def _apply_plan(self, plan: CommPlan):
        """Plan side effects: refresh the Algorithm-2 cost vector from the
        active routes, and toggle availability for regions the plan dropped
        as dark. The availability each region had when it went dark (user
        knob included) is recorded and restored VERBATIM on recovery, so the
        planner never silently re-enables a user-disabled worker."""
        self._frag_cost = self._plan_frag_cost(plan)
        dark = set(range(self.M)) - set(plan.participants)
        for r in sorted(dark - set(self._plan_dark)):
            self._plan_dark[r] = bool(self.worker_available[r])
            if self.worker_available[r]:
                self.set_worker_availability(r, False)
        for r in sorted(set(self._plan_dark) - dark):
            if self._plan_dark.pop(r):
                self.set_worker_availability(r, True)

    def _schedule_transfer(self, nbytes: int) -> Tuple[float, float]:
        """Queue one collective of `nbytes` (raw f32) on the WAN: applies the
        wire format, grabs the earliest-free channel, accounts per-link
        traffic. Returns ``(finish_wall_time, measured_duration)`` (duration
        excludes queueing — it is the Eq. 9 re-derivation's T_s sample).

        Static topologies keep the original closed-form arithmetic bitwise;
        with `Topology.dynamics` the finish time integrates the time-varying
        bottleneck bandwidth (and the engine-owned `_dyn_seq` counter makes
        per-transfer jitter a pure function of serialized state). With
        routing enabled the collective executes over the ACTIVE CommPlan's
        multi-hop routes and participants instead of the fixed formulas.

        With `channel_scheduler="fairshare"` there is no channel queue at all:
        the transfer joins the fair-share flow set immediately and its finish
        time is the max-min water-filling projection over everyone sharing
        its links (re-projected whenever a later transfer arrives)."""
        wire = self._wire_bytes(nbytes)
        tid = self.n_syncs              # unique, monotonic transfer id
        if self._fairshare is not None:
            finish, duration = self._fairshare_schedule(tid, wire)
            self.bytes_sent += wire
            self.wire_bytes_raw += int(nbytes)
            self.n_syncs += 1
            return finish, duration
        ch = min(range(len(self._channel_free)),
                 key=lambda i: self._channel_free[i])
        start = max(self.wall_clock, self._channel_free[ch])
        dyn = self.topology.dynamics
        if self._planner is not None:
            jitter = 1.0
            if dyn is not None:
                jitter = dyn.jitter_mult(self._dyn_seq)
                self._dyn_seq += 1
            # re-plannable integration: if the routes go dark mid-transfer
            # the collective re-forms on the fresh plan (fetched through
            # `_transfer_plan_fn`, so reroute/election counters track it)
            finish, nominal, retries, segments = \
                self.topology.routed_transfer_time(
                    self._transfer_plan_fn, wire, start, jitter=jitter)
            # `(start + nominal) - start` loses an ulp vs nominal; on a static
            # topology the routed accounting must equal the fixed-route path's
            actual = (finish - start) if dyn is not None else nominal
            self.n_retries += retries
            self.stall_seconds += max(0.0, actual - nominal)
            self.comm_seconds += actual
            scale = (actual / nominal if nominal > 0 else 1.0)
            # per-link traffic split across the plans that actually carried
            # the payload (a re-formed transfer charges the stand-in routes
            # for their share, not the abandoned dark ones)
            for seg_plan, frac in segments:
                if frac <= 0.0:
                    continue
                self.link_seconds += self.topology.plan_link_seconds(
                    seg_plan, wire) * (scale * frac)
                self.link_bytes += self.topology.plan_link_bytes(
                    seg_plan, wire) * frac
            if any(seg_plan.is_split for seg_plan, _ in segments):
                self.multipath_splits += 1
        elif dyn is None:
            t_s = self.topology.t_s(wire)
            finish = start + t_s
            self.comm_seconds += t_s
            self.link_seconds += self.topology.link_seconds(wire)
            self.link_bytes += self.topology.link_bytes(wire)
        else:
            jitter = dyn.jitter_mult(self._dyn_seq)
            self._dyn_seq += 1
            finish, nominal, retries = self.topology.transfer_time(
                wire, start, jitter=jitter)
            self.n_retries += retries
            self.stall_seconds += max(0.0, (finish - start) - nominal)
            self.comm_seconds += finish - start   # actual WAN occupancy
            # per-link busy-seconds scale with the ACTUAL duration (stall
            # attributed proportionally across the collective's links), so
            # link accounting reconciles with comm_seconds
            scale = (finish - start) / nominal if nominal > 0 else 1.0
            self.link_seconds += self.topology.link_seconds(wire) * scale
            self.link_bytes += self.topology.link_bytes(wire)
        self._channel_free[ch] = finish
        self.bytes_sent += wire
        self.wire_bytes_raw += int(nbytes)
        self.n_syncs += 1
        # sojourn = initiation -> finish, queueing INCLUDED (unlike duration)
        self._transfer_log[tid] = finish - self.wall_clock
        return finish, finish - start

    # ------------------------------------------------- fair-share scheduling

    def _fairshare_schedule(self, tid: int, wire: int) -> Tuple[float, float]:
        """Admit one collective into the fair-share flow set at the current
        wall-clock and re-project every in-flight transfer's finish time
        (arrivals only ever slow others down, so deliveries already made stay
        consistent). Returns ``(projected_finish, projected_duration)``."""
        sim = self._fairshare
        request = self.wall_clock
        sim.advance(request)
        spec = self._fs_flow_spec(request, wire, effectful=True)
        dyn = self.topology.dynamics
        jitter = 1.0
        if dyn is not None:
            jitter = dyn.jitter_mult(self._dyn_seq)
            self._dyn_seq += 1
        if spec["multipath"]:
            self.multipath_splits += 1
        sim.add_flow(tid, spec, request, wire, jitter)
        finishes = sim.project()
        by_tid = {ev.tid: ev for ev in self.pending}
        for fid, (fstart, ffinish) in finishes.items():
            self._transfer_log[fid] = ffinish - fstart
            ev = by_tid.get(fid)
            if ev is not None:
                ev.finish_time = ffinish
                ev.duration = ffinish - fstart
                ev.deliver_at = self._deliver_step_for(ev.t_init, ffinish)
        _, finish = finishes[tid]
        return finish, finish - request

    def _fs_flow_spec(self, t: float, wire: int, effectful: bool) -> Dict:
        """Fair-share flow description of one collective at wall-time t: link
        weights (busy-seconds per unit progress; bottleneck = 1), latency
        phases, unit-rate bandwidth work, and the accounting matrices. Routed
        engines derive it from the plan at t (`effectful=False` uses the pure
        `plan_at` so projections leak no planner side effects)."""
        topo = self.topology
        if self._planner is not None:
            plan = (self._transfer_plan_fn(t) if effectful
                    else self._planner.plan_at(t))
            return self._fs_pack_spec(
                topo.plan_link_bw_seconds(plan, wire),
                topo.plan_allreduce_time(plan, 0),
                topo.plan_n_latency_phases(plan),
                topo.plan_allreduce_time(plan, wire),
                topo.plan_link_seconds(plan, wire),
                topo.plan_link_bytes(plan, wire),
                multipath=plan.is_split)
        return self._fs_pack_spec(
            topo.link_bw_seconds(wire), topo.allreduce_time(0),
            topo.n_latency_phases, topo.allreduce_time(wire),
            topo.link_seconds(wire), topo.link_bytes(wire))

    @staticmethod
    def _fs_pack_spec(bsec, lat, phases, nominal, sec, link_bytes,
                      multipath: bool = False) -> Dict:
        work = float(bsec.max(initial=0.0))
        links = {}
        if work > 0.0:
            for i, j in np.argwhere(bsec > 0.0):
                links[(int(i), int(j))] = float(bsec[i, j] / work)
        return {"links": links, "lat": float(lat), "phases": int(phases),
                "work": work, "nominal": float(nominal), "sec": sec,
                "bytes": link_bytes, "multipath": bool(multipath)}

    def _fs_reform(self, t: float, wire: int, effectful: bool):
        """Mid-transfer re-plan hook for the fair-share sim (None on static
        routing: the flow waits out the outage on its links, like serial)."""
        if self._planner is None:
            return None
        return self._fs_flow_spec(t, wire, effectful)

    def _fs_finish(self, flow, finish: float):
        """Finalize one fair-share flow's accounting (the serial path's
        schedule-time accounting, deferred to actual completion): WAN
        occupancy, stall vs nominal, retries, and the per-link traffic split
        across the plans that carried the payload."""
        actual = finish - flow.start
        self.comm_seconds += actual
        self.stall_seconds += max(0.0, actual - flow.nominal)
        self.n_retries += flow.retries
        scale = actual / flow.nominal if flow.nominal > 0 else 1.0
        self.link_seconds += (flow.acc_sec
                              + flow.cur_sec * flow.frac_in) * scale
        self.link_bytes += flow.acc_bytes + flow.cur_bytes * flow.frac_in
        self._transfer_log[flow.id] = actual

    def _deliver_step_for(self, t: int, finish_time: float) -> int:
        """First step whose end-of-step wall-clock covers `finish_time`
        (overlapped methods never block, so wall(t') = (t'+1) * T_c)."""
        t_c = self.topology.t_c
        if t_c <= 0:
            return t + 1
        return max(t + 1, math.ceil(finish_time / t_c - 1e-9) - 1)

    # ------------------------------------------------------------ initiation

    def _initiate(self, t: int, params_stack, p: int):
        nbytes = self.frag.fragment_bytes(p)
        tid = self.n_syncs              # _schedule_transfer's id, pre-bump
        finish, duration = self._schedule_transfer(nbytes)
        self.state = self._fns.initiate(self.state, t, params_stack, p)
        self.pending.append(PendingSync(
            frag=p, t_init=t, deliver_at=self._deliver_step_for(t, finish),
            finish_time=finish, seq=self._seq, duration=duration,
            wire=self._wire_bytes(nbytes), tid=tid))
        self._seq += 1

    def _select_cocodc(self, t: int, busy: set) -> int:
        # _frag_cost tracks the wall-clock plan (refreshed in on_step_end
        # before deliveries/initiations), so pricing sees the CURRENT routes
        costs = self._frag_cost if self.cfg.link_pricing else None
        return adaptive_lib.select_fragment(self.adaptive, t, busy, costs=costs)

    # ------------------------------------------------------ event-driven API

    def next_event_step(self, t: int) -> "int | None":
        """Smallest step t' >= t at which `on_step_end(t', ...)` performs a
        protocol action: a scheduled initiation slot, a due delivery, or a
        blocking round. None when the method schedules no events (e.g.
        method="local" — the host loop may fuse every remaining step into one
        scanned segment).

        The schedule of WHEN is the registered `SyncMethod` strategy's call;
        WHICH fragment a cocodc initiation picks is data-dependent (Eq. 11),
        so the caller must re-query after every event."""
        return self.method_impl.next_event_step(self, t)

    def advance_steps(self, n: int):
        """Account wall-clock for `n` quiet local steps (no protocol event) —
        the steps a scanned segment fused away. Accumulated per-step to stay
        bitwise-identical with the per-step loop's repeated `+= t_c`."""
        for _ in range(n):
            self.wall_clock += self.topology.t_c

    # ------------------------------------------------------------- main hook

    def on_step_end(self, t: int, params_stack):
        """Call after inner step t (0-based). Ticks the wall-clock, then
        dispatches the method strategy's protocol action (blocking round,
        delivery processing + initiation, or nothing). Returns the updated
        params_stack."""
        self.wall_clock += self.topology.t_c
        out = self.method_impl.on_step_end(self, t, params_stack)
        if self._fairshare is not None:
            # advance the fluid sim to the post-step wall-clock, finalizing
            # flows that finished (advance is associative, so per-step and
            # segment-fused loops land on identical sim states)
            self._fairshare.advance(self.wall_clock)
        return out

    def _process_deliveries(self, t: int, params_stack):
        """Apply every in-flight delivery due at step t (delivery order:
        deliver_at, then initiation seq) and feed measured durations to the
        Eq. 9 re-derivation window. Shared by all overlapped strategies."""
        due = sorted((ev for ev in self.pending if ev.deliver_at <= t),
                     key=lambda e: (e.deliver_at, e.seq))
        for ev in due:
            self.state, params_stack = self._fns.deliver(
                self.state, t, params_stack, ev.frag)
            self.pending.remove(ev)
            if self._resync is not None:
                # a COMPLETED transfer's measured duration is shared history
                # (paired with its wire bytes for the Eq. 9 byte fit)
                self._resync.observe(ev.duration, ev.wire)
        return params_stack

    # ---------------------------------------------------------- checkpointing

    def scheduler_state(self) -> Dict[str, object]:
        """Host-side scheduler state (everything outside the EngineState
        pytree) as plain serializable containers — the in-flight schedule,
        WAN-channel clocks, and traffic accounting. The simulated wall-clock
        itself lives in TrainerState (single authority), not here."""
        return {
            "schema_version": SCHEDULER_SCHEMA_VERSION,
            "pending": [[ev.frag, ev.t_init, ev.deliver_at, ev.finish_time,
                         ev.seq, ev.duration, ev.wire, ev.tid]
                        for ev in self.pending],
            "seq": self._seq,
            "comm_seconds": self.comm_seconds,
            "bytes_sent": self.bytes_sent,
            "wire_bytes_raw": self.wire_bytes_raw,
            "n_syncs": self.n_syncs,
            "channel_free": [float(x) for x in self._channel_free],
            "worker_available": [bool(x) for x in self.worker_available],
            "link_bytes": self.link_bytes,
            "link_seconds": self.link_seconds,
            # dynamics clocks: the jitter draw counter + stall/retry tallies
            # (exact mid-transfer resume on time-varying links needs these)
            "dyn_seq": self._dyn_seq,
            "stall_seconds": self.stall_seconds,
            "n_retries": self.n_retries,
            # routed-planner state: the active plan is a pure function of its
            # plan time, so serializing the TIME (plus counters and the
            # planner-dropped regions) replays mid-outage resume bitwise
            "routing": {
                "plan_time": (-1.0 if self._plan_time is None
                              else float(self._plan_time)),
                "counted_time": (-1.0 if self._counted_time is None
                                 else float(self._counted_time)),
                "plan_dark": [[int(r), bool(prior)] for r, prior
                              in sorted(self._plan_dark.items())],
                "reroutes": int(self.reroutes),
                "hub_elections": int(self.hub_elections),
            },
            # Eq. 9/10 re-derivation window + the currently derived cadence
            "resync": {
                "measured": ([] if self._resync is None
                             else [float(x) for x in self._resync.measured]),
                "measured_bytes": ([] if self._resync is None else
                                   [float(x)
                                    for x in self._resync.measured_bytes]),
                "N": int(self.N),
                "h_cocodc": int(self.h_cocodc),
            },
            # fair-share traffic plane: the in-flight flow set (None under the
            # serial scheduler) + the per-transfer sojourn log
            "multipath_splits": int(self.multipath_splits),
            "transfer_log": [[int(k), float(v)] for k, v
                             in sorted(self._transfer_log.items())],
            "fairshare": (None if self._fairshare is None
                          else self._fairshare.state_dict()),
        }

    def restore_scheduler(self, st: Dict[str, object]):
        """Inverse of `scheduler_state` (EngineState is restored separately).
        Accepts any prior schema version — `upgrade_scheduler_state` is the
        single upgrade path; no per-field fallbacks live here."""
        st = upgrade_scheduler_state(st)
        self.pending = [PendingSync(frag=int(r[0]), t_init=int(r[1]),
                                    deliver_at=int(r[2]),
                                    finish_time=float(r[3]), seq=int(r[4]),
                                    duration=float(r[5]), wire=int(r[6]),
                                    tid=int(r[7]))
                        for r in st["pending"]]
        self._seq = int(st["seq"])
        self.comm_seconds = float(st["comm_seconds"])
        self.bytes_sent = int(st["bytes_sent"])
        self.wire_bytes_raw = int(st["wire_bytes_raw"])
        self.n_syncs = int(st["n_syncs"])
        self._channel_free = [float(x) for x in st["channel_free"]]
        self.worker_available = [bool(x) for x in st["worker_available"]]
        self.link_bytes = np.asarray(st["link_bytes"], dtype=np.float64)
        self.link_seconds = np.asarray(st["link_seconds"], dtype=np.float64)
        self._dyn_seq = int(st["dyn_seq"])
        self.stall_seconds = float(st["stall_seconds"])
        self.n_retries = int(st["n_retries"])
        routing = st["routing"]
        self.reroutes = int(routing["reroutes"])
        self.hub_elections = int(routing["hub_elections"])
        self._plan_dark = {int(row[0]): bool(row[1])
                           for row in routing["plan_dark"]}
        plan_time = float(routing["plan_time"])
        self._plan = None
        self._plan_time = None
        self._counted_time = None
        self._counted_key = None
        self._counted_hub = None
        if self._planner is not None:
            if plan_time >= 0.0:
                # re-derive the active plan from its serialized plan time
                # (pure function) and refresh the cost vector from it;
                # availability was restored above/inside EngineState, so no
                # side effects re-run
                self._plan_time = plan_time
                self._plan = self._planner.plan_at(plan_time)
                self._frag_cost = self._plan_frag_cost(self._plan)
            counted_time = float(routing["counted_time"])
            if counted_time >= 0.0:
                counted = self._planner.plan_at(counted_time)
                self._counted_time = counted_time
                self._counted_key = counted.route_key()
                self._counted_hub = counted.hub
        resync = st["resync"]
        if self._resync is not None:
            self._resync.measured = [float(x) for x in resync["measured"]]
            self._resync.measured_bytes = [float(x) for x
                                           in resync["measured_bytes"]]
        if resync["N"] is not None:
            self.N = int(resync["N"])
        if resync["h_cocodc"] is not None:
            self.h_cocodc = int(resync["h_cocodc"])
        self.multipath_splits = int(st["multipath_splits"])
        self._transfer_log = {int(k): float(v) for k, v in st["transfer_log"]}
        if self._fairshare is not None and st["fairshare"] is not None:
            self._fairshare.load_state(st["fairshare"])

    # ---------------------------------------------------------------- stats

    def stats(self) -> Dict[str, float]:
        sojourns = sorted(self._transfer_log.values())
        return {
            "wall_clock_s": float(self.wall_clock),
            "comm_seconds": float(self.comm_seconds),
            "bytes_sent": float(self.bytes_sent),
            "wire_bytes_total": float(self.bytes_sent),
            "wire_bytes_raw": float(self.wire_bytes_raw),
            "compression_ratio": float(
                1.0 if self.bytes_sent == 0
                else self.wire_bytes_raw / self.bytes_sent),
            "n_syncs": float(self.n_syncs),
            "mean_transfer_s": float(
                0.0 if self.n_syncs == 0
                else self.comm_seconds / self.n_syncs),
            "overlap_ratio": float(0.0 if self.wall_clock == 0 else
                                   min(1.0, self.comm_seconds / self.wall_clock)),
            "target_syncs_N": float(self.N),
            "busiest_link_bytes": float(self.link_bytes.max(initial=0.0)),
            "busiest_link_seconds": float(self.link_seconds.max(initial=0.0)),
            "stall_seconds": float(self.stall_seconds),
            "stall_fraction": float(0.0 if self.comm_seconds == 0 else
                                    self.stall_seconds / self.comm_seconds),
            "n_retries": float(self.n_retries),
            "reroutes": float(self.reroutes),
            "hub_elections": float(self.hub_elections),
            # per-transfer sojourn (initiation -> finish, queueing INCLUDED —
            # the scheduler-comparison metric; `mean_transfer_s` above keeps
            # its queueing-excluded occupancy semantics)
            "transfer_mean_s": float(np.mean(sojourns)) if sojourns else 0.0,
            "transfer_p50_s": _percentile(sojourns, 0.50),
            "transfer_p95_s": _percentile(sojourns, 0.95),
            "multipath_splits": float(self.multipath_splits),
            "max_link_busy_fraction": float(
                0.0 if self.wall_clock <= 0
                else self.link_seconds.max(initial=0.0) / self.wall_clock),
        }

    def link_stats(self) -> Dict[str, object]:
        """Per-link transfer accounting over the run (region-name keyed)."""
        regions = self.topology.regions
        links = {}
        m = self.M
        wall = float(self.wall_clock)
        for i in range(m):
            for j in range(m):
                if self.link_bytes[i, j] > 0:
                    # busy-seconds accrue PER FLOW (occupancy scaled by
                    # actual/nominal duration), so under fairshare a link
                    # shared by concurrent flows can exceed 1.0 — read it
                    # as demand on the link, like a load average
                    links[f"{regions[i]}->{regions[j]}"] = {
                        "bytes": float(self.link_bytes[i, j]),
                        "busy_seconds": float(self.link_seconds[i, j]),
                        "busy_fraction": float(
                            0.0 if wall <= 0
                            else self.link_seconds[i, j] / wall),
                    }
        busiest = None
        if links:
            busiest = max(links, key=lambda k: links[k]["busy_seconds"])
        return {"links": links, "busiest_link": busiest,
                "collective": self.topology.collective,
                "routing": self.cfg.routing,
                "hub": int(self._plan.hub if self._plan is not None
                           else self.topology.hub),
                "regions": list(regions)}
