"""Cross-region trainer: M worker-stacked inner AdamW loops + a protocol engine
(DiLoCo / Streaming DiLoCo / CoCoDC) coordinating cross-region synchronization.

Worker-local params/optimizer/batches carry a leading worker axis M; the inner
train step is vmapped over it (on the multi-pod mesh this axis is sharded over
`pod`, making each pod a datacenter — see launch/). The engine is host-side
scheduling around jitted device ops, exactly the structure of a real deployment's
coordinator process.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core.fragments import make_fragmenter
from repro.core.network import NetworkModel, Topology, paper_network
from repro.core.protocol import ProtocolEngine
from repro.data.pipeline import MarkovCorpus, make_worker_streams, stacked_batch
from repro.models import api
from repro.optim import adamw_init, adamw_update, warmup_cosine


@dataclasses.dataclass
class TrainerConfig:
    method: str = "cocodc"              # diloco | streaming | cocodc | local
    local_batch: int = 8
    seq_len: int = 64
    total_steps: int = 400
    inner_lr: float = 4e-4
    warmup_steps: int = 50
    weight_decay: float = 0.1
    eval_batch: int = 16
    seed: int = 0
    noniid_frac: float = 0.25
    # "jit" = functional EngineState transitions under jax.jit (hot path);
    # "host" = same pure functions executed eagerly (legacy-equivalent path,
    # kept for golden-trajectory parity tests and debugging)
    engine_impl: str = "jit"


class CrossRegionTrainer:
    def __init__(self, model_cfg: ModelConfig, ccfg: CoCoDCConfig,
                 tcfg: TrainerConfig,
                 network: Optional["NetworkModel | Topology"] = None):
        self.mcfg = model_cfg
        self.ccfg = ccfg
        self.tcfg = tcfg
        M = ccfg.num_workers

        key = jax.random.PRNGKey(tcfg.seed)
        params = api.init_params(model_cfg, key)
        self.params_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)
        self.opt_state = jax.vmap(adamw_init)(self.params_stack)

        shape = jax.eval_shape(lambda: params)
        self.fragmenter = make_fragmenter(model_cfg, shape, ccfg.num_fragments,
                                          strided=ccfg.strided_fragments)
        if network is None:
            network = paper_network(
                M, fragment_bytes=self.fragmenter.total_bytes // ccfg.num_fragments,
                tau=ccfg.overlap_depth)
        self.network = network
        self.engine = ProtocolEngine(tcfg.method, ccfg, self.fragmenter, network,
                                     self.params_stack,
                                     engine_impl=tcfg.engine_impl)

        self.streams = make_worker_streams(M, model_cfg.vocab, seed=tcfg.seed,
                                           noniid_frac=tcfg.noniid_frac)
        # held-out IID stream (global backbone) for consensus-model evaluation
        self.eval_stream = MarkovCorpus(vocab=model_cfg.vocab, seed=tcfg.seed,
                                        worker_id=-1, noniid_frac=0.0)

        mcfg, tc = model_cfg, tcfg

        def single_step(params, opt_state, batch, lr):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: api.loss_fn(mcfg, p, batch), has_aux=True)(params)
            params, opt_state = adamw_update(grads, opt_state, params, lr,
                                             weight_decay=tc.weight_decay)
            return params, opt_state, loss

        self._train_step = jax.jit(jax.vmap(single_step,
                                            in_axes=(0, 0, 0, None)))

        def eval_loss(params, batch):
            loss, metrics = api.loss_fn(mcfg, params, batch)
            return metrics["nll"]

        self._eval = jax.jit(eval_loss)
        self.history: List[Dict] = []
        self.step = 0

    def lr(self, step: int):
        return warmup_cosine(step, base_lr=self.tcfg.inner_lr,
                             warmup_steps=self.tcfg.warmup_steps,
                             total_steps=self.tcfg.total_steps)

    def _augment(self, batch, step, stacked: bool):
        """Add stub-frontend inputs for the audio family (frames are the
        carve-out stub: deterministic synthetic frame embeddings)."""
        if self.mcfg.family != "audio":
            return batch
        import jax
        key = jax.random.PRNGKey(step ^ 0x5EED)
        B = batch["tokens"].shape[-2]
        shape = (B, self.mcfg.n_prefix_tokens, self.mcfg.prefix_dim)
        frames = jax.random.normal(key, shape, jnp.float32) * 0.1
        if stacked:
            M = batch["tokens"].shape[0]
            frames = jnp.broadcast_to(frames[None], (M,) + shape)
        return dict(batch, frames=frames)

    def train_one_step(self):
        t = self.step
        batch = stacked_batch(self.streams, t, self.tcfg.local_batch,
                              self.tcfg.seq_len)
        batch = self._augment(batch, t, stacked=True)
        self.params_stack, self.opt_state, losses = self._train_step(
            self.params_stack, self.opt_state, batch, self.lr(t))
        self.params_stack = self.engine.on_step_end(t, self.params_stack)
        self.step += 1
        return float(jnp.mean(losses))

    def evaluate(self, n_batches: int = 2) -> Dict[str, float]:
        """Perplexity of the consensus (global) model on the held-out stream."""
        theta = self.engine.theta_g
        nll = 0.0
        for i in range(n_batches):
            batch = self.eval_stream.batch(10_000_000 + i, self.tcfg.eval_batch,
                                           self.tcfg.seq_len)
            batch = self._augment(batch, 10_000_000 + i, stacked=False)
            nll += float(self._eval(theta, batch))
        nll /= n_batches
        return {"nll": nll, "ppl": float(jnp.exp(nll))}

    def run(self, steps: Optional[int] = None, eval_every: int = 50,
            log: Callable[[str], None] = lambda s: None):
        steps = steps if steps is not None else self.tcfg.total_steps
        for _ in range(steps):
            train_loss = self.train_one_step()
            if self.step % eval_every == 0 or self.step == steps:
                ev = self.evaluate()
                rec = {"step": self.step, "train_loss": train_loss, **ev,
                       **self.engine.stats()}
                self.history.append(rec)
                log(f"[{self.tcfg.method}] step {self.step:5d} "
                    f"train {train_loss:.4f} eval_nll {ev['nll']:.4f} "
                    f"ppl {ev['ppl']:.2f} wall {self.engine.wall_clock:.0f}s")
        return self.history

    def steps_to_ppl(self, target: float) -> Optional[int]:
        for rec in self.history:
            if rec["ppl"] <= target:
                return rec["step"]
        return None
