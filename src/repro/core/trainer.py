"""Cross-region trainer: M worker-stacked inner AdamW loops + a protocol engine
(DiLoCo / Streaming DiLoCo / CoCoDC) coordinating cross-region synchronization.

Worker-local params/optimizer/batches carry a leading worker axis M; the inner
train step is vmapped over it (on the multi-pod mesh this axis is sharded over
`pod`, making each pod a datacenter — see launch/). The engine is host-side
scheduling around jitted device ops, exactly the structure of a real deployment's
coordinator process.

Execution engine (segment-scanned): the host loop iterates over PROTOCOL EVENTS,
not steps. All inner steps between consecutive events (fragment initiations,
deliveries, DiLoCo rounds) run as ONE jitted `lax.scan` over a prefetched
stacked batch segment (`SegmentRunner`), so N steps cost one dispatch instead of
N — the WAN-hiding structure of Streaming DiLoCo/CoCoDC maps onto long pure
segments punctuated by sparse syncs. `loop="per_step"` keeps the legacy
one-dispatch-per-step path for golden-trajectory parity tests and debugging.

Checkpoint/resume: the full run state — `TrainerState` pytree (params stack,
inner optimizer, EngineState, step/wall-clock/data cursor) plus the host
scheduler (in-flight transfers, WAN channel clocks, traffic matrices) and the
eval history — round-trips atomically through checkpoint/io at any segment
boundary; a resumed run replays the exact trajectory of an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.analysis.retrace import RetraceSentinel
from repro.checkpoint.io import load_pytree, restore_like, save_pytree
from repro.configs.base import CoCoDCConfig, ModelConfig
from repro.core import engine_state as es
from repro.core.fragments import make_fragmenter
from repro.core.network import (NetworkModel, Topology, apply_dynamics,
                                as_topology, paper_network)
from repro.core.protocol import ProtocolEngine
from repro.data.pipeline import (MarkovCorpus, make_worker_streams,
                                 stacked_batch, stacked_segment)
from repro.models import api
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.adamw import AdamWState


@dataclasses.dataclass
class TrainerConfig:
    method: str = "cocodc"              # diloco | streaming | cocodc | local
    local_batch: int = 8
    seq_len: int = 64
    total_steps: int = 400
    inner_lr: float = 4e-4
    warmup_steps: int = 50
    weight_decay: float = 0.1
    eval_batch: int = 16
    seed: int = 0
    noniid_frac: float = 0.25
    # "jit" = functional EngineState transitions under jax.jit (hot path);
    # "host" = same pure functions executed eagerly (legacy-equivalent path,
    # kept for golden-trajectory parity tests and debugging)
    engine_impl: str = "jit"
    # "segment" = fuse all inner steps between protocol events under one jitted
    # lax.scan (hot path); "per_step" = one dispatch per step (legacy path,
    # kept for golden-trajectory parity tests and debugging)
    loop: str = "segment"
    # longest fused segment (bounds the prefetched batch stack for event-free
    # stretches, e.g. method="local"); power of two keeps the chunked scan's
    # compiled-program set minimal
    max_segment: int = 64


@dataclasses.dataclass
class TrainerState:
    """Everything device-side a resumed run needs, as one pytree: worker-stacked
    params + inner AdamW state, the protocol EngineState, and the scalar run
    cursors. Host-side scheduler state (in-flight transfer schedule, channel
    clocks, traffic matrices) rides alongside in the checkpoint dict — see
    `CrossRegionTrainer.checkpoint_state`."""
    params_stack: Any
    opt_state: Any
    engine: es.EngineState
    step: int
    wall_clock: float
    data_cursor: int    # == step (data is a pure fn of step) — kept explicit
                        # so a future stateful loader has a slot to fill


jax.tree_util.register_dataclass(
    TrainerState,
    data_fields=[f.name for f in dataclasses.fields(TrainerState)],
    meta_fields=[])


CKPT_FORMAT = "trainer_state_v1"

# Checkpoint-meta schema. One upgrade path (`CrossRegionTrainer._upgrade_meta`)
# replaces the scattered `.get(..., default)` back-compat reads:
#   v1 — ad-hoc per-key trajectory meta (keys accreted over PRs 2-4)
#   v2 (PR 5) — + schema_version stamp, spec dict + spec_hash (primary
#     resume validation for spec-built trainers)
#   v3 (PR 6) — + wire-codec trajectory knobs (wire_codec, codec_block,
#     codec_error_feedback); pre-codec checkpoints upgrade to "none"
#   v4 (PR 7) — + traffic-plane knobs (channel_scheduler, multipath_k);
#     pre-fairshare checkpoints upgrade to the serial channel queue
#   v5 (PR 8) — + fused_updates (flat-plane engine buffers change the
#     in-flight/residual state SHAPES, so cross-mode resume must be
#     rejected up front, not die in restore_like); pre-fused checkpoints
#     upgrade to the per-leaf path
META_SCHEMA_VERSION = 5


@functools.lru_cache(maxsize=None)
def _jit_gen_frames():
    """Audio-stub frame segments in one dispatch: vmap the per-step folded-key
    generator over the step axis (rows are invariant to the padded length, so
    the per-step and segment paths share one generator and stay bitwise-equal)."""
    def gen(root_key, steps, batch, n_prefix, dim):
        def one(step):
            key = jax.random.fold_in(root_key, step)
            return jax.random.normal(key, (batch, n_prefix, dim),
                                     jnp.float32) * 0.1
        return jax.vmap(one)(steps)
    return jax.jit(gen, static_argnums=(2, 3, 4))


class SegmentRunner:
    """Fused inner-step executor: scans `single_step` (vmapped over the worker
    axis) across a stacked batch segment, carrying (params_stack, opt_state)
    and consuming a per-step LR array.

    The jit cache retraces per distinct scan length, and protocol event gaps
    vary (queueing shifts deliveries, Eq. 11 shifts initiations), so a raw
    per-length cache would recompile all run long. Segments are therefore
    dispatched as DESCENDING POWER-OF-TWO chunks (13 -> 8+4+1): the compiled-
    program set is bounded by log2(max segment), and since quiet steps carry no
    protocol interaction, the chunked scan is bitwise-identical to one fused
    scan (and to the per-step loop — pinned by tests/test_trainer_segments).

    On non-CPU backends the scan carry (params stack + inner optimizer) is
    DONATED to each chunk dispatch, so the buffers are updated in place instead
    of being copied per chunk; the caller always rebinds to the returned carry.
    CPU jit does not support donation (XLA warns and ignores it), so the flag
    is gated on the backend (`donate` overrides the gate — used by the
    static-analysis donation audit to inspect the accelerator wiring).

    The power-of-two contract is ENFORCED, not just relied on: the jitted
    scan is wrapped in a `RetraceSentinel` with budget log2(max_segment)+1
    (one compiled program per chunk length 1, 2, ..., max_segment), so an
    event-gap-induced recompile beyond that set fails loudly at the call
    that caused it instead of silently recompiling all run long."""

    DONATE_ARGNUMS = (0, 1)              # params_stack, opt_state (scan carry)

    def __init__(self, single_step, *, max_segment: int = 64,
                 donate: bool | None = None):
        self.single_step = single_step
        self.max_segment = int(max_segment)
        vstep = jax.vmap(single_step, in_axes=(0, 0, 0, None))

        def run_segment(params_stack, opt_state, batch_seg, lrs):
            def body(carry, xs):
                batch, lr = xs
                p, o, losses = vstep(carry[0], carry[1], batch, lr)
                return (p, o), losses

            (p, o), losses = jax.lax.scan(
                body, (params_stack, opt_state), (batch_seg, lrs))
            return p, o, losses          # losses: (n, M)

        can_donate = (jax.default_backend() != "cpu" if donate is None
                      else donate)
        self._fn = RetraceSentinel(
            jax.jit(run_segment,
                    donate_argnums=self.DONATE_ARGNUMS if can_donate else ()),
            name="trainer.segment_scan",
            max_traces=max(1, self.max_segment.bit_length()))

    @property
    def trace_count(self) -> int:
        return self._fn.trace_count

    def __call__(self, params_stack, opt_state, batch_seg, lrs):
        n = int(lrs.shape[0])
        loss_chunks = []
        i = 0
        while i < n:
            c = 1 << ((n - i).bit_length() - 1)   # largest power of two <= n-i
            chunk = jax.tree.map(lambda x: x[i:i + c], batch_seg)
            params_stack, opt_state, losses = self._fn(
                params_stack, opt_state, chunk, lrs[i:i + c])
            loss_chunks.append(losses)
            i += c
        losses = (loss_chunks[0] if len(loss_chunks) == 1
                  else jnp.concatenate(loss_chunks))
        return params_stack, opt_state, losses


class CrossRegionTrainer:
    def __init__(self, model_cfg: ModelConfig, ccfg: CoCoDCConfig,
                 tcfg: TrainerConfig,
                 network: Optional["NetworkModel | Topology"] = None,
                 dynamics: Optional[str] = None, dynamics_seed: int = 0,
                 spec: Optional[Any] = None):
        self.mcfg = model_cfg
        self.ccfg = ccfg
        self.tcfg = tcfg
        # the declarative ExperimentSpec this trainer was built from
        # (repro.api.build_experiment); None when constructed directly.
        # Rides into checkpoints as meta["spec"]/meta["spec_hash"] — the
        # primary resume-identity check.
        self.spec = spec
        M = ccfg.num_workers

        key = jax.random.PRNGKey(tcfg.seed)
        params = api.init_params(model_cfg, key)
        self.params_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (M,) + a.shape).copy(), params)
        self.opt_state = jax.vmap(adamw_init)(self.params_stack)

        shape = jax.eval_shape(lambda: params)
        self.fragmenter = make_fragmenter(model_cfg, shape, ccfg.num_fragments,
                                          strided=ccfg.strided_fragments,
                                          strategy=ccfg.fragment_strategy)
        if network is None:
            network = paper_network(
                M, fragment_bytes=self.fragmenter.total_bytes // ccfg.num_fragments,
                tau=ccfg.overlap_depth)
        if dynamics:
            # time-varying links apply to ANY base topology, incl. the
            # calibrated symmetric default (seeded -> deterministic resume)
            network = apply_dynamics(as_topology(network), dynamics,
                                     seed=dynamics_seed)
        self.network = network
        self.engine = ProtocolEngine(tcfg.method, ccfg, self.fragmenter, network,
                                     self.params_stack,
                                     engine_impl=tcfg.engine_impl)

        self.streams = make_worker_streams(M, model_cfg.vocab, seed=tcfg.seed,
                                           noniid_frac=tcfg.noniid_frac)
        # held-out IID stream (global backbone) for consensus-model evaluation
        self.eval_stream = MarkovCorpus(vocab=model_cfg.vocab, seed=tcfg.seed,
                                        worker_id=-1, noniid_frac=0.0)
        # frame RNG for the audio stub frontend: per-step keys are folded off
        # this root, never constructed from raw step arithmetic
        self._frame_key = jax.random.PRNGKey(0x5EED)

        mcfg, tc = model_cfg, tcfg

        def single_step(params, opt_state, batch, lr):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: api.loss_fn(mcfg, p, batch), has_aux=True)(params)
            params, opt_state = adamw_update(grads, opt_state, params, lr,
                                             weight_decay=tc.weight_decay)
            return params, opt_state, loss

        self._train_step = jax.jit(jax.vmap(single_step,
                                            in_axes=(0, 0, 0, None)))
        self.segment_runner = SegmentRunner(single_step,
                                            max_segment=tcfg.max_segment)

        def eval_loss(params, batch):
            loss, metrics = api.loss_fn(mcfg, params, batch)
            return metrics["nll"]

        self._eval = jax.jit(eval_loss)
        self.history: List[Dict] = []
        self.step = 0

    def lr(self, step):
        """Inner LR at `step` — accepts a scalar or a per-step array."""
        return warmup_cosine(step, base_lr=self.tcfg.inner_lr,
                             warmup_steps=self.tcfg.warmup_steps,
                             total_steps=self.tcfg.total_steps)

    # ------------------------------------------------------- data + frontends

    def _augment(self, batch, step, stacked: bool):
        """Add stub-frontend inputs for the audio family. Uses the SAME jitted
        generator as the segment path (a normal() computed eagerly vs under
        jit/vmap differs in the last ulp, which would break scanned-vs-per-step
        bitwise parity)."""
        if self.mcfg.family != "audio":
            return batch
        B = batch["tokens"].shape[-2]
        frames = _jit_gen_frames()(self._frame_key, jnp.asarray([step]), B,
                                   self.mcfg.n_prefix_tokens,
                                   self.mcfg.prefix_dim)[0]
        if stacked:
            M = batch["tokens"].shape[0]
            frames = jnp.broadcast_to(frames[None], (M,) + frames.shape)
        return dict(batch, frames=frames)

    def _augment_segment(self, batch_seg, t0: int, n: int):
        """Per-step frames stacked step-major: (n, M, B, P, D) — matches
        `_augment(..., stacked=True)` at each step of the segment, generated
        in ONE dispatch (power-of-two padded like the data segments)."""
        if self.mcfg.family != "audio":
            return batch_seg
        M, B = batch_seg["tokens"].shape[1], batch_seg["tokens"].shape[2]
        m = 1 << max(0, n - 1).bit_length()
        steps = jnp.arange(t0, t0 + m)
        frames = _jit_gen_frames()(self._frame_key, steps, B,
                                   self.mcfg.n_prefix_tokens,
                                   self.mcfg.prefix_dim)[:n]
        frames = jnp.broadcast_to(frames[:, None],
                                  (n, M) + frames.shape[1:])
        return dict(batch_seg, frames=frames)

    # -------------------------------------------------------------- stepping

    def train_one_step(self):
        """Legacy per-step path: one dispatch per inner step (loop="per_step").
        The scanned path must reproduce this trajectory exactly — pinned by
        tests/test_trainer_segments.py."""
        t = self.step
        batch = stacked_batch(self.streams, t, self.tcfg.local_batch,
                              self.tcfg.seq_len)
        batch = self._augment(batch, t, stacked=True)
        self.params_stack, self.opt_state, losses = self._train_step(
            self.params_stack, self.opt_state, batch, self.lr(t))
        self.params_stack = self.engine.on_step_end(t, self.params_stack)
        self.step += 1
        return float(jnp.mean(losses))

    def _run_segment(self, t0: int, n: int) -> float:
        """Run steps [t0, t0+n) as one scanned dispatch. The segment is chosen
        so only its LAST step can be a protocol event; quiet steps advance the
        simulated wall-clock without touching the engine."""
        batch_seg = stacked_segment(self.streams, t0, n, self.tcfg.local_batch,
                                    self.tcfg.seq_len)
        batch_seg = self._augment_segment(batch_seg, t0, n)
        lrs = self.lr(jnp.arange(t0, t0 + n))
        self.params_stack, self.opt_state, losses = self.segment_runner(
            self.params_stack, self.opt_state, batch_seg, lrs)
        if n > 1:
            self.engine.advance_steps(n - 1)
        self.params_stack = self.engine.on_step_end(t0 + n - 1,
                                                    self.params_stack)
        self.step = t0 + n
        return float(jnp.mean(losses[-1]))

    def _segment_end(self, t: int, target: int, eval_every: int,
                     ckpt_every: int) -> int:
        """Last step (inclusive) of the segment starting at t: the earliest of
        the next protocol event, the next eval/checkpoint boundary, and the end
        of the run."""
        end = min(target - 1, t + self.tcfg.max_segment - 1)
        ne = self.engine.next_event_step(t)
        if ne is not None:
            end = min(end, ne)
        for every in (eval_every, ckpt_every):
            if every:
                end = min(end, (t // every + 1) * every - 1)
        return end

    # ------------------------------------------------------------------ eval

    def evaluate(self, n_batches: int = 2) -> Dict[str, float]:
        """Perplexity of the consensus (global) model on the held-out stream."""
        theta = self.engine.theta_g
        nll = 0.0
        for i in range(n_batches):
            batch = self.eval_stream.batch(10_000_000 + i, self.tcfg.eval_batch,
                                           self.tcfg.seq_len)
            batch = self._augment(batch, 10_000_000 + i, stacked=False)
            nll += float(self._eval(theta, batch))
        nll /= n_batches
        return {"nll": nll, "ppl": float(jnp.exp(nll))}

    # ------------------------------------------------------------------- run

    def _record_eval(self, train_loss: float, log: Callable[[str], None]):
        ev = self.evaluate()
        rec = {"step": self.step, "train_loss": train_loss, **ev,
               **self.engine.stats()}
        self.history.append(rec)
        log(f"[{self.tcfg.method}] step {self.step:5d} "
            f"train {train_loss:.4f} eval_nll {ev['nll']:.4f} "
            f"ppl {ev['ppl']:.2f} wall {self.engine.wall_clock:.0f}s")

    def run(self, steps: Optional[int] = None, eval_every: int = 50,
            log: Callable[[str], None] = lambda s: None,
            ckpt_path: Optional[str] = None, ckpt_every: int = 0):
        """Train to absolute step `steps` (default tcfg.total_steps) — a resumed
        trainer continues from its restored cursor. With ckpt_path/ckpt_every,
        atomically checkpoints the full run state at those segment boundaries."""
        target = steps if steps is not None else self.tcfg.total_steps
        if self.tcfg.loop == "per_step":
            while self.step < target:
                train_loss = self.train_one_step()
                if self.step % eval_every == 0 or self.step == target:
                    self._record_eval(train_loss, log)
                if (ckpt_path and ckpt_every and self.step % ckpt_every == 0):
                    self.save_checkpoint(ckpt_path)
            return self.history

        while self.step < target:
            t0 = self.step
            end = self._segment_end(t0, target, eval_every, ckpt_every)
            train_loss = self._run_segment(t0, end - t0 + 1)
            if self.step % eval_every == 0 or self.step == target:
                self._record_eval(train_loss, log)
            if ckpt_path and ckpt_every and self.step % ckpt_every == 0:
                self.save_checkpoint(ckpt_path)
        return self.history

    def steps_to_ppl(self, target: float) -> Optional[int]:
        for rec in self.history:
            if rec["ppl"] <= target:
                return rec["step"]
        return None

    # ---------------------------------------------------------- checkpointing

    def trainer_state(self) -> TrainerState:
        return TrainerState(
            params_stack=self.params_stack,
            opt_state=self.opt_state,
            engine=self.engine.state,
            step=self.step,
            wall_clock=float(self.engine.wall_clock),
            data_cursor=self.step,
        )

    def checkpoint_state(self) -> Dict[str, Any]:
        """Full-run checkpoint payload: TrainerState pytree (as plain field
        dicts — msgpack-safe), the host scheduler, eval history, and identity
        metadata for resume validation."""
        ts = self.trainer_state()
        meta = {"schema_version": META_SCHEMA_VERSION,
                "arch": self.mcfg.name, **self._traj_meta()}
        if self.spec is not None:
            meta["spec"] = self.spec.to_dict()
            meta["spec_hash"] = self.spec.spec_hash
        return {
            "format": CKPT_FORMAT,
            "trainer_state": {
                "params_stack": ts.params_stack,
                "opt_state": {"mu": ts.opt_state.mu, "nu": ts.opt_state.nu,
                              "count": ts.opt_state.count},
                "engine": es.state_to_dict(ts.engine),
                "step": ts.step,
                "wall_clock": ts.wall_clock,
                "data_cursor": ts.data_cursor,
            },
            "scheduler": self.engine.scheduler_state(),
            "history": self.history,
            "meta": meta,
        }

    def _traj_meta(self) -> Dict[str, Any]:
        """Every config knob the trajectory is a function of (data streams, LR
        schedule, protocol event schedule) — saved in the checkpoint and
        validated on resume so a mismatched resume errors instead of silently
        diverging."""
        t, c = self.tcfg, self.ccfg
        return {"method": t.method, "seed": t.seed, "total_steps": t.total_steps,
                "warmup_steps": t.warmup_steps, "inner_lr": t.inner_lr,
                "weight_decay": t.weight_decay, "local_batch": t.local_batch,
                "seq_len": t.seq_len, "noniid_frac": t.noniid_frac,
                "num_workers": c.num_workers, "local_steps": c.local_steps,
                "num_fragments": c.num_fragments,
                "overlap_depth": c.overlap_depth,
                "fragment_strategy": self.fragmenter.strategy,
                "routing": c.routing, "hub_failover": c.hub_failover,
                "adaptive_resync": c.adaptive_resync,
                "wire_codec": c.wire_codec, "codec_block": c.codec_block,
                "codec_error_feedback": c.codec_error_feedback,
                "channel_scheduler": c.channel_scheduler,
                "multipath_k": c.multipath_k,
                "fused_updates": c.fused_updates}

    def _upgrade_meta(self, meta: Dict[str, Any]) -> Dict[str, Any]:
        """Single upgrade path for checkpoint meta of any prior schema
        version (the meta twin of `protocol.upgrade_scheduler_state`). A key
        a v1 checkpoint predates implies whatever the key-less code did with
        THIS config: pre-PR3 fragmentation came from strided_fragments;
        pre-PR4 runs had no routed planner or Eq. 9 re-derivation; pre-PR5
        runs carried no spec."""
        meta = dict(meta)
        meta.setdefault("fragment_strategy",
                        "strided" if self.ccfg.strided_fragments
                        else "contiguous")
        meta.setdefault("routing", "static")
        meta.setdefault("hub_failover", False)
        meta.setdefault("adaptive_resync", False)
        meta.setdefault("spec", None)
        meta.setdefault("spec_hash", None)
        # pre-PR6 checkpoints predate the wire codec: raw f32/sync_dtype wire
        meta.setdefault("wire_codec", "none")
        meta.setdefault("codec_block", 256)
        meta.setdefault("codec_error_feedback", True)
        # pre-PR7 checkpoints predate the traffic plane: serial channel queue
        meta.setdefault("channel_scheduler", "serial")
        meta.setdefault("multipath_k", 1)
        # pre-PR8 checkpoints predate the fused engine: per-leaf buffers
        meta.setdefault("fused_updates", False)
        meta["schema_version"] = META_SCHEMA_VERSION
        return meta

    def _validate_resume_identity(self, meta: Dict[str, Any]):
        """Reject a resume whose run identity differs from this trainer's.
        Spec-built trainers compare `spec_hash` (the digest of every
        trajectory-determining spec field); the error names the differing
        fields. Directly-constructed trainers (and pre-spec checkpoints)
        fall back to the per-key trajectory-meta comparison."""
        if self.spec is not None and meta["spec_hash"] is not None:
            if meta["spec_hash"] == self.spec.spec_hash:
                return
            if isinstance(meta["spec"], dict):
                from repro.api.spec import ExperimentSpec
                try:
                    # a checkpoint written before newer spec fields existed
                    # stores a hash over the field-less dict; re-hashing the
                    # SAVED spec with current code fills the new defaults, so
                    # a match proves the stored run is trajectory-identical
                    if ExperimentSpec.from_dict(
                            meta["spec"]).spec_hash == self.spec.spec_hash:
                        return
                except ValueError:
                    pass
            detail = ""
            if isinstance(meta["spec"], dict):
                from repro.api.spec import (_VOLATILE_RUN_FIELDS,
                                            ExperimentSpec, diff_specs)
                try:
                    saved = ExperimentSpec.from_dict(meta["spec"]).traj_dict()
                except ValueError:
                    # e.g. a checkpoint from a newer version with unknown
                    # spec fields: diff the raw dict, but strip the labels
                    # and volatile run fields traj_dict() excludes so the
                    # message only names genuine trajectory differences
                    saved = {k: v for k, v in meta["spec"].items()
                             if k not in ("name", "note")}
                    if isinstance(saved.get("run"), dict):
                        run = {k: v for k, v in saved["run"].items()
                               if k not in _VOLATILE_RUN_FIELDS}
                        # mirror RunSpec.resolved_warmup so a defaulted
                        # warmup is not reported as a spurious diff
                        if run.get("warmup_steps") is None and \
                                isinstance(run.get("steps"), int):
                            run["warmup_steps"] = max(10, run["steps"] // 20)
                        saved["run"] = run
                diffs = diff_specs(saved, self.spec.traj_dict())
                detail = "; differing fields: " + "; ".join(diffs)
            raise ValueError(
                f"checkpoint was written by a different experiment spec "
                f"(spec_hash {meta['spec_hash']} != {self.spec.spec_hash})"
                f"{detail}")
        for k, want in (("arch", self.mcfg.name), *self._traj_meta().items()):
            if meta.get(k) != want:
                raise ValueError(
                    f"checkpoint {k}={meta.get(k)!r} != trainer {want!r} — "
                    f"resume requires the saved run's config (data streams, "
                    f"LR schedule, and the protocol event schedule derive "
                    f"from it)")

    def save_checkpoint(self, path: str):
        save_pytree(path, self.checkpoint_state())

    def restore_checkpoint(self, path: str, state: Optional[Dict] = None):
        """Restore a `checkpoint_state` dump into this (freshly built) trainer.
        The trainer must have been constructed with the same model/protocol
        configs; the restored run continues bit-for-bit where the saved one
        stopped (pinned by tests/test_checkpoint.py kill-and-resume). Pass
        `state` if the checkpoint is already deserialized (avoids a second
        full read of a multi-GB file)."""
        st = load_pytree(path) if state is None else state
        if st.get("format") != CKPT_FORMAT:
            raise ValueError(f"not a {CKPT_FORMAT} checkpoint: {path}")
        self._validate_resume_identity(self._upgrade_meta(st["meta"]))
        ts = st["trainer_state"]
        self.params_stack = restore_like(self.params_stack, ts["params_stack"])
        self.opt_state = AdamWState(
            mu=restore_like(self.opt_state.mu, ts["opt_state"]["mu"]),
            nu=restore_like(self.opt_state.nu, ts["opt_state"]["nu"]),
            count=restore_like(self.opt_state.count, ts["opt_state"]["count"]))
        self.engine.state = es.state_from_dict(self.engine.state, ts["engine"])
        self.engine.restore_scheduler(st["scheduler"])
        # TrainerState is the single authority for the run cursors
        self.engine.wall_clock = float(ts["wall_clock"])
        self.step = int(ts["step"])
        if int(ts["data_cursor"]) != self.step:
            raise ValueError(
                f"checkpoint data_cursor={ts['data_cursor']} != "
                f"step={self.step} (stateful loaders are not supported yet)")
        self.history = [
            {k: (v.item() if getattr(v, "shape", None) == () else v)
             for k, v in rec.items()} for rec in st["history"]]
        return self
