"""Flat fragment plane: one contiguous ``(rows, LANES)`` f32 buffer per
fragment, with static per-leaf offsets computed once at `Fragmenter`
construction.

The per-leaf engine hot paths (outer Nesterov, Eq. 3 blending, Algorithm-1
delay compensation, offline-worker masking) operate on the SAME elements the
`Fragmenter` extracts — but extract/insert hand them over as a pytree, so
every stage pays one `jax.tree.map` pass and every kernel dispatch pays its
own ravel/pad/reshape per leaf. `FlatView` fixes the layout instead:

  * fragment-major: fragment p owns the contiguous row span
    ``[row_start(p), row_start(p) + rows(p))`` of a ``(total_rows, LANES)``
    full-model buffer, so full-model engine buffers (``inflight_delta``,
    ``wire_residual``, the CoCoDC snapshot) are addressed by STATIC row
    slices — no gather, no pad, no reshape per transition;
  * within a fragment: per-leaf chunks in pytree-flatten order at static
    element offsets (layered leaves contribute their fragment rows, whole
    leaves their full extent), zero-padded to a LANES multiple at the
    fragment END only — padding never interleaves with payload, so flat
    elementwise math matches the per-leaf math element-for-element.

`pack`/`unpack` convert between the pytree world (theta_g, momentum, the
worker params stack) and the flat plane at the transition BOUNDARY — one
gather/concatenate per fragment instead of one pad/reshape per leaf per
stage — and everything between (pseudo-gradient mean, codec round trip,
fused kernels) runs on the 2D buffer directly.

Construction is metadata-only (shapes from `jax.eval_shape`): building a
`FlatView` never allocates device memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LANES = 1024            # 8 sublanes x 128 lanes — the f32 TPU tile, flattened


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


@dataclasses.dataclass(frozen=True)
class _Chunk:
    """One leaf's contribution to one fragment's flat buffer."""
    path: str
    offset: int                       # element offset inside the fragment
    size: int                         # element count
    rows: Tuple[int, ...] | None      # layered: layer indices; None = whole
    shape: Tuple[int, ...]            # unraveled chunk shape (rows-first)
    dtype: Any


class FlatView:
    """Static flat layout of a fragmented model (see module docstring).

    Built by `Fragmenter.__init__` from its leaf plans; exposed as
    ``Fragmenter.flat``. All offsets/rows are Python ints — every slice in
    pack/unpack is static under jit.
    """

    LANES = LANES

    def __init__(self, params_shape: Any, plans: Dict[str, Any], K: int,
                 path_str_fn) -> None:
        self.K = int(K)
        leaves = jax.tree_util.tree_flatten_with_path(params_shape)[0]
        self._chunks: List[List[_Chunk]] = []
        self._elems: List[int] = []          # payload elements per fragment
        self._rows: List[int] = []           # padded rows per fragment
        for p in range(self.K):
            chunks: List[_Chunk] = []
            off = 0
            for path, leaf in leaves:
                key = path_str_fn(path)
                plan = plans[key]
                if plan.is_layered:
                    rows = plan.rows[p]
                    if not rows:
                        continue
                    shape = (len(rows),) + tuple(int(d)
                                                 for d in leaf.shape[1:])
                    size = _prod(shape)
                    chunks.append(_Chunk(key, off, size, tuple(rows), shape,
                                         leaf.dtype))
                elif plan.owner == p:
                    size = _prod(leaf.shape)
                    chunks.append(_Chunk(key, off, size, None,
                                         tuple(int(d) for d in leaf.shape),
                                         leaf.dtype))
                else:
                    continue
                off += size
            self._chunks.append(chunks)
            self._elems.append(off)
            self._rows.append(-(-off // LANES))
        starts = np.cumsum([0] + self._rows)
        self._row_start: List[int] = [int(s) for s in starts[:-1]]
        self.total_rows: int = int(starts[-1])
        self._by_path: List[Dict[str, _Chunk]] = [
            {c.path: c for c in chunks} for chunks in self._chunks]

    # ------------------------------------------------------------ geometry

    def rows(self, p: int) -> int:
        """Padded (rows, LANES) row count of fragment p's buffer."""
        return self._rows[p]

    def elems(self, p: int) -> int:
        """Payload elements of fragment p (excludes trailing pad)."""
        return self._elems[p]

    def row_span(self, p: int) -> Tuple[int, int]:
        """Fragment p's ``[start, stop)`` row span in the full-model plane."""
        return self._row_start[p], self._row_start[p] + self._rows[p]

    def full_zeros(self, *lead) -> jax.Array:
        """A zeroed full-model plane buffer, optional leading dims (e.g. the
        worker axis for the CoCoDC snapshot)."""
        return jnp.zeros(tuple(lead) + (self.total_rows, LANES), jnp.float32)

    # ---------------------------------------------------------------- pack

    def pack(self, tree, p: int, *, worker_axis: bool = False) -> jax.Array:
        """Ravel fragment p's elements of `tree` into one f32 buffer:
        ``(rows(p), LANES)``, or ``(M, rows(p), LANES)`` with a leading
        worker axis. Trailing pad is zero."""
        by_path = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            by_path["/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                             for q in path)] = leaf
        lead: Tuple[int, ...] = ()
        if worker_axis:
            lead = (next(iter(by_path.values())).shape[0],)
        parts = []
        for ch in self._chunks[p]:
            leaf = by_path[ch.path]
            if ch.rows is not None:
                leaf = jnp.take(leaf, jnp.asarray(ch.rows),
                                axis=1 if worker_axis else 0)
            parts.append(leaf.reshape(lead + (-1,)).astype(jnp.float32))
        if not parts:
            return jnp.zeros(lead + (0, LANES), jnp.float32)
        flat = jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]
        pad = self._rows[p] * LANES - self._elems[p]
        if pad:
            flat = jnp.pad(flat, [(0, 0)] * len(lead) + [(0, pad)])
        return flat.reshape(lead + (self._rows[p], LANES))

    def pack_stack(self, stack, p: int) -> jax.Array:
        """`pack` with a leading worker axis: ``(M, rows(p), LANES)``."""
        return self.pack(stack, p, worker_axis=True)

    def pack_full(self, tree, *, worker_axis: bool = False) -> jax.Array:
        """Full-model plane: every fragment's buffer stacked along the row
        axis in fragment order — ``(total_rows, LANES)``."""
        bufs = [self.pack(tree, p, worker_axis=worker_axis)
                for p in range(self.K)]
        return jnp.concatenate(bufs, axis=1 if worker_axis else 0)

    # -------------------------------------------------------------- unpack

    def unpack(self, tree, p: int, buf, *, worker_axis: bool = False):
        """Write fragment p's flat buffer back into `tree` (static slices +
        row scatters; leaves absent from p pass through untouched). Values
        are cast back to each leaf's dtype."""
        lead = buf.shape[:-2]
        flat = buf.reshape(lead + (-1,))
        by_path = self._by_path[p]
        off = 1 if worker_axis else 0

        def fn(path, leaf):
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in path)
            ch = by_path.get(key)
            if ch is None:
                return leaf
            x = flat[..., ch.offset:ch.offset + ch.size]
            x = x.reshape(lead + ch.shape).astype(leaf.dtype)
            if ch.rows is None:
                return x
            idx = jnp.asarray(ch.rows)
            return leaf.at[:, idx].set(x) if worker_axis else leaf.at[idx].set(x)

        return jax.tree_util.tree_map_with_path(fn, tree)

    def unpack_stack(self, stack, p: int, buf):
        """`unpack` with a leading worker axis."""
        return self.unpack(stack, p, buf, worker_axis=True)

    def unpack_full(self, tree, buf, *, worker_axis: bool = False):
        """Inverse of `pack_full`: write the whole plane back into `tree`."""
        axis = 1 if worker_axis else 0
        for p in range(self.K):
            r0, r1 = self.row_span(p)
            frag = (buf[:, r0:r1] if worker_axis else buf[r0:r1])
            tree = self.unpack(tree, p, frag, worker_axis=worker_axis)
        return tree
