"""CoCoDC core: the paper's contribution.

  fragments    — depth-wise model fragmentation (Streaming DiLoCo / CoCoDC)
  outer_opt    — Nesterov outer optimizer on pseudo-gradients
  delay_comp   — Algorithm 1 (Taylor-expansion staleness compensation)
  adaptive     — Algorithm 2 + Eqs. 9-12 (adaptive transmission scheduling)
  network      — WAN cost models: symmetric NetworkModel + heterogeneous
                 per-link Topology (ring/hierarchical collectives, scenarios)
  engine_state — functional EngineState pytree + pure jitted transitions
  protocol     — thin host wrapper: simulated wall-clock, channel queueing,
                 schedule, per-link stats around the EngineState transitions
"""
from repro.core.adaptive import AdaptiveState, select_fragment, sync_interval, target_syncs  # noqa: F401
from repro.core.delay_comp import blend, compensate  # noqa: F401
from repro.core.engine_state import EngineState, init_state, make_engine_fns  # noqa: F401
from repro.core.fragments import Fragmenter, make_fragmenter  # noqa: F401
from repro.core.network import (NetworkModel, Topology, as_topology,  # noqa: F401
                                make_scenario, paper_network)
from repro.core.protocol import ProtocolEngine  # noqa: F401
