"""CoCoDC core: the paper's contribution.

  fragments   — depth-wise model fragmentation (Streaming DiLoCo / CoCoDC)
  outer_opt   — Nesterov outer optimizer on pseudo-gradients
  delay_comp  — Algorithm 1 (Taylor-expansion staleness compensation)
  adaptive    — Algorithm 2 + Eqs. 9-12 (adaptive transmission scheduling)
  network     — WAN latency/bandwidth + compute-time model
  protocol    — event-driven engines: DiLoCo / Streaming DiLoCo / CoCoDC
"""
from repro.core.adaptive import AdaptiveState, select_fragment, sync_interval, target_syncs  # noqa: F401
from repro.core.delay_comp import blend, compensate  # noqa: F401
from repro.core.fragments import Fragmenter, make_fragmenter  # noqa: F401
from repro.core.network import NetworkModel, paper_network  # noqa: F401
from repro.core.protocol import ProtocolEngine  # noqa: F401
