"""CoCoDC core: the paper's contribution.

  fragments    — depth-wise model fragmentation (Streaming DiLoCo / CoCoDC)
  outer_opt    — Nesterov outer optimizer on pseudo-gradients
  delay_comp   — Algorithm 1 (Taylor-expansion staleness compensation)
  adaptive     — Algorithm 2 + Eqs. 9-12 (adaptive transmission scheduling)
  network      — WAN cost models: symmetric NetworkModel + heterogeneous
                 per-link Topology (ring/hierarchical collectives, scenarios)
                 + the routed CommPlan/RoutePlanner layer (multi-hop routes,
                 hub failover, per-edge re-planning on dynamic links)
  engine_state — functional EngineState pytree + pure jitted transitions
  protocol     — thin host wrapper: simulated wall-clock, channel queueing,
                 schedule, per-link stats around the EngineState transitions
"""
from repro.core.adaptive import (AdaptiveState, ResyncState, select_fragment,  # noqa: F401
                                 sync_interval, target_syncs)
from repro.core.delay_comp import blend, compensate  # noqa: F401
from repro.core.engine_state import EngineState, init_state, make_engine_fns  # noqa: F401
from repro.core.fragments import Fragmenter, make_fragmenter  # noqa: F401
from repro.core.network import (CommPlan, NetworkModel, RoutePlanner,  # noqa: F401
                                Topology, as_topology, make_scenario,
                                paper_network)
from repro.core.protocol import ProtocolEngine  # noqa: F401
