"""Model fragmentation along depth (Streaming DiLoCo / CoCoDC).

The model is partitioned into K disjoint fragments. Layer-stacked leaves (leading
axis == a known layer count) are split by layer rows under a ``strategy``:

  * "strided"    — layer l -> fragment l % K (the Streaming DiLoCo pattern)
  * "contiguous" — equal consecutive blocks
  * "skewed"     — size-skewed consecutive blocks: fragment p targets a
    geometric byte share ∝ SKEW_RATIO**p (every fragment keeps >= 1 layer when
    depth allows). Heterogeneous fragment sizes make per-fragment WAN costs
    differ, which is what separates Eq. 12 from Algorithm-2 cost-aware
    selection on heterogeneous topologies (ROADMAP PR 2 finding).

Non-stacked leaves (embeddings, heads, norms) are assigned wholesale to
fragments, greedily balancing fragment bytes (weighted by the same geometric
targets under "skewed").

The Fragmenter works on abstract shapes (eval_shape) so constructing it never
allocates; extract/insert are pure jittable gathers/scatters with static indices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


@dataclasses.dataclass(frozen=True)
class _LeafPlan:
    path: str
    is_layered: bool
    # layered: rows[p] = tuple of layer indices for fragment p
    rows: Tuple[Tuple[int, ...], ...] | None
    # non-layered: owning fragment
    owner: int | None
    nbytes_per_row: int
    nbytes: int


class Fragmenter:
    STRATEGIES = ("strided", "contiguous", "skewed")
    SKEW_RATIO = 0.55      # geometric byte share of fragment p ∝ SKEW_RATIO**p

    def __init__(self, params_shape: Any, n_fragments: int,
                 layer_counts: Sequence[int], *, strided: bool = True,
                 strategy: str = ""):
        """params_shape: pytree of ShapeDtypeStruct (jax.eval_shape of init).
        layer_counts: leading-dim sizes that mark a leaf as layer-stacked
        (e.g. {n_layers, n_groups, n_enc_layers}). `strategy` overrides the
        legacy `strided` flag when non-empty."""
        self.K = int(n_fragments)
        if not strategy:
            strategy = "strided" if strided else "contiguous"
        if strategy not in self.STRATEGIES:
            raise ValueError(f"unknown fragment strategy {strategy!r}; "
                             f"options: {self.STRATEGIES}")
        self.strategy = strategy
        weights = (np.array([self.SKEW_RATIO ** p for p in range(self.K)])
                   if strategy == "skewed" else np.ones(self.K))
        counts = {int(c) for c in layer_counts if int(c) > 1}
        leaves = jax.tree_util.tree_flatten_with_path(params_shape)[0]
        plans: List[_LeafPlan] = []
        frag_bytes = np.zeros(self.K, dtype=np.int64)

        # pass 1: layered leaves
        pending_flat = []
        for path, leaf in leaves:
            p = _path_str(path)
            nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize if leaf.shape else leaf.dtype.itemsize
            layered = (len(leaf.shape) >= 2 and leaf.shape[0] in counts
                       and p.split("/")[0] in ("layers", "encoder", "decoder",
                                               "rem", "groups"))
            if layered:
                L = leaf.shape[0]
                rows = self._layer_rows(L)
                per_row = nbytes // L
                for f in range(self.K):
                    frag_bytes[f] += per_row * len(rows[f])
                plans.append(_LeafPlan(p, True, rows, None, per_row, nbytes))
            else:
                pending_flat.append((p, nbytes))

        # pass 2: whole leaves, biggest first, to the (weight-relative)
        # lightest fragment — uniform weights reproduce the legacy greedy
        for p, nbytes in sorted(pending_flat, key=lambda t: -t[1]):
            owner = int(np.argmin(frag_bytes / weights))
            frag_bytes[owner] += nbytes
            plans.append(_LeafPlan(p, False, None, owner, nbytes, nbytes))

        self._plans: Dict[str, _LeafPlan] = {pl.path: pl for pl in plans}
        self._frag_bytes = frag_bytes
        # flat fragment plane: static (rows, LANES) layout per fragment with
        # per-leaf element offsets — metadata only (never allocates); the
        # fused engine path addresses every full-model buffer through it
        from repro.core.flatplane import FlatView
        self.flat = FlatView(params_shape, self._plans, self.K, _path_str)

    def _layer_rows(self, L: int) -> Tuple[Tuple[int, ...], ...]:
        """Per-fragment layer indices for an L-deep stacked leaf."""
        K = self.K
        if self.strategy == "strided":
            rows = [[] for _ in range(K)]
            for l in range(L):
                rows[l % K].append(l)
        elif self.strategy == "contiguous":
            rows = [[] for _ in range(K)]
            for l in range(L):
                rows[min(l * K // L, K - 1)].append(l)
        else:  # skewed: geometric consecutive block sizes, >=1 layer each
            if L < K:
                sizes = [1 if p < L else 0 for p in range(K)]
            else:
                w = np.array([self.SKEW_RATIO ** p for p in range(K)])
                extra = (L - K) * w / w.sum()
                base = np.floor(extra).astype(int)
                order = sorted(range(K),
                               key=lambda p: (-(extra[p] - base[p]), p))
                for p in order[:int(L - K - base.sum())]:
                    base[p] += 1
                sizes = [1 + int(b) for b in base]
            rows, off = [], 0
            for s in sizes:
                rows.append(list(range(off, off + s)))
                off += s
        return tuple(tuple(r) for r in rows)

    # -- interface ----------------------------------------------------------

    def fragment_bytes(self, p: int) -> int:
        return int(self._frag_bytes[p])

    @property
    def total_bytes(self) -> int:
        return int(self._frag_bytes.sum())

    def _plan(self, path) -> _LeafPlan:
        return self._plans[_path_str(path)]

    def extract(self, tree, p: int, *, worker_axis: bool = False):
        """Return the fragment-p sub-pytree (same structure; absent leaves -> None,
        layered leaves -> only fragment rows). worker_axis: leaves have a leading
        worker dim M before the layer axis."""
        off = 1 if worker_axis else 0

        def fn(path, leaf):
            plan = self._plan(path)
            if plan.is_layered:
                rows = plan.rows[p]
                if not rows:
                    return None
                return jnp.take(leaf, jnp.asarray(rows), axis=off)
            return leaf if plan.owner == p else None

        return jax.tree_util.tree_map_with_path(fn, tree)

    def insert(self, tree, p: int, frag, *, worker_axis: bool = False):
        """Write fragment-p values back into the full tree."""
        off = 1 if worker_axis else 0

        def fn(path, leaf, fleaf):
            plan = self._plan(path)
            if plan.is_layered:
                rows = plan.rows[p]
                if not rows or fleaf is None:
                    return leaf
                idx = jnp.asarray(rows)
                if worker_axis:
                    return leaf.at[:, idx].set(fleaf)
                return leaf.at[idx].set(fleaf)
            if plan.owner == p and fleaf is None:
                raise ValueError(f"missing fragment leaf for {_path_str(path)}")
            return fleaf if plan.owner == p else leaf

        return jax.tree_util.tree_map_with_path(fn, tree, frag,
                                                is_leaf=lambda x: x is None)

    def extract_meta(self, tree, p: int):
        """Structure-only extraction (no slicing): keeps the leaf object itself for
        leaves present in fragment p, None otherwise. Used to derive sharding /
        SDS pytrees for fragment arguments (a row-take preserves rank, so the
        original sharding applies to the sliced leaf)."""

        def fn(path, leaf):
            plan = self._plan(path)
            if plan.is_layered:
                return leaf if plan.rows[p] else None
            return leaf if plan.owner == p else None

        return jax.tree_util.tree_map_with_path(fn, tree)

    def owners(self) -> Dict[str, Any]:
        """Debug/properties: path -> (fragment owner | per-fragment rows)."""
        return {p: (pl.rows if pl.is_layered else pl.owner)
                for p, pl in self._plans.items()}


def make_fragmenter(cfg_model, params_shape, n_fragments: int, *,
                    strided: bool = True, strategy: str = "") -> Fragmenter:
    counts = [cfg_model.n_layers, cfg_model.n_enc_layers]
    if cfg_model.block_pattern:
        counts.append(cfg_model.n_layers // len(cfg_model.block_pattern))
    return Fragmenter(params_shape, n_fragments, counts, strided=strided,
                      strategy=strategy)
