"""WAN network + compute model for the cross-region simulation.

Models the paper's environment: M datacenters joined by high-latency,
bandwidth-limited links running ring all-reduce. Supplies:
  * T_s(bytes)  — single-fragment ring all-reduce time (Eq. 9 denominator)
  * T_c         — per-local-step compute time
  * tau(bytes)  — overlap depth implied by T_s/T_c (or fixed, paper-style)
and a simulated wall-clock used by the protocol engines (DiLoCo blocks on T_s;
Streaming/CoCoDC hide it under compute).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NetworkModel:
    num_workers: int = 4
    latency_s: float = 0.15          # WAN RTT-scale latency per all-reduce phase
    bandwidth_Bps: float = 1.25e9    # 10 Gb/s inter-DC
    step_time_s: float = 1.0         # T_c: one local training step

    def allreduce_time(self, nbytes: int) -> float:
        """Ring all-reduce: 2(M-1)/M of the payload crosses each link, plus
        2(M-1) latency hops."""
        m = self.num_workers
        if m <= 1:
            return 0.0
        return 2 * (m - 1) * self.latency_s + (2 * (m - 1) / m) * nbytes / self.bandwidth_Bps

    @property
    def t_c(self) -> float:
        return self.step_time_s

    def t_s(self, nbytes: int) -> float:
        return self.allreduce_time(nbytes)

    def tau_steps(self, nbytes: int) -> int:
        """Overlap depth implied by the network: steps of compute that fit inside
        one fragment all-reduce."""
        import math
        return max(1, math.ceil(self.t_s(nbytes) / self.t_c))


def paper_network(num_workers: int = 4, *, step_time_s: float = 1.0,
                  fragment_bytes: int | None = None,
                  tau: int = 5) -> NetworkModel:
    """Network calibrated so that T_s = tau * T_c for the given fragment size,
    matching the paper's tau=5, N=8 (gamma=0.4, H=100) setting."""
    if fragment_bytes is None or num_workers <= 1:
        return NetworkModel(num_workers=num_workers, step_time_s=step_time_s)
    m = num_workers
    target_ts = tau * step_time_s
    lat = 0.1 * target_ts / (2 * (m - 1))          # 10% latency, 90% bandwidth
    bw = (2 * (m - 1) / m) * fragment_bytes / (0.9 * target_ts)
    return NetworkModel(num_workers=m, latency_s=lat, bandwidth_Bps=bw,
                        step_time_s=step_time_s)
