"""WAN network + compute model for the cross-region simulation.

Three levels of fidelity:

``NetworkModel`` — the original single-link symmetric model (kept for
back-compat and closed-form tests): one latency, one bandwidth, ring
all-reduce over M identical links.

``Topology`` — the heterogeneous simulator the protocol engine actually runs
on: a per-region-pair latency/bandwidth matrix, a choice of collective
algorithm (ring vs hub-and-spoke hierarchical), a bounded number of concurrent
WAN collectives (contention), and per-link traffic accounting. Fragment
delivery times are derived from simulated transfer *completion* (initiation
time + queueing + per-link bottleneck cost), not a fixed ``t + tau``.

``LinkDynamics`` — time-varying behavior layered on a Topology: piecewise
diurnal bandwidth curves (per-region phase offsets), scheduled link
degradation/outage windows (an outage pauses in-flight collectives; recovery
pays the latency phases again — a retry), and seeded per-transfer jitter.
``Topology.transfer_time`` integrates the bottleneck bandwidth over time, so a
transfer that straddles a trough or an outage finishes late by exactly the
bandwidth-seconds it lost. ``dynamics is None`` keeps the closed-form static
path bitwise-unchanged (regression-pinned).

``CommPlan`` / ``RoutePlanner`` — the routed communication-plan layer on top
of the above: a plan is the executable route set for ONE collective (logical
links, multi-hop routes chosen by sum-latency + bottleneck-bandwidth cost over
the *current* effective link state, participants, effective hub) valid until
the next ``LinkDynamics`` edge. ``RoutePlanner.plan_at(t)`` is a pure function
of wall-time, so every region replaying the shared dynamics clock elects the
same hub and computes identical routes with zero coordination — and a resumed
run re-derives the active plan from its serialized plan time. Hub failover:
while the declared hub's links are out the next-best-connected region is
deterministically elected in its place (restored on recovery), and fully dark
regions drop out of the collective instead of stalling it.

All expose the same cost API used by the engines and Eq. 9:
  * ``t_s(bytes)``   — one fragment all-reduce (wall seconds, nominal)
  * ``t_c``          — per-local-step compute time
  * ``tau_steps(b)`` — overlap depth implied by T_s/T_c

Scenario constructors (``SCENARIOS``) cover fixed hand-built meshes;
``generate_mesh`` (``MESH_PROFILES``: ring / hub_spoke / continental /
random_geo) builds seeded N-region meshes for arbitrary N, and
``apply_dynamics`` parses a ``"diurnal:...,hub_failure:...,jitter:..."`` spec
string into a ``LinkDynamics`` attached to any Topology.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class NetworkModel:
    num_workers: int = 4
    latency_s: float = 0.15          # WAN RTT-scale latency per all-reduce phase
    bandwidth_Bps: float = 1.25e9    # 10 Gb/s inter-DC
    step_time_s: float = 1.0         # T_c: one local training step

    def allreduce_time(self, nbytes: int) -> float:
        """Ring all-reduce: 2(M-1)/M of the payload crosses each link, plus
        2(M-1) latency hops."""
        m = self.num_workers
        if m <= 1:
            return 0.0
        return 2 * (m - 1) * self.latency_s + (2 * (m - 1) / m) * nbytes / self.bandwidth_Bps

    @property
    def t_c(self) -> float:
        return self.step_time_s

    def t_s(self, nbytes: int) -> float:
        return self.allreduce_time(nbytes)

    def tau_steps(self, nbytes: int) -> int:
        """Overlap depth implied by the network: steps of compute that fit inside
        one fragment all-reduce."""
        return max(1, math.ceil(self.t_s(nbytes) / self.t_c))

    def to_topology(self) -> "Topology":
        """Equivalent symmetric Topology (identical allreduce_time)."""
        return Topology.uniform(self.num_workers, latency_s=self.latency_s,
                                bandwidth_Bps=self.bandwidth_Bps,
                                step_time_s=self.step_time_s)


def paper_network(num_workers: int = 4, *, step_time_s: float = 1.0,
                  fragment_bytes: int | None = None,
                  tau: int = 5) -> NetworkModel:
    """Network calibrated so that T_s = tau * T_c for the given fragment size,
    matching the paper's tau=5, N=8 (gamma=0.4, H=100) setting."""
    if fragment_bytes is None or num_workers <= 1:
        return NetworkModel(num_workers=num_workers, step_time_s=step_time_s)
    m = num_workers
    target_ts = tau * step_time_s
    lat = 0.1 * target_ts / (2 * (m - 1))          # 10% latency, 90% bandwidth
    bw = (2 * (m - 1) / m) * fragment_bytes / (0.9 * target_ts)
    return NetworkModel(num_workers=m, latency_s=lat, bandwidth_Bps=bw,
                        step_time_s=step_time_s)


# ---------------------------------------------------------------------------
# time-varying link dynamics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkEvent:
    """One scheduled degradation/outage window on a (optionally symmetric)
    directed link: during [start_s, end_s) the link's bandwidth is multiplied
    by ``bandwidth_factor`` (0.0 = outage) and ``extra_latency_s`` is added to
    every latency phase that starts inside the window."""
    start_s: float
    end_s: float
    src: int
    dst: int
    bandwidth_factor: float = 1.0
    extra_latency_s: float = 0.0
    symmetric: bool = True

    def covers(self, i: int, j: int) -> bool:
        return (i, j) == (self.src, self.dst) or (
            self.symmetric and (i, j) == (self.dst, self.src))

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


@dataclasses.dataclass(frozen=True)
class DiurnalProfile:
    """Piecewise-constant day/night bandwidth curve. The underlying cosine dips
    to ``1 - trough_depth`` half a period after each region's local midnight
    (``phase_s``), sampled at ``n_bins`` bins per period so the time
    integration is exact and resume-deterministic. A link's phase is the mean
    of its endpoint regions' phases (congestion follows both ends)."""
    period_s: float = 240.0
    trough_depth: float = 0.5
    n_bins: int = 24
    phase_s: Tuple[float, ...] = ()      # per-region offsets; () = synchronized

    def link_phase(self, i: int, j: int) -> float:
        if not self.phase_s:
            return 0.0
        return 0.5 * (self.phase_s[i] + self.phase_s[j])

    def factor(self, i: int, j: int, t: float) -> float:
        """Bandwidth multiplier for link (i, j) at wall-time t (bin-sampled)."""
        phase = self.link_phase(i, j)
        u = ((t - phase) / self.period_s) % 1.0
        center = (math.floor(u * self.n_bins) + 0.5) / self.n_bins
        return 1.0 - self.trough_depth * (0.5 - 0.5 * math.cos(
            2.0 * math.pi * center))

    def next_edge(self, i: int, j: int, t: float) -> float:
        """First bin boundary strictly after t for link (i, j)."""
        w = self.period_s / self.n_bins
        phase = self.link_phase(i, j)
        k = math.floor((t - phase) / w + 1e-9) + 1
        return phase + k * w


@dataclasses.dataclass(frozen=True)
class LinkDynamics:
    """Time-varying behavior of a Topology's links: a diurnal bandwidth curve,
    scheduled degradation/outage events, and seeded per-transfer jitter.

    Everything is a pure function of wall-time plus a caller-owned draw
    counter (``jitter_mult(seq)``), so a resumed run that restores the
    scheduler's clocks (channel frees + the jitter sequence counter) replays
    the exact same transfer completions — no hidden RNG state."""
    diurnal: Optional[DiurnalProfile] = None
    events: Tuple[LinkEvent, ...] = ()
    jitter_frac: float = 0.0
    seed: int = 0
    retry_latency: bool = True    # outage interruption re-pays latency phases

    @property
    def is_trivial(self) -> bool:
        return (self.diurnal is None and not self.events
                and self.jitter_frac == 0.0)

    # --------------------------------------------------------- point queries

    def bw_factor(self, i: int, j: int, t: float) -> float:
        f = self.diurnal.factor(i, j, t) if self.diurnal else 1.0
        for ev in self.events:
            if ev.covers(i, j) and ev.active(t):
                f *= ev.bandwidth_factor
        return f

    def extra_latency_s(self, i: int, j: int, t: float) -> float:
        out = 0.0
        for ev in self.events:
            if ev.covers(i, j) and ev.active(t):
                out += ev.extra_latency_s
        return out

    def jitter_mult(self, seq: int) -> float:
        """Deterministic per-transfer bandwidth-work multiplier: the `seq`-th
        transfer always draws the same jitter for a given seed (counter-based,
        stateless — the counter itself is serialized by the scheduler)."""
        if self.jitter_frac <= 0.0:
            return 1.0
        u = np.random.default_rng(
            np.random.SeedSequence([int(self.seed) & 0x7FFFFFFF, int(seq)])
        ).uniform(-1.0, 1.0)
        return float(1.0 + self.jitter_frac * u)

    # ------------------------------------------------------ piecewise change

    def next_change(self, links: Sequence[Tuple[int, int]],
                    t: float) -> Optional[float]:
        """Earliest time strictly after t at which any used link's factor can
        change (diurnal bin edge or event boundary). None = constant forever."""
        nxt = math.inf
        if self.diurnal is not None:
            for i, j in links:
                nxt = min(nxt, self.diurnal.next_edge(i, j, t))
        for ev in self.events:
            if any(ev.covers(i, j) for i, j in links):
                for edge in (ev.start_s, ev.end_s):
                    if edge > t:
                        nxt = min(nxt, edge)
        return None if math.isinf(nxt) else nxt


# ---------------------------------------------------------------------------
# heterogeneous topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """Immutable description + cost model of a heterogeneous inter-region WAN.

    latency_s / bandwidth_Bps are dense (M, M) matrices over *directed* links
    (diag ignored). ``collective`` picks the all-reduce algorithm:
      * "ring"         — fixed ring 0 -> 1 -> ... -> M-1 -> 0; 2(M-1) phases of
                         nbytes/M chunks, each phase paced by the slowest link.
      * "hierarchical" — reduce-to-hub then broadcast; both halves paced by the
                         slowest spoke link (concurrent spoke transfers).
    ``concurrent_collectives`` bounds how many fragment all-reduces the WAN
    carries at once; the engine queues the excess (contention -> later
    delivery). ``dynamics`` (optional) makes the links time-varying — see
    ``transfer_time``; None keeps the closed-form static path byte-for-byte.
    Mutable transfer-schedule state lives in the engine, not here.
    """
    latency_s: np.ndarray
    bandwidth_Bps: np.ndarray
    step_time_s: float = 1.0
    regions: Tuple[str, ...] = ()
    collective: str = "ring"
    hub: int = 0
    concurrent_collectives: int = 1
    dynamics: Optional[LinkDynamics] = None

    def __post_init__(self):
        lat = np.asarray(self.latency_s, dtype=np.float64)
        bw = np.asarray(self.bandwidth_Bps, dtype=np.float64)
        if lat.shape != bw.shape or lat.ndim != 2 or lat.shape[0] != lat.shape[1]:
            raise ValueError(f"latency/bandwidth must be square & congruent, "
                             f"got {lat.shape} vs {bw.shape}")
        if self.collective not in ("ring", "hierarchical"):
            raise ValueError(f"unknown collective {self.collective!r}")
        if int(self.concurrent_collectives) < 1:
            raise ValueError(
                f"concurrent_collectives must be >= 1 (the serial scheduler "
                f"needs at least one WAN channel), got "
                f"{self.concurrent_collectives}")
        object.__setattr__(self, "latency_s", lat)
        object.__setattr__(self, "bandwidth_Bps", bw)
        if not self.regions:
            object.__setattr__(
                self, "regions",
                tuple(f"region{i}" for i in range(lat.shape[0])))

    # ------------------------------------------------------------- properties

    @property
    def num_workers(self) -> int:
        return self.latency_s.shape[0]

    @property
    def t_c(self) -> float:
        return self.step_time_s

    @property
    def is_symmetric(self) -> bool:
        links = self._links()
        lats = [self.latency_s[i, j] for i, j in links]
        bws = [self.bandwidth_Bps[i, j] for i, j in links]
        return (np.allclose(lats, lats[0]) and np.allclose(bws, bws[0])
                if links else True)

    # ----------------------------------------------------------- cost models

    def _links(self):
        """Directed links the collective uses."""
        m = self.num_workers
        if m <= 1:
            return []
        if self.collective == "ring":
            return [(i, (i + 1) % m) for i in range(m)]
        h = self.hub
        out = []
        for i in range(m):
            if i != h:
                out.extend([(i, h), (h, i)])
        return out

    def allreduce_time(self, nbytes: int) -> float:
        m = self.num_workers
        if m <= 1:
            return 0.0
        if self.collective == "ring":
            chunk = nbytes / m
            phase = max(self.latency_s[i, j] + chunk / self.bandwidth_Bps[i, j]
                        for i, j in self._links())
            return 2 * (m - 1) * phase
        h = self.hub
        gather = max(self.latency_s[i, h] + nbytes / self.bandwidth_Bps[i, h]
                     for i in range(m) if i != h)
        bcast = max(self.latency_s[h, i] + nbytes / self.bandwidth_Bps[h, i]
                    for i in range(m) if i != h)
        return gather + bcast

    def t_s(self, nbytes: int) -> float:
        return self.allreduce_time(nbytes)

    def tau_steps(self, nbytes: int) -> int:
        return max(1, math.ceil(self.t_s(nbytes) / self.t_c))

    # ------------------------------------------- time-integrated transfers

    @property
    def n_latency_phases(self) -> int:
        """Latency phases one collective pays (ring: 2(M-1) hops; hierarchical:
        gather + broadcast)."""
        m = self.num_workers
        if m <= 1:
            return 0
        return 2 * (m - 1) if self.collective == "ring" else 2

    def _dyn_latency(self, links, t: float,
                     n_phases: Optional[int] = None) -> float:
        """Event-driven extra latency for phases starting at wall-time t.
        `n_phases` overrides the collective's phase count (routed plans may
        use a different participant set than the full mesh)."""
        dyn = self.dynamics
        if dyn is None or not dyn.events:
            return 0.0
        extra = max((dyn.extra_latency_s(i, j, t) for i, j in links),
                    default=0.0)
        if n_phases is None:
            n_phases = self.n_latency_phases
        return n_phases * extra

    def _integrate_transfer(self, links, lat: float, work: float, start: float,
                            n_phases: int) -> Tuple[float, int]:
        """Shared time-integration core of `transfer_time` /
        `plan_transfer_time`: serve `work` bandwidth-seconds over `links`
        starting at `start` (after `lat` seconds of latency phases), pausing
        through outages and re-paying the latency phases on recovery."""
        dyn = self.dynamics
        t = start + lat + self._dyn_latency(links, start, n_phases)
        n_retries = 0
        in_outage = False
        for _ in range(1_000_000):
            rho = min(dyn.bw_factor(i, j, t) for i, j in links)
            nxt = dyn.next_change(links, t)
            if rho <= 0.0:                       # outage: wait for recovery
                if nxt is None:
                    raise RuntimeError(
                        f"transfer started at {start:.3f}s hit a permanent "
                        f"outage at {t:.3f}s (no future dynamics change)")
                t = nxt
                in_outage = True                 # one retry per RECOVERY, not
                continue                         # per bin edge inside the dark
            if in_outage:                        # window
                in_outage = False
                n_retries += 1
                if dyn.retry_latency:
                    t += lat + self._dyn_latency(links, t, n_phases)
                    continue                     # latency may cross an edge
            if work <= 0.0:
                break
            if nxt is None or work <= (nxt - t) * rho:
                t += work / rho
                break
            work -= (nxt - t) * rho
            t = nxt
        else:
            raise RuntimeError("transfer_time did not converge "
                               "(pathological dynamics spec)")
        return t, n_retries

    def transfer_time(self, nbytes: int, start: float, *,
                      jitter: float = 1.0) -> Tuple[float, float, int]:
        """Simulate one collective of `nbytes` starting at wall-time `start`
        under ``self.dynamics``: integrates the bottleneck bandwidth factor
        (min over the collective's links) through diurnal bins and event
        windows. An outage (factor 0) pauses the transfer; on recovery the
        collective re-establishes and pays its latency phases again (a retry).

        Returns ``(finish_time, nominal_t_s, n_retries)``. With
        ``dynamics=None`` this is exactly ``start + t_s(nbytes)``.
        """
        nominal = self.allreduce_time(nbytes)
        dyn = self.dynamics
        if dyn is None:
            return start + nominal, nominal, 0
        links = self._links()
        if not links:
            return start + nominal, nominal, 0
        lat = self.allreduce_time(0)            # latency phases (fixed part)
        work = (nominal - lat) * jitter         # bandwidth-seconds to serve
        t, n_retries = self._integrate_transfer(links, lat, work, start,
                                                self.n_latency_phases)
        return t, nominal, n_retries

    # ------------------------------------------------- plan-based cost model

    def plan_n_latency_phases(self, plan: "CommPlan") -> int:
        """Latency phases the planned collective pays (over its PARTICIPANTS,
        which may be fewer than the mesh during an outage)."""
        p = len(plan.participants)
        if p <= 1:
            return 0
        return 2 * (p - 1) if plan.kind == "ring" else 2

    def _plan_route_costs(self, plan: "CommPlan"):
        """Per logical link: (summed latency, bottleneck bandwidth) of its hop
        chain, from the STATIC matrices (nominal cost; dynamics are applied by
        the time integration)."""
        lats = [sum(self.latency_s[a, b] for a, b in route)
                for route in plan.routes]
        bws = [min(self.bandwidth_Bps[a, b] for a, b in route)
               for route in plan.routes]
        return lats, bws

    def plan_allreduce_time(self, plan: "CommPlan", nbytes: int) -> float:
        """Nominal wall-seconds of one collective executed over `plan`'s
        routes. For single-hop direct routes over the full mesh this is
        EXACTLY `allreduce_time(nbytes)` (same arithmetic)."""
        p = len(plan.participants)
        if p <= 1 or not plan.logical:
            return 0.0
        if plan.multiroutes:
            return self._multiroute_allreduce_time(plan, nbytes)
        lats, bws = self._plan_route_costs(plan)
        if plan.kind == "ring":
            chunk = nbytes / p
            phase = max(l + chunk / w for l, w in zip(lats, bws))
            return 2 * (p - 1) * phase
        h = plan.hub
        gather = max(l + nbytes / w
                     for (i, j), l, w in zip(plan.logical, lats, bws)
                     if j == h)
        bcast = max(l + nbytes / w
                    for (i, j), l, w in zip(plan.logical, lats, bws)
                    if i == h)
        return gather + bcast

    def _multiroute_allreduce_time(self, plan: "CommPlan",
                                   nbytes: int) -> float:
        """Multipath variant: a logical link's cost is the max over its
        subflows (each pays its own path latency + its byte share over the
        path's bottleneck bandwidth); completion = slowest subflow."""
        p = len(plan.participants)

        def group_cost(group, b):
            return max(
                sum(self.latency_s[x, y] for x, y in route)
                + share * b / min(self.bandwidth_Bps[x, y] for x, y in route)
                for route, share in group)

        if plan.kind == "ring":
            chunk = nbytes / p
            phase = max(group_cost(g, chunk) for g in plan.multiroutes)
            return 2 * (p - 1) * phase
        h = plan.hub
        gather = max(group_cost(g, nbytes)
                     for (i, j), g in zip(plan.logical, plan.multiroutes)
                     if j == h)
        bcast = max(group_cost(g, nbytes)
                    for (i, j), g in zip(plan.logical, plan.multiroutes)
                    if i == h)
        return gather + bcast

    def plan_link_bytes(self, plan: "CommPlan", nbytes: int) -> np.ndarray:
        """(M, M) bytes each directed PHYSICAL link carries for one collective
        routed per `plan` (every hop of a logical link's route carries that
        logical link's full payload share)."""
        m = self.num_workers
        out = np.zeros((m, m), dtype=np.float64)
        p = len(plan.participants)
        if p <= 1 or not plan.logical:
            return out
        per_logical = (2 * (p - 1) * nbytes / p if plan.kind == "ring"
                       else nbytes)
        for route, share in plan.iter_routes():
            for a, b in route:
                out[a, b] += per_logical * share
        return out

    def plan_link_seconds(self, plan: "CommPlan", nbytes: int) -> np.ndarray:
        """(M, M) nominal busy-seconds per directed physical link for one
        collective routed per `plan`."""
        m = self.num_workers
        out = np.zeros((m, m), dtype=np.float64)
        p = len(plan.participants)
        if p <= 1 or not plan.logical:
            return out
        if plan.kind == "ring":
            phases, chunk = 2 * (p - 1), nbytes / p
            for route, share in plan.iter_routes():
                for a, b in route:
                    out[a, b] += phases * (
                        self.latency_s[a, b]
                        + share * chunk / self.bandwidth_Bps[a, b])
        else:
            for route, share in plan.iter_routes():
                for a, b in route:
                    out[a, b] += (self.latency_s[a, b]
                                  + share * nbytes / self.bandwidth_Bps[a, b])
        return out

    def plan_link_bw_seconds(self, plan: "CommPlan",
                             nbytes: int) -> np.ndarray:
        """(M, M) pure bandwidth busy-seconds per directed physical link for
        one planned collective — `plan_link_seconds` minus the latency-phase
        terms. These are the fair-share scheduler's per-link weights: a link's
        entry is the byte volume it carries over its static bandwidth."""
        b = self.plan_link_bytes(plan, nbytes)
        out = np.zeros_like(b)
        nz = b > 0.0
        out[nz] = b[nz] / self.bandwidth_Bps[nz]
        return out

    def plan_transfer_time(self, plan: "CommPlan", nbytes: int, start: float,
                           *, jitter: float = 1.0) -> Tuple[float, float, int]:
        """`transfer_time` over a FIXED routed plan: the bottleneck factor is
        taken over the plan's physical hops (a plan that routed around a dark
        link never waits on it). See `routed_transfer_time` for the
        re-planning variant the engine uses."""
        nominal = self.plan_allreduce_time(plan, nbytes)
        dyn = self.dynamics
        links = plan.phys_links
        if dyn is None or not links:
            return start + nominal, nominal, 0
        lat = self.plan_allreduce_time(plan, 0)
        work = (nominal - lat) * jitter
        t, n_retries = self._integrate_transfer(
            links, lat, work, start, self.plan_n_latency_phases(plan))
        return t, nominal, n_retries

    def routed_transfer_time(
            self, plan_fn, nbytes: int, start: float, *,
            jitter: float = 1.0,
    ) -> Tuple[float, float, int, List[Tuple["CommPlan", float]]]:
        """Simulate one collective on RE-PLANNABLE routes. ``plan_fn(t)``
        supplies the valid plan at wall-time t (the engine passes a wrapper
        around its `_active_plan`, so counters and plan side effects track
        every refresh). The transfer executes plan_fn(start)'s routes; at a
        plan validity edge where those routes have gone DARK and the fresh
        plan routes differently, the collective RE-FORMS on the new routes —
        it pays the new plan's latency phases again (counted as a retry) and
        the unserved payload fraction carries over. Working routes are never
        abandoned mid-transfer (no route flapping), so with no outage this is
        exactly `plan_transfer_time` of the starting plan.

        Returns ``(finish, nominal, n_retries, segments)``; `nominal` is the
        STARTING plan's closed-form cost (the stall baseline) and `segments`
        is ``[(plan, payload_fraction_served), ...]`` — the accounting split
        across the plans that actually carried the bytes (a single
        ``(plan, 1.0)`` entry when no re-form happened)."""
        plan = plan_fn(start)
        nominal = self.plan_allreduce_time(plan, nbytes)
        dyn = self.dynamics
        if dyn is None or not plan.phys_links:
            return start + nominal, nominal, 0, [(plan, 1.0)]

        def establish(p: "CommPlan"):
            lat = self.plan_allreduce_time(p, 0)
            phases = self.plan_n_latency_phases(p)
            total = (self.plan_allreduce_time(p, nbytes) - lat) * jitter
            return p.phys_links, lat, phases, total

        links, lat, phases, work_total = establish(plan)
        work = work_total
        frac_in = 1.0                    # payload fraction unserved at entry
        segments: List[Tuple["CommPlan", float]] = []
        check_at = plan.valid_until      # next plan refresh (<= any link edge)
        t = start + lat + self._dyn_latency(links, start, phases)
        n_retries = 0
        in_outage = False
        for _ in range(1_000_000):
            if t >= check_at:
                new = plan_fn(t)
                check_at = new.valid_until
                if (new.route_key() != plan.route_key()
                        and min(dyn.bw_factor(i, j, t)
                                for i, j in links) <= 0.0):
                    # current routes are dark and an alternative exists:
                    # re-form the collective on the fresh routes
                    frac_left = (work / work_total if work_total > 0 else 0.0)
                    segments.append((plan, frac_in - frac_left))
                    frac_in = frac_left
                    plan = new
                    links, lat, phases, work_total = establish(plan)
                    work = frac_left * work_total
                    n_retries += 1
                    in_outage = False
                    t += lat + self._dyn_latency(links, t, phases)
                    continue
            rho = min(dyn.bw_factor(i, j, t) for i, j in links)
            nxt = dyn.next_change(links, t)
            if math.isfinite(check_at):
                nxt = check_at if nxt is None else min(nxt, check_at)
            if rho <= 0.0:                   # dark with no alternative: wait
                if nxt is None:
                    raise RuntimeError(
                        f"transfer started at {start:.3f}s hit a permanent "
                        f"outage at {t:.3f}s (no future dynamics change)")
                t = nxt
                in_outage = True
                continue
            if in_outage:                    # recovered on the SAME routes
                in_outage = False
                n_retries += 1
                if dyn.retry_latency:
                    t += lat + self._dyn_latency(links, t, phases)
                    continue
            if work <= 0.0:
                break
            if nxt is None or work <= (nxt - t) * rho:
                t += work / rho
                break
            work -= (nxt - t) * rho
            t = nxt
        else:
            raise RuntimeError("routed_transfer_time did not converge "
                               "(pathological dynamics spec)")
        segments.append((plan, frac_in))
        return t, nominal, n_retries, segments

    # ------------------------------------------------------ per-link traffic

    def link_bytes(self, nbytes: int) -> np.ndarray:
        """(M, M) bytes each directed link carries for ONE collective of
        payload `nbytes` (ring: 2(M-1) chunks of nbytes/M per ring link;
        hierarchical: the full payload up and down each spoke)."""
        m = self.num_workers
        out = np.zeros((m, m), dtype=np.float64)
        if m <= 1:
            return out
        if self.collective == "ring":
            per_link = 2 * (m - 1) * nbytes / m
            for i, j in self._links():
                out[i, j] += per_link
        else:
            for i, j in self._links():
                out[i, j] += nbytes
        return out

    def link_seconds(self, nbytes: int) -> np.ndarray:
        """(M, M) busy-seconds per directed link for one collective (its own
        serialization + latency cost; bottleneck links show the largest)."""
        m = self.num_workers
        out = np.zeros((m, m), dtype=np.float64)
        if m <= 1:
            return out
        if self.collective == "ring":
            chunk = nbytes / m
            for i, j in self._links():
                out[i, j] += 2 * (m - 1) * (
                    self.latency_s[i, j] + chunk / self.bandwidth_Bps[i, j])
        else:
            for i, j in self._links():
                out[i, j] += self.latency_s[i, j] + nbytes / self.bandwidth_Bps[i, j]
        return out

    def link_bw_seconds(self, nbytes: int) -> np.ndarray:
        """(M, M) pure bandwidth busy-seconds per directed link for one
        collective (`link_seconds` minus the latency terms) — the fair-share
        scheduler's per-link weights on an unplanned (static) topology."""
        b = self.link_bytes(nbytes)
        out = np.zeros_like(b)
        nz = b > 0.0
        out[nz] = b[nz] / self.bandwidth_Bps[nz]
        return out

    # ------------------------------------------------------------- mutations

    def degrade_link(self, i: int, j: int, *, bandwidth_factor: float = 1.0,
                     extra_latency_s: float = 0.0,
                     symmetric: bool = True) -> "Topology":
        """A flaky/degraded link scenario: returns a new Topology with link
        (i, j) (and (j, i) when symmetric) slowed down."""
        lat = self.latency_s.copy()
        bw = self.bandwidth_Bps.copy()
        pairs = [(i, j), (j, i)] if symmetric else [(i, j)]
        for a, b in pairs:
            lat[a, b] += extra_latency_s
            bw[a, b] *= bandwidth_factor
        return dataclasses.replace(self, latency_s=lat, bandwidth_Bps=bw)

    def with_dynamics(self, dynamics: Optional[LinkDynamics]) -> "Topology":
        """Attach (or clear) a time-varying dynamics layer."""
        return dataclasses.replace(self, dynamics=dynamics)

    # ----------------------------------------------------------- constructors

    @classmethod
    def uniform(cls, num_workers: int, *, latency_s: float = 0.15,
                bandwidth_Bps: float = 1.25e9, step_time_s: float = 1.0,
                **kw) -> "Topology":
        m = num_workers
        lat = np.full((m, m), latency_s); np.fill_diagonal(lat, 0.0)
        bw = np.full((m, m), bandwidth_Bps); np.fill_diagonal(bw, np.inf)
        return cls(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                   **kw)


def as_topology(net) -> Topology:
    """Normalize NetworkModel | Topology -> Topology."""
    if isinstance(net, Topology):
        return net
    if isinstance(net, NetworkModel):
        return net.to_topology()
    raise TypeError(f"expected NetworkModel or Topology, got {type(net)}")


# ---------------------------------------------------------------------------
# routed communication plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Executable route set for ONE collective, computed against the link
    state at ``valid_from`` and usable until ``valid_until`` (the next
    dynamics edge; ``inf`` on a static topology).

    ``logical`` are the collective's logical links (ring neighbor pairs or
    spoke<->hub pairs over the PARTICIPANTS — regions whose links are not all
    dark); ``routes[i]`` is the chain of directed physical hops logical link i
    actually traverses (a single direct hop on a healthy network).

    ``multiroutes`` (optional) splits each logical link's payload across k
    edge-disjoint paths: ``multiroutes[i]`` is a tuple of ``(route, share)``
    pairs whose shares sum to 1. Empty () keeps every cost function on the
    single-path arithmetic byte-for-byte; when non-empty it fully describes
    the traffic (``routes`` stays the primary path for display)."""
    kind: str                                        # "ring" | "hierarchical"
    hub: int                                         # effective hub
    participants: Tuple[int, ...]
    logical: Tuple[Tuple[int, int], ...]
    routes: Tuple[Tuple[Tuple[int, int], ...], ...]
    valid_from: float
    valid_until: float
    multiroutes: Tuple[Tuple[Tuple[Tuple[Tuple[int, int], ...], float],
                             ...], ...] = ()

    def iter_routes(self):
        """(route, byte_share) pairs over all logical links — multiroute-aware
        (share = 1.0 on single-path plans)."""
        if self.multiroutes:
            for group in self.multiroutes:
                for route, share in group:
                    yield route, share
        else:
            for route in self.routes:
                yield route, 1.0

    @property
    def phys_links(self) -> Tuple[Tuple[int, int], ...]:
        """Unique directed physical hops the plan uses (first-use order)."""
        seen: List[Tuple[int, int]] = []
        for route, _ in self.iter_routes():
            for hop in route:
                if hop not in seen:
                    seen.append(hop)
        return tuple(seen)

    @property
    def is_multi_hop(self) -> bool:
        return any(len(route) > 1 for route in self.routes)

    @property
    def is_split(self) -> bool:
        """True when some logical link's payload rides more than one path."""
        return any(len(group) > 1 for group in self.multiroutes)

    def route_key(self):
        """Identity of the routing decision (reroute/election counting)."""
        if self.multiroutes:
            return (self.kind, self.hub, self.participants, self.routes,
                    self.multiroutes)
        return (self.kind, self.hub, self.participants, self.routes)


def _path_better(cand, cur) -> bool:
    """Deterministic path preference: lower cost, then fewer hops, then the
    lexicographically smallest node sequence."""
    c1, p1 = cand
    c2, p2 = cur
    return (c1, len(p1), p1) < (c2, len(p2), p2)


class RoutePlanner:
    """Deterministic network-aware route planner for one Topology.

    ``plan_at(t)`` is a PURE function of wall-time: the effective link state
    (static matrices x dynamics factors at t) determines participants, the
    effective hub, and min-cost multi-hop routes (per-hop cost = latency +
    ref_bytes / effective bandwidth; dark links are unusable). Every region
    replaying the shared dynamics clock therefore computes the identical plan
    with zero coordination messages — the same determinism contract as
    Algorithm 2 — and a resumed run re-derives the active plan from the
    serialized plan time alone.

    ``hub_failover=True`` re-elects the next-best-connected participant
    (largest total effective bandwidth; ties -> lowest index) as hub while the
    declared hub is dark, and restores the declared hub on recovery.

    ``multipath_k > 1`` splits every logical link's payload across up to k
    edge-disjoint min-cost paths (greedy: take the shortest path, remove its
    directed edges, repeat), with byte shares proportional to inverse path
    cost; the plan's ``multiroutes`` carries the split."""

    def __init__(self, topo: Topology, *, hub_failover: bool = False,
                 ref_bytes: int = 1, multipath_k: int = 1):
        self.topo = topo
        self.hub_failover = bool(hub_failover)
        self.ref_bytes = max(1, int(ref_bytes))
        if int(multipath_k) < 1:
            raise ValueError(f"multipath_k must be >= 1, got {multipath_k}")
        self.multipath_k = int(multipath_k)

    # ------------------------------------------------------------ link state

    def effective_bandwidth(self, t: float) -> np.ndarray:
        """(M, M) off-diagonal effective bandwidth at wall-time t (static
        matrix x dynamics bandwidth factor; 0.0 = dark link)."""
        topo = self.topo
        m = topo.num_workers
        dyn = topo.dynamics
        eff = np.zeros((m, m), dtype=np.float64)
        for i in range(m):
            for j in range(m):
                if i == j:
                    continue
                f = dyn.bw_factor(i, j, t) if dyn is not None else 1.0
                eff[i, j] = topo.bandwidth_Bps[i, j] * f
        return eff

    def dark_regions(self, t: float,
                     eff: Optional[np.ndarray] = None) -> Tuple[int, ...]:
        """Regions with EVERY incident directed link dark at t — they cannot
        participate in any collective and drop out instead of stalling it."""
        if eff is None:
            eff = self.effective_bandwidth(t)
        m = self.topo.num_workers
        out = []
        for r in range(m):
            inc = [eff[r, j] for j in range(m) if j != r]
            inc += [eff[j, r] for j in range(m) if j != r]
            if inc and max(inc) <= 0.0:
                out.append(r)
        return tuple(out)

    def elect_hub(self, t: float,
                  participants: Optional[Sequence[int]] = None,
                  eff: Optional[np.ndarray] = None) -> int:
        """Effective hub at t: the declared hub while it participates; when it
        is dark (links out) and failover is on, the next-best-connected
        participant (largest total effective bandwidth, ties -> lowest
        index)."""
        topo = self.topo
        if eff is None:
            eff = self.effective_bandwidth(t)
        if participants is None:
            dark = self.dark_regions(t, eff)
            participants = [r for r in range(topo.num_workers)
                            if r not in dark]
        declared = topo.hub
        if not self.hub_failover or declared in participants \
                or not participants:
            return declared

        def score(r: int) -> float:
            return float(sum(eff[r, j] + eff[j, r]
                             for j in participants if j != r))

        return max(participants, key=lambda r: (score(r), -r))

    # --------------------------------------------------------------- routing

    def _shortest_paths(self, eff: np.ndarray, nodes: Sequence[int]):
        """All-pairs deterministic min-cost paths over `nodes` (per-hop cost =
        latency + ref_bytes/effective bandwidth; dark hops excluded). Ties
        break on hop count then the node sequence, so every replica agrees."""
        topo = self.topo
        ref = float(self.ref_bytes)
        w = {}
        for a in nodes:
            for b in nodes:
                if a != b and eff[a, b] > 0.0:
                    w[(a, b)] = float(topo.latency_s[a, b]) + ref / eff[a, b]
        best = {a: {a: (0.0, (a,))} for a in nodes}
        edges = sorted(w)
        for _ in range(max(1, len(nodes))):
            changed = False
            for u, v in edges:
                for a in nodes:
                    row = best[a]
                    if u not in row:
                        continue
                    cu, pu = row[u]
                    if v in pu:                       # simple paths only
                        continue
                    cand = (cu + w[(u, v)], pu + (v,))
                    cur = row.get(v)
                    if cur is None or _path_better(cand, cur):
                        row[v] = cand
                        changed = True
            if not changed:
                break
        return best

    def _edge_weights(self, eff: np.ndarray, nodes: Sequence[int]):
        """Per-hop cost dict over `nodes` (dark hops excluded) — the same
        cost formula `_shortest_paths` uses."""
        topo = self.topo
        ref = float(self.ref_bytes)
        w = {}
        for a in nodes:
            for b in nodes:
                if a != b and eff[a, b] > 0.0:
                    w[(a, b)] = float(topo.latency_s[a, b]) + ref / eff[a, b]
        return w

    @staticmethod
    def _pair_shortest(w, nodes: Sequence[int], src: int, dst: int):
        """Deterministic min-cost simple path src->dst over the edge set `w`
        (same relaxation + tie-breaks as `_shortest_paths`); None if
        unreachable."""
        best = {src: (0.0, (src,))}
        edges = sorted(w)
        for _ in range(max(1, len(nodes))):
            changed = False
            for u, v in edges:
                if u not in best:
                    continue
                cu, pu = best[u]
                if v in pu:                           # simple paths only
                    continue
                cand = (cu + w[(u, v)], pu + (v,))
                cur = best.get(v)
                if cur is None or _path_better(cand, cur):
                    best[v] = cand
                    changed = True
            if not changed:
                break
        return best.get(dst)

    def _k_disjoint_paths(self, eff: np.ndarray, nodes: Sequence[int],
                          src: int, dst: int, k: int):
        """Up to k edge-disjoint min-cost paths src->dst (greedy shortest-path
        removal over DIRECTED edges). Returns [(cost, hop_tuple), ...] in
        discovery order; at least the primary path when src/dst connect."""
        w = self._edge_weights(eff, nodes)
        out = []
        for _ in range(max(1, int(k))):
            hit = self._pair_shortest(w, nodes, src, dst)
            if hit is None:
                break
            cost, seq = hit
            hops = tuple(zip(seq[:-1], seq[1:]))
            out.append((cost, hops))
            for hop in hops:
                del w[hop]
        return out

    def multiroutes_at(self, eff: np.ndarray, participants: Sequence[int],
                       logical: Sequence[Tuple[int, int]]):
        """Per logical link: ((route, share), ...) over up to ``multipath_k``
        edge-disjoint paths, shares proportional to inverse path cost
        (normalized to sum to 1). Logical links with a single usable path
        degrade to ((direct_route, 1.0),)."""
        groups = []
        for a, b in logical:
            paths = self._k_disjoint_paths(eff, participants, a, b,
                                           self.multipath_k)
            if not paths:                    # unreachable: direct hop (stalls)
                groups.append(((((a, b),), 1.0),))
                continue
            inv = [1.0 / max(c, 1e-12) for c, _ in paths]
            tot = sum(inv)
            groups.append(tuple((hops, iv / tot)
                                for (c, hops), iv in zip(paths, inv)))
        return tuple(groups)

    def plan_at(self, t: float) -> CommPlan:
        """The routed plan for one collective starting at wall-time t — a pure
        function of t (see class docstring)."""
        topo = self.topo
        m = topo.num_workers
        eff = self.effective_bandwidth(t)
        # dropping dark regions (and re-electing the hub) is the FAILOVER
        # behavior; plain routed mode re-routes over the full mesh and still
        # stalls on an unreachable region, like the static path
        dark = self.dark_regions(t, eff) if self.hub_failover else ()
        participants = tuple(r for r in range(m) if r not in dark)
        fallback = len(participants) < 2     # total blackout: stall like the
        if fallback:                         # static path rather than "free"
            participants = tuple(range(m))
        kind = topo.collective
        hub = topo.hub
        if kind == "hierarchical" and not fallback:
            hub = self.elect_hub(t, participants, eff)

        logical: List[Tuple[int, int]] = []
        if len(participants) > 1:
            if kind == "ring":
                for idx, a in enumerate(participants):
                    logical.append(
                        (a, participants[(idx + 1) % len(participants)]))
            else:
                for s in participants:
                    if s != hub:
                        logical.extend([(s, hub), (hub, s)])

        multiroutes = ()
        if fallback:
            routes = tuple(((a, b),) for a, b in logical)
        else:
            paths = self._shortest_paths(eff, participants)
            routes_list = []
            for a, b in logical:
                hit = paths.get(a, {}).get(b)
                if hit is None:              # unreachable: direct hop (stalls)
                    routes_list.append(((a, b),))
                else:
                    seq = hit[1]
                    routes_list.append(tuple(zip(seq[:-1], seq[1:])))
            routes = tuple(routes_list)
            if self.multipath_k > 1:
                multiroutes = self.multiroutes_at(eff, participants, logical)

        dyn = topo.dynamics
        valid_until = math.inf
        if dyn is not None:
            all_pairs = [(i, j) for i in range(m) for j in range(m) if i != j]
            nxt = dyn.next_change(all_pairs, t)
            if nxt is not None:
                valid_until = nxt
        return CommPlan(kind=kind, hub=hub, participants=participants,
                        logical=tuple(logical), routes=routes,
                        valid_from=float(t), valid_until=float(valid_until),
                        multiroutes=multiroutes)

    # -------------------------------------------------- point-to-point routes

    def point_route_at(self, t: float, src: int, dst: int):
        """Min-cost simple route src -> dst at wall-time t for a point-to-point
        message (a serving request/response, not a collective). Routes over
        every non-dark region; returns (cost, hop_tuple) with the same cost
        formula and tie-breaks the collective planner uses, or None when dst is
        unreachable from src at t."""
        if src == dst:
            return (0.0, ())
        m = self.topo.num_workers
        eff = self.effective_bandwidth(t)
        nodes = tuple(range(m))
        w = self._edge_weights(eff, nodes)
        hit = self._pair_shortest(w, nodes, src, dst)
        if hit is None:
            return None
        cost, seq = hit
        return cost, tuple(zip(seq[:-1], seq[1:]))

    def point_latency_at(self, t: float, src: int, dst: int,
                         nbytes: int) -> Optional[float]:
        """One-way delivery latency (seconds) of an `nbytes` message src -> dst
        at wall-time t over the min-cost route: per hop, propagation latency
        (+ dynamics extra latency) plus nbytes / effective bandwidth. None when
        unreachable."""
        if src == dst:
            return 0.0
        hit = self.point_route_at(t, src, dst)
        if hit is None:
            return None
        _, hops = hit
        topo = self.topo
        dyn = topo.dynamics
        eff = self.effective_bandwidth(t)
        total = 0.0
        for a, b in hops:
            if eff[a, b] <= 0.0:
                return None
            lat = float(topo.latency_s[a, b])
            if dyn is not None:
                lat += dyn.extra_latency_s(a, b, t)
            total += lat + float(nbytes) / eff[a, b]
        return total


# ---------------------------------------------------------------------------
# fair-share bandwidth scheduling (max-min water-filling over shared links)
# ---------------------------------------------------------------------------


def maxmin_rates(flow_links: Sequence[Dict[Tuple[int, int], float]],
                 caps: Dict[Tuple[int, int], float],
                 eps: float = 1e-12) -> List[float]:
    """Max-min fair progress rates for concurrent flows over shared links,
    by progressive water-filling.

    ``flow_links[f]`` maps each directed link flow f uses to its WEIGHT: the
    busy-seconds the flow puts on that link per unit of flow progress (the
    bottleneck link of a flow has weight 1, every other link <= 1).
    ``caps[l]`` is link l's current capacity factor (1.0 nominal, 0.0 dark).

    All flows' rates rise together until some link saturates
    (sum_f weight * rate = cap); flows crossing a saturated link freeze at
    the water level, the rest keep rising. The result is feasible (per-link
    weighted sum <= cap) and max-min optimal (every flow with positive rate
    is bottlenecked at a saturated link). Flows crossing a dark link get 0.
    """
    n = len(flow_links)
    rates = [0.0] * n
    active = set()
    for f in range(n):
        links = {l: w for l, w in flow_links[f].items() if w > 0.0}
        if links and all(caps.get(l, 1.0) > 0.0 for l in links):
            active.add(f)
    rem = {l: float(c) for l, c in caps.items()}
    for _ in range(n + 1):
        if not active:
            break
        wsum: Dict[Tuple[int, int], float] = {}
        for f in active:
            for l, w in flow_links[f].items():
                if w > 0.0:
                    wsum[l] = wsum.get(l, 0.0) + w
        delta = min(rem.get(l, math.inf) / s for l, s in wsum.items())
        delta = max(delta, 0.0)
        for f in active:
            rates[f] += delta
        sat = set()
        for l, s in wsum.items():
            left = rem.get(l, math.inf) - delta * s
            rem[l] = left
            if left <= eps * max(1.0, caps.get(l, 1.0)):
                sat.add(l)
        frozen = {f for f in active
                  if any(l in sat for l, w in flow_links[f].items()
                         if w > 0.0)}
        if not frozen:          # numerical corner: stop raising the level
            break
        active -= frozen
    return rates


@dataclasses.dataclass
class FairFlow:
    """One in-flight collective inside `FairShareSim` (mutable record).

    ``links`` maps each directed physical link to its weight (busy-seconds
    per unit progress, bottleneck = 1); ``work_*`` are bandwidth-seconds at
    unit rate; ``lat_left`` counts down the latency phases (the flow serves
    bytes only once it reaches 0). The ``acc_*``/``cur_*`` matrices carry the
    per-link accounting split across re-formed plans, exactly like
    `routed_transfer_time`'s segments."""
    id: int
    wire: int
    start: float
    jitter: float
    links: Dict[Tuple[int, int], float]
    lat: float
    phases: int
    work_total: float
    work_left: float
    nominal: float
    lat_left: float
    in_outage: bool = False
    retries: int = 0
    frac_in: float = 1.0
    acc_sec: Optional[np.ndarray] = None
    acc_bytes: Optional[np.ndarray] = None
    cur_sec: Optional[np.ndarray] = None
    cur_bytes: Optional[np.ndarray] = None

    def clone(self) -> "FairFlow":
        return dataclasses.replace(
            self, links=dict(self.links),
            acc_sec=self.acc_sec.copy(), acc_bytes=self.acc_bytes.copy(),
            cur_sec=self.cur_sec.copy(), cur_bytes=self.cur_bytes.copy())

    def reform(self, spec: Dict, t: float, topo: Topology) -> None:
        """Re-form the collective on a fresh plan's links: close the current
        accounting segment, carry the unserved payload fraction over, and pay
        the new plan's latency phases again (counted as a retry)."""
        frac_left = (self.work_left / self.work_total
                     if self.work_total > 0 else 0.0)
        self.acc_sec = self.acc_sec + self.cur_sec * (self.frac_in - frac_left)
        self.acc_bytes = (self.acc_bytes
                          + self.cur_bytes * (self.frac_in - frac_left))
        self.frac_in = frac_left
        self.links = dict(spec["links"])
        self.lat = float(spec["lat"])
        self.phases = int(spec["phases"])
        self.cur_sec = np.asarray(spec["sec"], dtype=np.float64)
        self.cur_bytes = np.asarray(spec["bytes"], dtype=np.float64)
        self.work_total = float(spec["work"]) * self.jitter
        self.work_left = frac_left * self.work_total
        self.retries += 1
        self.in_outage = False
        self.lat_left = self.lat + topo._dyn_latency(
            list(self.links), t, self.phases)


class FairShareSim:
    """Fluid-flow WAN scheduler: every in-flight collective shares link
    capacity via max-min water-filling (`maxmin_rates`), advancing bytes
    between network-change edges, latency expiries, and flow finishes. This
    subsumes the serial channel queue's `transfer_time`/`routed_transfer_time`
    integration: outage retries, mid-transfer re-planning, and per-link
    accounting all happen inside one event loop, but a transfer's completion
    now depends on who shares its bottleneck links.

    The sim's `advance` is associative over time (advancing to t1 then t2
    equals advancing straight to t2), so per-step and segment-fused loops see
    identical trajectories. `project()` computes each flow's finish time
    assuming no future arrivals (exact until the next `add_flow`, which
    re-projects everything) using the PURE `reform_fn(t, wire, False)` path
    so no planner side effects leak out of speculation."""

    _TOL = 1e-9

    def __init__(self, topo: Topology, reform_fn=None, finish_fn=None):
        self.topo = topo
        self._reform = reform_fn       # (t, wire, effectful) -> spec | None
        self._finish = finish_fn       # (flow, finish_time) -> None
        self.t = 0.0
        self.flows: List[FairFlow] = []

    # ------------------------------------------------------------- flow entry

    def add_flow(self, fid: int, spec: Dict, start: float, wire: int,
                 jitter: float) -> FairFlow:
        topo = self.topo
        m = topo.num_workers
        links = dict(spec["links"])
        lat = float(spec["lat"])
        phases = int(spec["phases"])
        work = float(spec["work"]) * float(jitter)
        flow = FairFlow(
            id=int(fid), wire=int(wire), start=float(start),
            jitter=float(jitter), links=links, lat=lat, phases=phases,
            work_total=work, work_left=work, nominal=float(spec["nominal"]),
            lat_left=lat + topo._dyn_latency(list(links), start, phases),
            acc_sec=np.zeros((m, m), dtype=np.float64),
            acc_bytes=np.zeros((m, m), dtype=np.float64),
            cur_sec=np.asarray(spec["sec"], dtype=np.float64),
            cur_bytes=np.asarray(spec["bytes"], dtype=np.float64))
        self.flows.append(flow)
        return flow

    # ------------------------------------------------------------ advancement

    def advance(self, to: float) -> None:
        """Advance real sim state to wall-time `to`, finalizing flows that
        finish on the way (engine accounting via `finish_fn`)."""
        self.t = self._run(self.flows, self.t, to, effectful=True,
                           finishes=None)

    def project(self) -> Dict[int, Tuple[float, float]]:
        """{flow_id: (start, finish)} for every in-flight flow, assuming no
        future arrivals. Pure: runs on clones with the speculative plan
        path."""
        finishes: Dict[int, Tuple[float, float]] = {}
        flows = [f.clone() for f in self.flows]
        self._run(flows, self.t, math.inf, effectful=False, finishes=finishes)
        return finishes

    def _run(self, flows: List[FairFlow], t: float, to: float,
             effectful: bool, finishes) -> float:
        topo = self.topo
        dyn = topo.dynamics
        for _ in range(1_000_000):
            if not flows:
                return to if math.isfinite(to) else t
            # finalize BEFORE the `to` gate: a flow whose work hits zero
            # exactly at `to` (diloco blocks until the projected finish, then
            # advances exactly there) must not stay pending forever — and a
            # finish always wins over a simultaneous outage edge
            done_now = [f for f in flows
                        if f.lat_left <= 0.0 and not f.in_outage
                        and f.work_left <= self._work_tol(f)]
            if done_now:
                for flow in done_now:
                    flows.remove(flow)
                    if finishes is not None:
                        finishes[flow.id] = (flow.start, t)
                    if effectful and self._finish is not None:
                        self._finish(flow, t)
                continue
            if t >= to:
                return t
            links_all = self._link_union(flows)
            caps = self._caps(links_all, t)
            changed = False
            for flow in flows:
                if flow.lat_left > 0.0:
                    continue
                dark = any(caps[l] <= 0.0 for l in flow.links)
                if dark:
                    flow.in_outage = True
                    spec = (self._reform(t, flow.wire, effectful)
                            if self._reform is not None else None)
                    if spec is not None and dict(spec["links"]) != flow.links:
                        # current links dark and the planner routes
                        # differently: re-form on the fresh routes
                        flow.reform(spec, t, topo)
                        changed = True
                elif flow.in_outage:        # recovered on the SAME links
                    flow.in_outage = False
                    flow.retries += 1
                    if dyn is not None and dyn.retry_latency:
                        flow.lat_left = flow.lat + topo._dyn_latency(
                            list(flow.links), t, flow.phases)
            if changed:                     # link sets moved: fresh capacities
                links_all = self._link_union(flows)
                caps = self._caps(links_all, t)
            serving = [f for f in flows
                       if f.lat_left <= 0.0 and not f.in_outage]
            rates = maxmin_rates([f.links for f in serving], caps)
            nxt = to
            if dyn is not None and links_all:
                change = dyn.next_change(links_all, t)
                if change is not None:
                    nxt = min(nxt, change)
            stuck = False
            for flow in flows:
                if flow.lat_left > 0.0:
                    if t + flow.lat_left <= t:    # float residue below one
                        flow.lat_left = 0.0       # ulp of t: expire in place
                        stuck = True
                    else:
                        nxt = min(nxt, t + flow.lat_left)
            for flow, x in zip(serving, rates):
                if x > 0.0:
                    if t + flow.work_left / x <= t:
                        flow.work_left = 0.0      # finalized next iteration
                        stuck = True
                    else:
                        nxt = min(nxt, t + flow.work_left / x)
            if stuck:
                continue
            if math.isinf(nxt):
                raise RuntimeError(
                    f"fair-share transfer hit a permanent outage at {t:.3f}s "
                    f"(no future dynamics change)")
            dt = nxt - t
            if dt > 0.0:
                for flow in flows:
                    if flow.lat_left > 0.0:
                        flow.lat_left = max(0.0, flow.lat_left - dt)
                for flow, x in zip(serving, rates):
                    if x > 0.0:
                        flow.work_left = max(0.0, flow.work_left - x * dt)
            t = nxt
        raise RuntimeError("fair-share advance did not converge "
                           "(pathological dynamics spec)")

    def _link_union(self, flows: List[FairFlow]):
        seen = set()
        out: List[Tuple[int, int]] = []
        for flow in flows:
            for l in flow.links:
                if l not in seen:
                    seen.add(l)
                    out.append(l)
        return out

    def _caps(self, links, t: float) -> Dict[Tuple[int, int], float]:
        dyn = self.topo.dynamics
        if dyn is None:
            return {l: 1.0 for l in links}
        return {l: dyn.bw_factor(l[0], l[1], t) for l in links}

    @classmethod
    def _work_tol(cls, flow: FairFlow) -> float:
        return cls._TOL * max(1.0, flow.work_total)

    # ---------------------------------------------------------- serialization

    def state_dict(self) -> Dict:
        return {
            "t": float(self.t),
            "flows": [{
                "id": int(f.id), "wire": int(f.wire), "start": float(f.start),
                "jitter": float(f.jitter), "lat": float(f.lat),
                "phases": int(f.phases), "work_total": float(f.work_total),
                "work_left": float(f.work_left), "nominal": float(f.nominal),
                "lat_left": float(f.lat_left), "in_outage": bool(f.in_outage),
                "retries": int(f.retries), "frac_in": float(f.frac_in),
                "links": [[int(i), int(j), float(u)]
                          for (i, j), u in sorted(f.links.items())],
                "acc_sec": f.acc_sec, "acc_bytes": f.acc_bytes,
                "cur_sec": f.cur_sec, "cur_bytes": f.cur_bytes,
            } for f in self.flows],
        }

    def load_state(self, st: Dict) -> None:
        self.t = float(st["t"])
        self.flows = []
        for row in st["flows"]:
            self.flows.append(FairFlow(
                id=int(row["id"]), wire=int(row["wire"]),
                start=float(row["start"]), jitter=float(row["jitter"]),
                links={(int(i), int(j)): float(u)
                       for i, j, u in row["links"]},
                lat=float(row["lat"]), phases=int(row["phases"]),
                work_total=float(row["work_total"]),
                work_left=float(row["work_left"]),
                nominal=float(row["nominal"]),
                lat_left=float(row["lat_left"]),
                in_outage=bool(row["in_outage"]),
                retries=int(row["retries"]), frac_in=float(row["frac_in"]),
                acc_sec=np.asarray(row["acc_sec"], dtype=np.float64),
                acc_bytes=np.asarray(row["acc_bytes"], dtype=np.float64),
                cur_sec=np.asarray(row["cur_sec"], dtype=np.float64),
                cur_bytes=np.asarray(row["cur_bytes"], dtype=np.float64)))


# ---------------------------------------------------------------------------
# named scenarios (multi-region sweeps)
# ---------------------------------------------------------------------------


def paper_symmetric(num_workers: int = 4, *, step_time_s: float = 1.0,
                    fragment_bytes: Optional[int] = None,
                    tau: int = 5) -> Topology:
    """The paper's setting as a Topology: symmetric mesh calibrated so one
    fragment all-reduce costs tau compute steps."""
    return as_topology(paper_network(num_workers, step_time_s=step_time_s,
                                     fragment_bytes=fragment_bytes, tau=tau))


def four_region_asymmetric(*, step_time_s: float = 1.0,
                           scale: float = 1.0) -> Topology:
    """Asymmetric 4-region mesh: us-east / us-west / eu-west / ap-northeast.
    Latencies are one-way WAN-scale; the transpacific links are the bandwidth
    bottleneck. `scale` multiplies all bandwidths (sweep knob)."""
    regions = ("us-east", "us-west", "eu-west", "ap-northeast")
    lat = np.array([
        [0.000, 0.035, 0.040, 0.085],
        [0.035, 0.000, 0.070, 0.055],
        [0.040, 0.070, 0.000, 0.120],
        [0.085, 0.055, 0.120, 0.000],
    ])
    gbps = np.array([
        [np.inf, 25.0, 10.0, 5.0],
        [25.0, np.inf, 8.0, 8.0],
        [10.0, 8.0, np.inf, 2.5],
        [5.0, 8.0, 2.5, np.inf],
    ])
    return Topology(latency_s=lat, bandwidth_Bps=gbps * 0.125e9 * scale,
                    step_time_s=step_time_s, regions=regions)


def hub_and_spoke(num_workers: int = 4, *, hub: int = 0,
                  spoke_latency_s: float = 0.05,
                  spoke_bandwidth_Bps: float = 1.25e9,
                  step_time_s: float = 1.0) -> Topology:
    """Hierarchical all-reduce through a hub region (e.g. regional DCs homed to
    a central one)."""
    m = num_workers
    lat = np.full((m, m), spoke_latency_s); np.fill_diagonal(lat, 0.0)
    bw = np.full((m, m), spoke_bandwidth_Bps); np.fill_diagonal(bw, np.inf)
    return Topology(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                    collective="hierarchical", hub=hub,
                    regions=tuple(["hub"] + [f"spoke{i}" for i in range(1, m)])
                    if hub == 0 else ())


def transpacific_flaky(*, step_time_s: float = 1.0,
                       bandwidth_factor: float = 0.25,
                       extra_latency_s: float = 0.08) -> Topology:
    """The asymmetric 4-region mesh with a degraded transpacific crossing
    (congestion / partial cable failure). The ring collective traverses
    ap-northeast <-> us-east (links (3,0)/(0,3)), so that is the pair that is
    degraded — flakiness on a link the collective never uses would be
    invisible."""
    return four_region_asymmetric(step_time_s=step_time_s).degrade_link(
        3, 0, bandwidth_factor=bandwidth_factor,
        extra_latency_s=extra_latency_s)


SCENARIOS: Dict[str, Callable[..., Topology]] = {
    "paper": paper_symmetric,
    "asym4": four_region_asymmetric,
    "hub_spoke": hub_and_spoke,
    "transpacific_flaky": transpacific_flaky,
}


def make_scenario(name: str, *, num_workers: int = 4,
                  step_time_s: float = 1.0, **kw) -> Topology:
    """Build a named scenario. Scenarios with a fixed region count (asym4,
    transpacific_flaky) require num_workers == 4."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown topology scenario {name!r}; "
                       f"options: {sorted(SCENARIOS)}")
    fn = SCENARIOS[name]
    if name in ("asym4", "transpacific_flaky"):
        if num_workers != 4:
            raise ValueError(f"{name} is a 4-region scenario "
                             f"(got num_workers={num_workers})")
        return fn(step_time_s=step_time_s, **kw)
    return fn(num_workers, step_time_s=step_time_s, **kw)


# ---------------------------------------------------------------------------
# generated N-region meshes
# ---------------------------------------------------------------------------


def _mesh_rng(seed: int, tag: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed & 0x7FFFFFFF,
                                                         tag]))


def _ring_mesh(n: int, rng: np.random.Generator, step_time_s: float) -> Topology:
    """Regions on a WAN ring: neighbor links drawn from realistic one-way
    latency / backbone bandwidth ranges; non-adjacent pairs priced as the
    multi-hop shortest path (sum latency, min bandwidth) so hierarchical
    collectives over the same mesh stay meaningful."""
    nb_lat = rng.uniform(0.02, 0.08, n)         # region i <-> i+1
    nb_bw = rng.uniform(5.0, 25.0, n) * 0.125e9
    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            fwd = [(k % n) for k in range(i, i + (j - i) % n)]
            bwd = [(k % n) for k in range(j, j + (i - j) % n)]
            hops = fwd if len(fwd) <= len(bwd) else bwd
            lat[i, j] = sum(nb_lat[h] for h in hops)
            bw[i, j] = min(nb_bw[h] for h in hops)
    return Topology(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                    regions=tuple(f"ring{i}" for i in range(n)))


def _hub_spoke_mesh(n: int, rng: np.random.Generator,
                    step_time_s: float) -> Topology:
    """Regional DCs homed to a central hub (hierarchical collective): seeded
    heterogeneous spoke links; spoke<->spoke goes through the hub."""
    sp_lat = rng.uniform(0.015, 0.09, n)
    sp_bw = rng.uniform(4.0, 40.0, n) * 0.125e9
    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            if i == 0 or j == 0:
                k = max(i, j)
                lat[i, j] = sp_lat[k]
                bw[i, j] = sp_bw[k]
            else:
                lat[i, j] = sp_lat[i] + sp_lat[j]
                bw[i, j] = min(sp_bw[i], sp_bw[j])
    return Topology(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                    collective="hierarchical", hub=0,
                    regions=tuple(["hub"] + [f"spoke{i}"
                                             for i in range(1, n)]))


def _continental_mesh(n: int, rng: np.random.Generator,
                      step_time_s: float) -> Topology:
    """Clustered continents: fast fat intra-continent links, slow thin
    inter-continent crossings (the submarine-cable pattern DiLoCoX-style
    decentralized clusters see). Continents get near-equal region counts."""
    n_cont = max(2, min(4, round(math.sqrt(n))))
    cont = np.array([i * n_cont // n for i in range(n)])
    cont_lat = rng.uniform(0.05, 0.14, (n_cont, n_cont))
    cont_lat = (cont_lat + cont_lat.T) / 2
    cont_bw = rng.uniform(1.5, 8.0, (n_cont, n_cont)) * 0.125e9
    cont_bw = (cont_bw + cont_bw.T) / 2
    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf)
    names = []
    tags = ("na", "eu", "ap", "sa")
    for i in range(n):
        names.append(f"{tags[cont[i]]}{i}")
        for j in range(n):
            if i == j:
                continue
            if cont[i] == cont[j]:
                lat[i, j] = rng.uniform(0.004, 0.02)
                bw[i, j] = rng.uniform(40.0, 100.0) * 0.125e9
            else:
                lat[i, j] = cont_lat[cont[i], cont[j]]
                bw[i, j] = cont_bw[cont[i], cont[j]]
    lat = (lat + lat.T) / 2
    finite = np.isfinite(bw)
    bws = np.where(finite, bw, 0.0)
    bw = np.where(finite, (bws + bws.T) / 2, np.inf)
    return Topology(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                    regions=tuple(names))


def _random_geo_mesh(n: int, rng: np.random.Generator,
                     step_time_s: float) -> Topology:
    """Regions at seeded random points on a unit globe-patch: latency scales
    with great-circle-ish distance, bandwidth decays with distance times a
    lognormal capacity draw (far pairs are thin AND slow)."""
    xy = rng.uniform(0.0, 1.0, (n, 2))
    cap = np.exp(rng.normal(0.0, 0.4, (n, n)))
    cap = (cap + cap.T) / 2
    d = np.linalg.norm(xy[:, None, :] - xy[None, :, :], axis=-1)
    lat = 0.005 + 0.12 * d
    np.fill_diagonal(lat, 0.0)
    with np.errstate(divide="ignore"):
        bw = 20.0 * 0.125e9 * cap / (0.35 + d)
    np.fill_diagonal(bw, np.inf)
    return Topology(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                    regions=tuple(f"geo{i}" for i in range(n)))


MESH_PROFILES: Dict[str, Callable[..., Topology]] = {
    "ring": _ring_mesh,
    "hub_spoke": _hub_spoke_mesh,
    "continental": _continental_mesh,
    "random_geo": _random_geo_mesh,
}

# PERMANENT per-profile RNG stream tags: a profile's tag may never change and
# a retired tag may never be reused, or every existing (profile, n, seed) mesh
# — and any run/sweep/checkpoint built on one — silently changes. New
# profiles take the next unused integer.
_PROFILE_STREAM_TAGS = {"continental": 0, "hub_spoke": 1, "random_geo": 2,
                        "ring": 3}


def generate_mesh(n_regions: int, profile: str = "random_geo", seed: int = 0,
                  *, step_time_s: float = 1.0) -> Topology:
    """Seeded N-region mesh for any N >= 1. Same (profile, n, seed) always
    yields the identical Topology (matrices drawn from a dedicated PCG64
    stream), so sweeps and resumed runs agree on the network."""
    if profile not in MESH_PROFILES:
        raise KeyError(f"unknown mesh profile {profile!r}; "
                       f"options: {sorted(MESH_PROFILES)}")
    if n_regions < 1:
        raise ValueError(f"n_regions must be >= 1, got {n_regions}")
    tag = _PROFILE_STREAM_TAGS[profile]
    return MESH_PROFILES[profile](n_regions, _mesh_rng(seed, tag),
                                  step_time_s)


# ---------------------------------------------------------------------------
# dynamics spec parsing ("diurnal:depth=0.6,hub_failure:start=40:dur=24,...")
# ---------------------------------------------------------------------------


DYNAMICS_KINDS = ("diurnal", "hub_failure", "flaky", "degrade", "jitter")


def _hub_of(topo: Topology) -> int:
    """Hub region for hub_failure: the declared hub for hierarchical
    collectives, else the best-connected region (largest total egress)."""
    if topo.collective == "hierarchical":
        return topo.hub
    bw = np.where(np.isfinite(topo.bandwidth_Bps), topo.bandwidth_Bps, 0.0)
    return int(np.argmax(bw.sum(axis=1)))


def _slowest_link(topo: Topology) -> Tuple[int, int]:
    """Thinnest link the collective actually traverses (degrading an unused
    link would be invisible)."""
    links = topo._links()
    return min(links, key=lambda ij: (topo.bandwidth_Bps[ij], ij))


def parse_dynamics(spec: str, topo: Topology, *, seed: int = 0) -> LinkDynamics:
    """Parse a comma-separated dynamics spec into one LinkDynamics. Each entry
    is ``kind[:key=val]*``; times are simulated seconds. Kinds:

      diurnal      period (240*T_c), depth (0.5), bins (24), stagger (1.0)
                   — bandwidth trough once per period; stagger spreads region
                   phases across the period (1.0 = evenly spaced timezones)
      hub_failure  start (40*T_c), dur (24*T_c), hub (auto), factor (0.0)
                   — every link touching the hub degrades/goes dark
      flaky        n (4), dur (8*T_c), factor (0.2), start (10*T_c),
                   span (12*n*dur), link ("i-j", default: thinnest used link)
                   — n seeded random degradation windows on one link
      degrade      start, dur, link ("i-j"), factor (0.3), lat (0.0)
                   — one explicit degradation window
      jitter       frac (0.05) — seeded per-transfer bandwidth jitter
    """
    tc = topo.step_time_s
    m = topo.num_workers
    diurnal: Optional[DiurnalProfile] = None
    events: List[LinkEvent] = []
    jitter_frac = 0.0

    def _link_kw(kw) -> Tuple[int, int]:
        if "link" in kw:
            i, j = kw["link"].split("-")
            return int(i), int(j)
        return _slowest_link(topo)

    for part in filter(None, (p.strip() for p in spec.split(","))):
        bits = part.split(":")
        kind, kw = bits[0], dict(b.split("=", 1) for b in bits[1:])
        if kind == "diurnal":
            period = float(kw.get("period", 240 * tc))
            stagger = float(kw.get("stagger", 1.0))
            phases = tuple(stagger * period * i / m for i in range(m))
            diurnal = DiurnalProfile(
                period_s=period,
                trough_depth=float(kw.get("depth", 0.5)),
                n_bins=int(kw.get("bins", 24)),
                phase_s=phases if stagger else ())
        elif kind == "hub_failure":
            hub = int(kw["hub"]) if "hub" in kw else _hub_of(topo)
            start = float(kw.get("start", 40 * tc))
            end = start + float(kw.get("dur", 24 * tc))
            factor = float(kw.get("factor", 0.0))
            for j in range(m):
                if j != hub:
                    events.append(LinkEvent(start, end, hub, j,
                                            bandwidth_factor=factor))
        elif kind == "flaky":
            i, j = _link_kw(kw)
            n = int(kw.get("n", 4))
            dur = float(kw.get("dur", 8 * tc))
            start = float(kw.get("start", 10 * tc))
            span = float(kw.get("span", 12 * n * dur))
            rng = np.random.default_rng(
                np.random.SeedSequence([seed & 0x7FFFFFFF, 0xF1A]))
            for s in sorted(rng.uniform(start, start + span, n)):
                events.append(LinkEvent(float(s), float(s) + dur, i, j,
                                        bandwidth_factor=float(
                                            kw.get("factor", 0.2))))
        elif kind == "degrade":
            i, j = _link_kw(kw)
            start = float(kw.get("start", 0.0))
            events.append(LinkEvent(start, start + float(kw.get("dur", 24 * tc)),
                                    i, j,
                                    bandwidth_factor=float(kw.get("factor", 0.3)),
                                    extra_latency_s=float(kw.get("lat", 0.0))))
        elif kind == "jitter":
            jitter_frac = float(kw.get("frac", 0.05))
        else:
            raise KeyError(f"unknown dynamics kind {kind!r}; "
                           f"options: {DYNAMICS_KINDS}")
    return LinkDynamics(diurnal=diurnal, events=tuple(events),
                        jitter_frac=jitter_frac, seed=seed)


def apply_dynamics(topo: Topology, spec: "str | LinkDynamics | None", *,
                   seed: int = 0) -> Topology:
    """Attach dynamics to a Topology: a spec string (parsed), a ready
    LinkDynamics, or None (no-op)."""
    if spec is None:
        return topo
    if isinstance(spec, LinkDynamics):
        return topo.with_dynamics(spec)
    return topo.with_dynamics(parse_dynamics(spec, topo, seed=seed))


# auto-calibration target: bandwidth-seconds of one mean-fragment collective,
# in compute steps (latency is left untouched, so the calibrated transfers
# are bandwidth-dominated by construction — asserted in calibrate_bw_scale)
CALIB_BW_STEPS = 6.0


def calibrate_bw_scale(net: Topology, frag_bytes: int, *,
                       target_steps: float = CALIB_BW_STEPS) -> float:
    """paper_network-style auto-calibration: the bandwidth multiplier that
    makes one `frag_bytes` collective spend `target_steps * T_c` seconds in
    its BANDWIDTH phase on this topology. The bandwidth phase is measured on
    a latency-free copy (on a heterogeneous mesh the collective's bottleneck
    link CHANGES with the scale, so subtracting the latency phases from the
    full cost would calibrate against the wrong link). Latencies are
    untouched, so the calibrated transfer is bandwidth-dominated — asserted,
    because a latency-dominated transfer would hide any link dynamics under
    test. Used by spec-driven experiments (`NetworkSpec.bw_scale="auto"`)
    and the scenario sweep."""
    lat_free = dataclasses.replace(net,
                                   latency_s=np.zeros_like(net.latency_s))
    bw_seconds = lat_free.allreduce_time(frag_bytes)
    if bw_seconds <= 0.0:
        raise AssertionError(
            f"calibration: topology has no bandwidth cost "
            f"({net.num_workers} regions)")
    target = target_steps * net.step_time_s
    lat = net.allreduce_time(0)
    assert target > lat, (
        f"calibrated transfer would be latency-dominated: bandwidth target "
        f"{target:.3f}s <= latency phases {lat:.3f}s")
    return bw_seconds / target
