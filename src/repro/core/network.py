"""WAN network + compute model for the cross-region simulation.

Two levels of fidelity:

``NetworkModel`` — the original single-link symmetric model (kept for
back-compat and closed-form tests): one latency, one bandwidth, ring
all-reduce over M identical links.

``Topology`` — the heterogeneous simulator the protocol engine actually runs
on: a per-region-pair latency/bandwidth matrix, a choice of collective
algorithm (ring vs hub-and-spoke hierarchical), a bounded number of concurrent
WAN collectives (contention), and per-link traffic accounting. Fragment
delivery times are derived from simulated transfer *completion* (initiation
time + queueing + per-link bottleneck cost), not a fixed ``t + tau``.

Both expose the same cost API used by the engines and Eq. 9:
  * ``t_s(bytes)``   — one fragment all-reduce (wall seconds)
  * ``t_c``          — per-local-step compute time
  * ``tau_steps(b)`` — overlap depth implied by T_s/T_c

Scenario constructors (``SCENARIOS``) cover the sweeps the scalar model could
not express: asymmetric 4-region meshes, hub-and-spoke trees, transpacific
bottlenecks, and flaky (degraded) links.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class NetworkModel:
    num_workers: int = 4
    latency_s: float = 0.15          # WAN RTT-scale latency per all-reduce phase
    bandwidth_Bps: float = 1.25e9    # 10 Gb/s inter-DC
    step_time_s: float = 1.0         # T_c: one local training step

    def allreduce_time(self, nbytes: int) -> float:
        """Ring all-reduce: 2(M-1)/M of the payload crosses each link, plus
        2(M-1) latency hops."""
        m = self.num_workers
        if m <= 1:
            return 0.0
        return 2 * (m - 1) * self.latency_s + (2 * (m - 1) / m) * nbytes / self.bandwidth_Bps

    @property
    def t_c(self) -> float:
        return self.step_time_s

    def t_s(self, nbytes: int) -> float:
        return self.allreduce_time(nbytes)

    def tau_steps(self, nbytes: int) -> int:
        """Overlap depth implied by the network: steps of compute that fit inside
        one fragment all-reduce."""
        return max(1, math.ceil(self.t_s(nbytes) / self.t_c))

    def to_topology(self) -> "Topology":
        """Equivalent symmetric Topology (identical allreduce_time)."""
        return Topology.uniform(self.num_workers, latency_s=self.latency_s,
                                bandwidth_Bps=self.bandwidth_Bps,
                                step_time_s=self.step_time_s)


def paper_network(num_workers: int = 4, *, step_time_s: float = 1.0,
                  fragment_bytes: int | None = None,
                  tau: int = 5) -> NetworkModel:
    """Network calibrated so that T_s = tau * T_c for the given fragment size,
    matching the paper's tau=5, N=8 (gamma=0.4, H=100) setting."""
    if fragment_bytes is None or num_workers <= 1:
        return NetworkModel(num_workers=num_workers, step_time_s=step_time_s)
    m = num_workers
    target_ts = tau * step_time_s
    lat = 0.1 * target_ts / (2 * (m - 1))          # 10% latency, 90% bandwidth
    bw = (2 * (m - 1) / m) * fragment_bytes / (0.9 * target_ts)
    return NetworkModel(num_workers=m, latency_s=lat, bandwidth_Bps=bw,
                        step_time_s=step_time_s)


# ---------------------------------------------------------------------------
# heterogeneous topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """Immutable description + cost model of a heterogeneous inter-region WAN.

    latency_s / bandwidth_Bps are dense (M, M) matrices over *directed* links
    (diag ignored). ``collective`` picks the all-reduce algorithm:
      * "ring"         — fixed ring 0 -> 1 -> ... -> M-1 -> 0; 2(M-1) phases of
                         nbytes/M chunks, each phase paced by the slowest link.
      * "hierarchical" — reduce-to-hub then broadcast; both halves paced by the
                         slowest spoke link (concurrent spoke transfers).
    ``concurrent_collectives`` bounds how many fragment all-reduces the WAN
    carries at once; the engine queues the excess (contention -> later
    delivery). Mutable transfer-schedule state lives in the engine, not here.
    """
    latency_s: np.ndarray
    bandwidth_Bps: np.ndarray
    step_time_s: float = 1.0
    regions: Tuple[str, ...] = ()
    collective: str = "ring"
    hub: int = 0
    concurrent_collectives: int = 1

    def __post_init__(self):
        lat = np.asarray(self.latency_s, dtype=np.float64)
        bw = np.asarray(self.bandwidth_Bps, dtype=np.float64)
        if lat.shape != bw.shape or lat.ndim != 2 or lat.shape[0] != lat.shape[1]:
            raise ValueError(f"latency/bandwidth must be square & congruent, "
                             f"got {lat.shape} vs {bw.shape}")
        if self.collective not in ("ring", "hierarchical"):
            raise ValueError(f"unknown collective {self.collective!r}")
        object.__setattr__(self, "latency_s", lat)
        object.__setattr__(self, "bandwidth_Bps", bw)
        if not self.regions:
            object.__setattr__(
                self, "regions",
                tuple(f"region{i}" for i in range(lat.shape[0])))

    # ------------------------------------------------------------- properties

    @property
    def num_workers(self) -> int:
        return self.latency_s.shape[0]

    @property
    def t_c(self) -> float:
        return self.step_time_s

    @property
    def is_symmetric(self) -> bool:
        links = self._links()
        lats = [self.latency_s[i, j] for i, j in links]
        bws = [self.bandwidth_Bps[i, j] for i, j in links]
        return (np.allclose(lats, lats[0]) and np.allclose(bws, bws[0])
                if links else True)

    # ----------------------------------------------------------- cost models

    def _links(self):
        """Directed links the collective uses."""
        m = self.num_workers
        if m <= 1:
            return []
        if self.collective == "ring":
            return [(i, (i + 1) % m) for i in range(m)]
        h = self.hub
        out = []
        for i in range(m):
            if i != h:
                out.extend([(i, h), (h, i)])
        return out

    def allreduce_time(self, nbytes: int) -> float:
        m = self.num_workers
        if m <= 1:
            return 0.0
        if self.collective == "ring":
            chunk = nbytes / m
            phase = max(self.latency_s[i, j] + chunk / self.bandwidth_Bps[i, j]
                        for i, j in self._links())
            return 2 * (m - 1) * phase
        h = self.hub
        gather = max(self.latency_s[i, h] + nbytes / self.bandwidth_Bps[i, h]
                     for i in range(m) if i != h)
        bcast = max(self.latency_s[h, i] + nbytes / self.bandwidth_Bps[h, i]
                    for i in range(m) if i != h)
        return gather + bcast

    def t_s(self, nbytes: int) -> float:
        return self.allreduce_time(nbytes)

    def tau_steps(self, nbytes: int) -> int:
        return max(1, math.ceil(self.t_s(nbytes) / self.t_c))

    # ------------------------------------------------------ per-link traffic

    def link_bytes(self, nbytes: int) -> np.ndarray:
        """(M, M) bytes each directed link carries for ONE collective of
        payload `nbytes` (ring: 2(M-1) chunks of nbytes/M per ring link;
        hierarchical: the full payload up and down each spoke)."""
        m = self.num_workers
        out = np.zeros((m, m), dtype=np.float64)
        if m <= 1:
            return out
        if self.collective == "ring":
            per_link = 2 * (m - 1) * nbytes / m
            for i, j in self._links():
                out[i, j] += per_link
        else:
            for i, j in self._links():
                out[i, j] += nbytes
        return out

    def link_seconds(self, nbytes: int) -> np.ndarray:
        """(M, M) busy-seconds per directed link for one collective (its own
        serialization + latency cost; bottleneck links show the largest)."""
        m = self.num_workers
        out = np.zeros((m, m), dtype=np.float64)
        if m <= 1:
            return out
        if self.collective == "ring":
            chunk = nbytes / m
            for i, j in self._links():
                out[i, j] += 2 * (m - 1) * (
                    self.latency_s[i, j] + chunk / self.bandwidth_Bps[i, j])
        else:
            for i, j in self._links():
                out[i, j] += self.latency_s[i, j] + nbytes / self.bandwidth_Bps[i, j]
        return out

    # ------------------------------------------------------------- mutations

    def degrade_link(self, i: int, j: int, *, bandwidth_factor: float = 1.0,
                     extra_latency_s: float = 0.0,
                     symmetric: bool = True) -> "Topology":
        """A flaky/degraded link scenario: returns a new Topology with link
        (i, j) (and (j, i) when symmetric) slowed down."""
        lat = self.latency_s.copy()
        bw = self.bandwidth_Bps.copy()
        pairs = [(i, j), (j, i)] if symmetric else [(i, j)]
        for a, b in pairs:
            lat[a, b] += extra_latency_s
            bw[a, b] *= bandwidth_factor
        return dataclasses.replace(self, latency_s=lat, bandwidth_Bps=bw)

    # ----------------------------------------------------------- constructors

    @classmethod
    def uniform(cls, num_workers: int, *, latency_s: float = 0.15,
                bandwidth_Bps: float = 1.25e9, step_time_s: float = 1.0,
                **kw) -> "Topology":
        m = num_workers
        lat = np.full((m, m), latency_s); np.fill_diagonal(lat, 0.0)
        bw = np.full((m, m), bandwidth_Bps); np.fill_diagonal(bw, np.inf)
        return cls(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                   **kw)


def as_topology(net) -> Topology:
    """Normalize NetworkModel | Topology -> Topology."""
    if isinstance(net, Topology):
        return net
    if isinstance(net, NetworkModel):
        return net.to_topology()
    raise TypeError(f"expected NetworkModel or Topology, got {type(net)}")


# ---------------------------------------------------------------------------
# named scenarios (multi-region sweeps)
# ---------------------------------------------------------------------------


def paper_symmetric(num_workers: int = 4, *, step_time_s: float = 1.0,
                    fragment_bytes: Optional[int] = None,
                    tau: int = 5) -> Topology:
    """The paper's setting as a Topology: symmetric mesh calibrated so one
    fragment all-reduce costs tau compute steps."""
    return as_topology(paper_network(num_workers, step_time_s=step_time_s,
                                     fragment_bytes=fragment_bytes, tau=tau))


def four_region_asymmetric(*, step_time_s: float = 1.0,
                           scale: float = 1.0) -> Topology:
    """Asymmetric 4-region mesh: us-east / us-west / eu-west / ap-northeast.
    Latencies are one-way WAN-scale; the transpacific links are the bandwidth
    bottleneck. `scale` multiplies all bandwidths (sweep knob)."""
    regions = ("us-east", "us-west", "eu-west", "ap-northeast")
    lat = np.array([
        [0.000, 0.035, 0.040, 0.085],
        [0.035, 0.000, 0.070, 0.055],
        [0.040, 0.070, 0.000, 0.120],
        [0.085, 0.055, 0.120, 0.000],
    ])
    gbps = np.array([
        [np.inf, 25.0, 10.0, 5.0],
        [25.0, np.inf, 8.0, 8.0],
        [10.0, 8.0, np.inf, 2.5],
        [5.0, 8.0, 2.5, np.inf],
    ])
    return Topology(latency_s=lat, bandwidth_Bps=gbps * 0.125e9 * scale,
                    step_time_s=step_time_s, regions=regions)


def hub_and_spoke(num_workers: int = 4, *, hub: int = 0,
                  spoke_latency_s: float = 0.05,
                  spoke_bandwidth_Bps: float = 1.25e9,
                  step_time_s: float = 1.0) -> Topology:
    """Hierarchical all-reduce through a hub region (e.g. regional DCs homed to
    a central one)."""
    m = num_workers
    lat = np.full((m, m), spoke_latency_s); np.fill_diagonal(lat, 0.0)
    bw = np.full((m, m), spoke_bandwidth_Bps); np.fill_diagonal(bw, np.inf)
    return Topology(latency_s=lat, bandwidth_Bps=bw, step_time_s=step_time_s,
                    collective="hierarchical", hub=hub,
                    regions=tuple(["hub"] + [f"spoke{i}" for i in range(1, m)])
                    if hub == 0 else ())


def transpacific_flaky(*, step_time_s: float = 1.0,
                       bandwidth_factor: float = 0.25,
                       extra_latency_s: float = 0.08) -> Topology:
    """The asymmetric 4-region mesh with a degraded transpacific crossing
    (congestion / partial cable failure). The ring collective traverses
    ap-northeast <-> us-east (links (3,0)/(0,3)), so that is the pair that is
    degraded — flakiness on a link the collective never uses would be
    invisible."""
    return four_region_asymmetric(step_time_s=step_time_s).degrade_link(
        3, 0, bandwidth_factor=bandwidth_factor,
        extra_latency_s=extra_latency_s)


SCENARIOS: Dict[str, Callable[..., Topology]] = {
    "paper": paper_symmetric,
    "asym4": four_region_asymmetric,
    "hub_spoke": hub_and_spoke,
    "transpacific_flaky": transpacific_flaky,
}


def make_scenario(name: str, *, num_workers: int = 4,
                  step_time_s: float = 1.0, **kw) -> Topology:
    """Build a named scenario. Scenarios with a fixed region count (asym4,
    transpacific_flaky) require num_workers == 4."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown topology scenario {name!r}; "
                       f"options: {sorted(SCENARIOS)}")
    fn = SCENARIOS[name]
    if name in ("asym4", "transpacific_flaky"):
        if num_workers != 4:
            raise ValueError(f"{name} is a 4-region scenario "
                             f"(got num_workers={num_workers})")
        return fn(step_time_s=step_time_s, **kw)
    return fn(num_workers, step_time_s=step_time_s, **kw)
