"""Outer optimizer (DiLoCo family): SGD with Nesterov momentum on pseudo-gradients.

The pseudo-gradient Delta = (1/M) sum_m (theta^m - theta^g_prev) points in the
descent direction already (it is the average local progress), so the update is
ascent along Delta:

    m      <- mu * m + Delta
    theta  <- theta + lr * (Delta + mu * m)        (Nesterov)

State is kept per-fragment-leaf as a full-tree momentum pytree; fragment updates
touch only the fragment's rows (the Fragmenter hands us sub-trees).

This per-leaf loop reads theta and momentum twice each per output (2 leaves x
2 passes); under `fused_updates` the engine replaces it with ONE fused Pallas
dispatch over the flat fragment plane (kernels/outer_update.outer_nesterov —
same arithmetic, one read of each operand, one write of each output).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(params_like):
    return jax.tree.map(
        lambda a: None if a is None else jnp.zeros_like(a), params_like,
        is_leaf=lambda x: x is None)


def nesterov_update(theta, momentum, delta, *, lr: float, mu: float):
    """Apply one outer step on a (fragment) pytree. None leaves pass through."""

    def upd(t, m, d):
        if t is None:
            return None, None
        m_new = mu * m + d
        t_new = t + lr * (d + mu * m_new)
        return t_new, m_new

    flat_t, treedef = jax.tree.flatten(theta, is_leaf=lambda x: x is None)
    flat_m = treedef.flatten_up_to(momentum)
    flat_d = treedef.flatten_up_to(delta)
    out = [upd(t, m, d) for t, m, d in zip(flat_t, flat_m, flat_d)]
    theta_new = treedef.unflatten([o[0] for o in out])
    mom_new = treedef.unflatten([o[1] for o in out])
    return theta_new, mom_new
