"""Pluggable sync-method strategy registry (the DiLoCo family).

Every cross-region synchronization method — DiLoCo, Streaming DiLoCo, CoCoDC,
plain local SGD — is one registered `SyncMethod` strategy object instead of an
``if method == ...`` branch scattered through `core/protocol.py` and
`core/engine_state.py`. A strategy exposes exactly the event hooks the engine
dispatches on:

  host side (scheduling — the strategy drives a `ProtocolEngine`):
    * `next_event_step(eng, t)`   — initiation cadence: the next step with a
      protocol action (None = the host loop may fuse every remaining step)
    * `on_step_end(eng, t, ...)`  — the per-step protocol action itself
      (blocking round, delivery processing, fragment initiation)

  device side (pure, traced under jit by `engine_state.make_engine_fns`):
    * `apply_delivery(...)`       — round blending: how a delivered global
      fragment is folded back into worker-local state (Eq. 3 blending,
      Algorithm-1 delay compensation, ...)

  state shape flags:
    * `overlapped`      — parks fragment payloads in the in-flight buffers
    * `keeps_snapshot`  — records initiation-time local state (Algorithm 1)
    * `supports_adaptive_resync` — Eq. 9/10 re-derivation applies
    * `fused_delivery`  — kernels/outer_update deliver mode ("blend" |
      "compensate"; empty = method cannot run with `fused_updates=on`) plus
      `fused_delivery_kwargs` for the mode's scalar operands

New methods in the family (e.g. a CO2-style full-overlap local SGD,
arXiv:2401.16265) register with `@register_method` and become selectable by
name everywhere a method string is accepted (`ExperimentSpec`, CLI flags,
`ProtocolEngine`) — no core edits. The four built-ins reproduce the previous
hard-coded branches BITWISE (pinned by tests/test_engine_state.py and
tests/test_trainer_segments.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import adaptive as adaptive_lib
from repro.core import delay_comp as dc_lib

_REGISTRY: Dict[str, "SyncMethod"] = {}


def register_method(cls: type) -> type:
    """Class decorator: instantiate `cls` and register it under `cls().name`.
    Re-registering a name replaces the previous strategy (latest wins), so a
    downstream experiment can override a built-in."""
    inst = cls()
    if not getattr(inst, "name", ""):
        raise ValueError(f"{cls.__name__} must define a non-empty `name`")
    _REGISTRY[inst.name] = inst
    return cls


def unregister_method(name: str) -> None:
    """Remove a registered strategy (primarily for test cleanup)."""
    _REGISTRY.pop(name, None)


def registered_methods() -> Tuple[str, ...]:
    """Sorted names of every registered sync method."""
    return tuple(sorted(_REGISTRY))


def get_method(name: str) -> "SyncMethod":
    """Registry lookup; unknown names raise listing what IS registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown sync method {name!r}; registered methods: "
            f"{', '.join(registered_methods())} "
            f"(add one with @repro.core.methods.register_method)") from None


class SyncMethod:
    """Base strategy: local-SGD semantics (no cross-region traffic). Subclass
    and override the hooks; the `eng` argument of the host hooks is the
    `ProtocolEngine` driving the run (its `_initiate`/`_process_deliveries`/
    `_schedule_transfer` helpers are the supported extension surface)."""

    name: str = ""
    overlapped: bool = False
    keeps_snapshot: bool = False
    supports_adaptive_resync: bool = False
    # kernels/outer_update deliver mode under `fused_updates` ("" = the
    # engine rejects fused mode for this method if it is overlapped)
    fused_delivery: str = ""

    # ------------------------------------------------------------ host hooks

    def next_event_step(self, eng, t: int) -> Optional[int]:
        """Smallest step t' >= t with a protocol action; None = never."""
        return None

    def on_step_end(self, eng, t: int, params_stack):
        """Protocol action after inner step t (wall-clock already ticked by
        the engine). Returns the possibly-updated params_stack."""
        return params_stack

    # ---------------------------------------------------------- device hook

    def apply_delivery(self, ccfg, dc_impl, *, local_now, snapshot, g_b,
                       t, t_init):
        """Fold a delivered global fragment `g_b` (broadcast over the worker
        axis) into the workers' current local fragment `local_now`. Pure —
        traced under jit by `engine_state.make_engine_fns`."""
        raise NotImplementedError(
            f"method {self.name!r} parks no fragments in flight")

    def fused_delivery_kwargs(self, ccfg, *, t, t_init) -> dict:
        """Scalar operands for kernels/outer_update `fused_deliver` under
        this method's `fused_delivery` mode. Values may be traced (e.g. the
        ACTUAL overlap depth tau = t - t_init)."""
        return {}


@register_method
class LocalSGD(SyncMethod):
    """No synchronization at all — the isolated-datacenter baseline."""
    name = "local"


@register_method
class DiLoCo(SyncMethod):
    """Blocking DiLoCo: full-model all-reduce + outer update every H steps;
    all workers restart from the new consensus (wall-clock pays the WAN)."""
    name = "diloco"

    def next_event_step(self, eng, t: int) -> int:
        return t + (eng.H - 1 - t) % eng.H

    def on_step_end(self, eng, t: int, params_stack):
        if (t + 1) % eng.H == 0:
            finish, _ = eng._schedule_transfer(eng.frag.total_bytes)
            eng.wall_clock = max(eng.wall_clock, finish)   # BLOCKING
            eng.state, params_stack = eng._fns.diloco_round(
                eng.state, params_stack)
        return params_stack


class OverlappedMethod(SyncMethod):
    """Shared machinery for methods that overlap fragment all-reduces with
    computation: plan refresh, due-delivery processing, then the method's own
    initiation rule. Subclasses define `sync_interval` + `initiate_due` (and
    optionally `extra_event_step`/`after_deliveries`)."""
    overlapped = True

    def sync_interval(self, eng) -> int:
        raise NotImplementedError

    def extra_event_step(self, eng, t: int) -> Optional[int]:
        """An additional host-side event boundary (e.g. the outer-round edge
        where Eq. 9 re-derivation runs); None = none."""
        return None

    def initiate_due(self, eng, t: int, params_stack) -> None:
        raise NotImplementedError

    def after_deliveries(self, eng, t: int) -> None:
        pass

    def next_event_step(self, eng, t: int) -> int:
        h = self.sync_interval(eng)
        nxt = t if t % h == 0 else t + h - t % h
        extra = self.extra_event_step(eng, t)
        if extra is not None:
            nxt = min(nxt, extra)
        for ev in eng.pending:
            nxt = min(nxt, max(t, ev.deliver_at))
        return nxt

    def on_step_end(self, eng, t: int, params_stack):
        if eng._planner is not None:
            # roll the plan state to the CURRENT wall-clock before any device
            # decision this step (a queued future transfer may have pulled
            # the cached plan ahead of simulated time — availability and
            # pricing must reflect now, not the future)
            eng._active_plan(eng.wall_clock)
        params_stack = eng._process_deliveries(t, params_stack)
        self.initiate_due(eng, t, params_stack)
        self.after_deliveries(eng, t)
        return params_stack


@register_method
class StreamingDiLoCo(OverlappedMethod):
    """Streaming DiLoCo: fixed round-robin fragment schedule (one fragment
    every H/K steps), Eq. 3 blending on delivery."""
    name = "streaming"
    fused_delivery = "blend"

    def sync_interval(self, eng) -> int:
        return eng.h_stream

    def initiate_due(self, eng, t: int, params_stack) -> None:
        if t % eng.h_stream == 0:
            p = (t // eng.h_stream) % eng.K
            if all(ev.frag != p for ev in eng.pending):
                eng._initiate(t, params_stack, p)

    def apply_delivery(self, ccfg, dc_impl, *, local_now, snapshot, g_b,
                       t, t_init):
        return dc_lib.blend(local_now, g_b, alpha=ccfg.mixing_alpha)

    def fused_delivery_kwargs(self, ccfg, *, t, t_init) -> dict:
        return {"alpha": ccfg.mixing_alpha}


@register_method
class CoCoDC(OverlappedMethod):
    """CoCoDC: Eq. 9/10 initiation cadence, Algorithm-2 fragment selection,
    Algorithm-1 delay compensation on delivery (with the ACTUAL overlap
    depth), optional per-round Eq. 9 re-derivation from measured T_s."""
    name = "cocodc"
    keeps_snapshot = True
    supports_adaptive_resync = True
    fused_delivery = "compensate"

    def sync_interval(self, eng) -> int:
        return eng.h_cocodc

    def extra_event_step(self, eng, t: int) -> Optional[int]:
        if eng._resync is not None:
            # Eq. 9 re-derivation runs in on_step_end at each outer-round
            # boundary — that step must be a protocol event, or the segment
            # loop would fuse it away and diverge from the per-step loop
            return t + (eng.H - 1 - t) % eng.H
        return None

    def initiate_due(self, eng, t: int, params_stack) -> None:
        if t % eng.h_cocodc == 0:
            busy = {ev.frag for ev in eng.pending}
            if len(busy) < eng.K:
                p = eng._select_cocodc(t, busy)
                eng._initiate(t, params_stack, p)

    def after_deliveries(self, eng, t: int) -> None:
        if eng._resync is not None and (t + 1) % eng.H == 0:
            # end of an outer round: re-derive Eq. 9's N / Eq. 10's h from
            # the measured T_s so next round's cadence tracks the network
            # the run actually sees. Under the fair-share scheduler the
            # durations include contention, so the latency/bandwidth
            # decomposition isolates the congestion-sensitive term (the
            # serial path keeps the window-mean arithmetic byte-for-byte).
            eng.N, eng.h_cocodc = adaptive_lib.rederive_schedule(
                eng._resync, eng.K, eng.H, eng.topology.t_c,
                eng.cfg.net_utilization, eng._t_s_startup,
                decompose=(eng.cfg.channel_scheduler == "fairshare"),
                ref_bytes=eng._ref_wire_bytes, lat_s=eng._lat_startup)

    def apply_delivery(self, ccfg, dc_impl, *, local_now, snapshot, g_b,
                       t, t_init):
        tau_actual = jnp.maximum(1, t - t_init).astype(jnp.float32)
        return dc_lib.compensate(
            local_now, snapshot, g_b, tau=tau_actual, lam=ccfg.comp_lambda,
            H=float(ccfg.local_steps), sign=ccfg.eq4_sign, impl=dc_impl)

    def fused_delivery_kwargs(self, ccfg, *, t, t_init) -> dict:
        tau_actual = jnp.maximum(1, t - t_init).astype(jnp.float32)
        return {"tau": tau_actual, "lam": ccfg.comp_lambda,
                "H": float(ccfg.local_steps), "sign": ccfg.eq4_sign}
