"""CoCoDC delay compensation (paper Algorithm 1, Eqs. 4-8) at the pytree level.

Given, for one fragment p of one worker m:
  theta_tl — local fragment now (step t_l)
  theta_tp — local fragment snapshot at initiation (step t_p = t_l - tau)
  theta_g  — freshly outer-updated global fragment state (consensus at t_p)

    g      = sign * (theta_tl - theta_tp) / tau          (Eq. 4; sign: DESIGN.md §5)
    g_corr = g + lam * g . g . (theta_g - theta_tp)/H    (Eq. 7, Hadamard)
    out    = theta_g + tau * g_corr                      (Eq. 8)

`impl="kernel"` routes through the fused Pallas kernel; "ref" is the jnp oracle
(used on CPU and under jit inside the protocol engine).
"""
from __future__ import annotations

import jax

from repro.kernels.delay_comp.ops import delay_comp_array, pack_scalars
from repro.kernels.delay_comp.ref import delay_comp_ref


def compensate(theta_tl, theta_tp, theta_g, *, tau, lam, H, sign=1.0,
               impl: str = "ref"):
    """Pytree-level Algorithm 1. None leaves (absent from this fragment) pass
    through as None."""
    # kernel path: SMEM scalar operand built once for the whole tree, not per
    # leaf (the ref path keeps the python scalars — its traced program is
    # golden-pinned)
    scalars = pack_scalars(tau, lam, H, sign) if impl == "kernel" else None

    def fn(tl, tp, tg):
        if tl is None:
            return None
        if impl == "kernel":
            return delay_comp_array(tl, tp, tg, scalars=scalars)
        return delay_comp_ref(tl, tp, tg, tau=tau, lam=lam, H=H, sign=sign)

    flat_tl, treedef = jax.tree.flatten(theta_tl, is_leaf=lambda x: x is None)
    flat_tp = treedef.flatten_up_to(theta_tp)
    flat_tg = treedef.flatten_up_to(theta_g)
    return treedef.unflatten([fn(a, b, c)
                              for a, b, c in zip(flat_tl, flat_tp, flat_tg)])


def blend(theta_local, theta_g, *, alpha: float):
    """Streaming DiLoCo Eq. 3: (1-alpha)*local + alpha*global."""

    def fn(l, g):
        if l is None:
            return None
        return (1.0 - alpha) * l + alpha * g

    flat_l, treedef = jax.tree.flatten(theta_local, is_leaf=lambda x: x is None)
    flat_g = treedef.flatten_up_to(theta_g)
    return treedef.unflatten([fn(l, g) for l, g in zip(flat_l, flat_g)])
