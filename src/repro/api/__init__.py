"""Public experiment API: declarative specs + the pluggable sync-method
registry + the one trainer factory.

    from repro.api import ExperimentSpec, MethodSpec, build_experiment

    spec = ExperimentSpec(method=MethodSpec(name="cocodc", local_steps=100))
    trainer = build_experiment(spec)
    trainer.run(eval_every=spec.run.eval_every)

Specs serialize to JSON (`spec.to_json()` / `ExperimentSpec.from_json_file`),
validate cross-field constraints (`spec.validate()`), and carry a stable
`spec_hash` used for checkpoint-resume validation. New sync methods register
with `@register_method` (see repro/core/methods.py) and are then selectable
by name in any spec or CLI flag.
"""
from repro.api.build import (build_experiment, build_network,
                             mean_fragment_bytes, resolve_model)
from repro.api.spec import (ExperimentSpec, MethodExtensions, MethodSpec,
                            ModelRef, NetworkSpec, RunSpec, diff_specs)
from repro.core.methods import (SyncMethod, get_method, register_method,
                                registered_methods, unregister_method)

__all__ = [
    "ExperimentSpec", "MethodSpec", "MethodExtensions", "ModelRef",
    "NetworkSpec", "RunSpec", "build_experiment", "build_network",
    "mean_fragment_bytes", "resolve_model", "diff_specs",
    "SyncMethod", "register_method", "unregister_method", "get_method",
    "registered_methods",
]
