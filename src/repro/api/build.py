"""`build_experiment(spec) -> CrossRegionTrainer` — the single factory behind
every launcher (repro.launch.train, benchmarks/sweep.py,
benchmarks/convergence.py, examples/train_cross_region.py).

All network/mesh/dynamics assembly that used to be re-implemented per caller
lives here once: named scenario or generated mesh, optional bandwidth
calibration (`NetworkSpec.bw_scale="auto"`), and the dynamics layer (attached
by the trainer so it applies to the calibrated symmetric default too).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

from repro.api.spec import ExperimentSpec
from repro.core.network import (Topology, calibrate_bw_scale, generate_mesh,
                                make_scenario)


def resolve_model(spec: ExperimentSpec):
    """ModelRef -> ModelConfig (reduced variant / dtype override applied)."""
    from repro.configs import get_config
    mcfg = get_config(spec.model.arch)
    if spec.model.reduced:
        mcfg = mcfg.reduced()
    if spec.model.compute_dtype is not None:
        mcfg = dataclasses.replace(mcfg, compute_dtype=spec.model.compute_dtype)
    return mcfg


@functools.lru_cache(maxsize=None)
def _mean_fragment_bytes_cached(arch: str, reduced: bool,
                                compute_dtype: Optional[str],
                                num_fragments: int) -> int:
    import jax

    from repro.core.fragments import make_fragmenter
    from repro.models import api as models_api
    mcfg = resolve_model(ExperimentSpec.from_dict(
        {"model": {"arch": arch, "reduced": reduced,
                   "compute_dtype": compute_dtype}}))
    shape = jax.eval_shape(functools.partial(models_api.init_params, mcfg),
                           jax.random.PRNGKey(0))
    frag = make_fragmenter(mcfg, shape, num_fragments)
    return frag.total_bytes // num_fragments


def mean_fragment_bytes(spec: ExperimentSpec) -> int:
    """Mean fragment payload (f32 wire format) of the spec's model under its
    fragment count — the `bw_scale="auto"` calibration input. Abstract shapes
    only (eval_shape); never allocates the model."""
    return _mean_fragment_bytes_cached(
        spec.model.arch, spec.model.reduced, spec.model.compute_dtype,
        spec.method.num_fragments)


def build_network(spec: ExperimentSpec) -> Optional[Topology]:
    """NetworkSpec -> base Topology (no dynamics attached — the trainer owns
    that so dynamics also apply to the default network). None = let the
    trainer build the calibrated symmetric paper network."""
    n = spec.network
    if n.mesh is not None:
        net = generate_mesh(spec.method.num_workers, n.mesh, seed=n.mesh_seed,
                            step_time_s=n.step_time_s)
    elif n.topology not in (None, "paper"):
        net = make_scenario(n.topology, num_workers=spec.method.num_workers,
                            step_time_s=n.step_time_s)
    else:
        # "paper"/None keeps the calibrated-symmetric default (network=None)
        # so the fragment-size calibration in CrossRegionTrainer applies
        return None
    scale = n.bw_scale
    if scale == "auto":
        scale = calibrate_bw_scale(net, mean_fragment_bytes(spec))
    if scale is not None and float(scale) != 1.0:
        net = dataclasses.replace(net,
                                  bandwidth_Bps=net.bandwidth_Bps * float(scale))
    if n.concurrent_collectives != 1:
        net = dataclasses.replace(
            net, concurrent_collectives=n.concurrent_collectives)
    return net


def build_experiment(spec: ExperimentSpec):
    """Validate `spec` and construct the trainer it describes. The spec rides
    on the trainer into every checkpoint (`meta["spec"]`/`meta["spec_hash"]`)
    so a resume validates against the run's full declarative identity."""
    from repro.core.trainer import CrossRegionTrainer
    spec.validate()
    mcfg = resolve_model(spec)
    ccfg = spec.method.to_cocodc(spec.network)
    tcfg = spec.run.to_trainer_config(spec.method.name)
    return CrossRegionTrainer(
        mcfg, ccfg, tcfg, network=build_network(spec),
        dynamics=spec.network.dynamics, dynamics_seed=spec.network.mesh_seed,
        spec=spec)
