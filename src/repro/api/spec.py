"""Declarative experiment specification: ONE serializable object that fully
determines a cross-region training run.

An `ExperimentSpec` composes four frozen sections:

  * `ModelRef`     — which architecture config, reduced or full
  * `MethodSpec`   — sync-method name + the paper §IV protocol hyperparameters,
    with the beyond-paper knobs split into `MethodExtensions`
  * `NetworkSpec`  — named WAN scenario | generated mesh, link-dynamics spec,
    routed-planner knobs
  * `RunSpec`      — step budget, data/optimizer settings, execution loop,
    checkpoint cadence, seeds

Specs round-trip through JSON exactly (`to_json`/`from_json` — pinned by
tests/test_experiment_spec.py), validate cross-field constraints in ONE place
(`validate`), and expose a stable `spec_hash`: a digest of the
trajectory-determining fields (presentation-only knobs — eval cadence,
checkpoint cadence, loop/engine implementation, labels — are excluded, since
the scanned/per-step and jit/host paths are pinned bitwise-equal). The hash is
written into every checkpoint and replaces the ad-hoc per-key `_traj_meta`
comparison as the primary resume validation.

`repro.launch.train --print-spec` emits the spec any flag combination maps
onto; `--spec path.json` launches from a file, with explicit flags applied as
overrides on top.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import typing
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Union

from repro.core.methods import get_method
from repro.core.network import MESH_PROFILES, SCENARIOS


@dataclass(frozen=True)
class ModelRef:
    """Reference to a registered architecture config."""
    arch: str = "paper_150m"
    reduced: bool = False            # use the CPU-friendly smoke variant
    compute_dtype: Optional[str] = None   # override (None = the arch default)


@dataclass(frozen=True)
class MethodExtensions:
    """Beyond-paper protocol knobs, split from the §IV hyperparameters so a
    paper-faithful run is `MethodSpec(name=...)` with defaults here."""
    fragment_strategy: str = ""      # "" = strided (Streaming DiLoCo pattern)
    sync_dtype: str = "float32"      # WAN payload dtype (bf16 halves bytes)
    sync_topk_frac: float = 1.0      # top-k sparsification; 1.0 = dense
    link_pricing: bool = False       # Algorithm-2 cost-aware selection
    adaptive_resync: bool = False    # per-round Eq. 9 re-derivation
    wire_codec: str = "none"         # delta wire codec: none | int8 | int4
    codec_block: int = 256           # elements per absmax quantization block
    codec_error_feedback: bool = True  # EF residual folded into next initiation
    fused_updates: bool = False      # flat-plane + kernels/outer_update engine


@dataclass(frozen=True)
class MethodSpec:
    """Sync method (registry name) + paper §IV protocol hyperparameters."""
    name: str = "cocodc"
    num_workers: int = 4             # M
    local_steps: int = 100           # H
    num_fragments: int = 4           # K
    overlap_depth: int = 5           # tau
    mixing_alpha: float = 0.5        # Streaming DiLoCo blending (Eq. 3)
    comp_lambda: float = 0.5         # delay compensation strength (Eq. 7)
    net_utilization: float = 0.4     # gamma (Eq. 9)
    eq4_sign: float = 1.0            # +1 self-consistent; -1 literal Eq. (4)
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    extensions: MethodExtensions = field(default_factory=MethodExtensions)

    def to_cocodc(self, network: "NetworkSpec"):
        """Lower to the core-layer `CoCoDCConfig` (routing knobs live in the
        NetworkSpec but land on the protocol config)."""
        from repro.configs.base import CoCoDCConfig
        ext = self.extensions
        return CoCoDCConfig(
            num_workers=self.num_workers, local_steps=self.local_steps,
            num_fragments=self.num_fragments, overlap_depth=self.overlap_depth,
            mixing_alpha=self.mixing_alpha, comp_lambda=self.comp_lambda,
            net_utilization=self.net_utilization, eq4_sign=self.eq4_sign,
            outer_lr=self.outer_lr, outer_momentum=self.outer_momentum,
            fragment_strategy=ext.fragment_strategy,
            sync_dtype=ext.sync_dtype, sync_topk_frac=ext.sync_topk_frac,
            link_pricing=ext.link_pricing,
            adaptive_resync=ext.adaptive_resync,
            wire_codec=ext.wire_codec, codec_block=ext.codec_block,
            codec_error_feedback=ext.codec_error_feedback,
            fused_updates=ext.fused_updates,
            routing=network.routing, hub_failover=network.hub_failover,
            channel_scheduler=network.channel_scheduler,
            multipath_k=network.multipath_k)


@dataclass(frozen=True)
class NetworkSpec:
    """WAN description: at most one of `topology` (named scenario) or `mesh`
    (generated profile); neither = the calibrated symmetric paper network."""
    topology: Optional[str] = None   # named scenario, or "paper"/None
    mesh: Optional[str] = None       # generated-mesh profile (N = num_workers)
    mesh_seed: int = 0               # mesh generation + dynamics draws
    dynamics: Optional[str] = None   # time-varying link spec (parse_dynamics)
    step_time_s: float = 1.0         # T_c for explicit topologies/meshes
    # bandwidth multiplier: None = leave the mesh's real-world bandwidths;
    # "auto" = calibrate so one mean-fragment collective is bandwidth-
    # dominated at this model's scale (core.network.calibrate_bw_scale);
    # a float overrides either
    bw_scale: Union[float, str, None] = None
    routing: str = "static"          # "routed" = multi-hop planned collectives
    hub_failover: bool = False       # re-elect the hub while its links are out
    # WAN traffic plane: "serial" = channel queue (bitwise-pinned default);
    # "fairshare" = max-min water-filling over all in-flight transfers
    channel_scheduler: str = "serial"
    multipath_k: int = 1             # k edge-disjoint paths per logical link
    # serial scheduler's WAN channel pool (explicit networks only)
    concurrent_collectives: int = 1

    @property
    def explicit(self) -> bool:
        """True when the spec names a non-default network."""
        return self.mesh is not None or self.topology not in (None, "paper")


@dataclass(frozen=True)
class RunSpec:
    """Execution budget and run-level knobs."""
    steps: int = 200
    seed: int = 0
    local_batch: int = 4
    seq_len: int = 64
    inner_lr: float = 4e-4
    warmup_steps: Optional[int] = None   # None = max(10, steps // 20)
    weight_decay: float = 0.1
    noniid_frac: float = 0.25
    eval_batch: int = 16
    eval_every: int = 50
    ckpt_every: int = 0              # 0 = only a final checkpoint (if any)
    loop: str = "segment"            # segment-scanned vs per_step (bitwise)
    engine_impl: str = "jit"         # jitted vs eager transitions (bitwise)
    max_segment: int = 64

    @property
    def resolved_warmup(self) -> int:
        return (self.warmup_steps if self.warmup_steps is not None
                else max(10, self.steps // 20))

    def to_trainer_config(self, method: str):
        from repro.core.trainer import TrainerConfig
        return TrainerConfig(
            method=method, local_batch=self.local_batch, seq_len=self.seq_len,
            total_steps=self.steps, inner_lr=self.inner_lr,
            warmup_steps=self.resolved_warmup,
            weight_decay=self.weight_decay, eval_batch=self.eval_batch,
            seed=self.seed, noniid_frac=self.noniid_frac,
            engine_impl=self.engine_impl, loop=self.loop,
            max_segment=self.max_segment)


_SECTIONS = {"model": ModelRef, "method": MethodSpec, "network": NetworkSpec,
             "run": RunSpec}

# fields that do NOT determine the training trajectory (eval/checkpoint
# cadence and the two execution-path knobs whose variants are pinned
# bitwise-equal) — excluded from spec_hash so e.g. resuming with a different
# eval cadence is not rejected
_VOLATILE_RUN_FIELDS = ("eval_batch", "eval_every", "ckpt_every", "loop",
                        "engine_impl", "max_segment")


def _coerce(cls, kwargs: Dict[str, Any]) -> Dict[str, Any]:
    """Cast JSON numbers onto the dataclass field types (an int in a float
    field would survive construction but break hash stability)."""
    hints = typing.get_type_hints(cls)
    out = {}
    for k, v in kwargs.items():
        t = hints.get(k)
        if t is float and v is not None:
            v = float(v)
        elif t is int and v is not None:
            v = int(v)
        elif t == Optional[int] and v is not None:
            v = int(v)
        elif t == Optional[float] and v is not None:
            v = float(v)
        elif t == Union[float, str, None] and isinstance(v, int) \
                and not isinstance(v, bool):
            v = float(v)
        out[k] = v
    return out


def _from_section(cls, d: Dict[str, Any], where: str):
    if not isinstance(d, dict):
        raise ValueError(f"spec section {where!r} must be an object, "
                         f"got {type(d).__name__}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"unknown spec field(s) in {where!r}: {unknown}; "
                         f"known: {sorted(known)}")
    kwargs = dict(d)
    if cls is MethodSpec and "extensions" in kwargs:
        kwargs["extensions"] = _from_section(
            MethodExtensions, kwargs["extensions"] or {}, "method.extensions")
    return cls(**_coerce(cls, kwargs))


@dataclass(frozen=True)
class ExperimentSpec:
    """The one way to define an experiment: serializable, validated,
    hashable. Build a trainer from it with `repro.api.build_experiment`."""
    model: ModelRef = field(default_factory=ModelRef)
    method: MethodSpec = field(default_factory=MethodSpec)
    network: NetworkSpec = field(default_factory=NetworkSpec)
    run: RunSpec = field(default_factory=RunSpec)
    name: str = ""                   # label (scenario name, sweep id, ...)
    note: str = ""                   # free-form description

    # ------------------------------------------------------------ validation

    def validate(self) -> "ExperimentSpec":
        """Cross-field validation; raises ValueError with an actionable
        message. Returns self so `spec.validate()` chains."""
        def fail(msg):
            raise ValueError(f"invalid ExperimentSpec: {msg}")

        # method must be registered (raises listing registered methods)
        impl = get_method(self.method.name)
        from repro.configs import ARCH_IDS, canonical
        try:
            canonical(self.model.arch)
        except KeyError:
            fail(f"unknown arch {self.model.arch!r}; known: {sorted(ARCH_IDS)}")
        n = self.network
        if n.mesh is not None and n.topology is not None:
            fail("network.mesh and network.topology are mutually exclusive "
                 "(--mesh/--topology)")
        if n.mesh is not None and n.mesh not in MESH_PROFILES:
            fail(f"unknown mesh profile {n.mesh!r}; "
                 f"options: {sorted(MESH_PROFILES)}")
        if n.topology not in (None, "paper") and n.topology not in SCENARIOS:
            fail(f"unknown topology scenario {n.topology!r}; "
                 f"options: paper, {', '.join(sorted(SCENARIOS))}")
        if n.routing not in ("static", "routed"):
            fail(f"network.routing must be 'static' or 'routed', "
                 f"got {n.routing!r}")
        if n.routing == "routed" and not n.explicit:
            fail("network.routing='routed' requires an explicit topology or "
                 "mesh (multi-hop planning over the calibrated symmetric "
                 "default is a no-op)")
        if n.hub_failover and n.routing != "routed":
            fail("network.hub_failover requires network.routing='routed'")
        if n.channel_scheduler not in ("serial", "fairshare"):
            fail(f"network.channel_scheduler must be 'serial' or 'fairshare', "
                 f"got {n.channel_scheduler!r}")
        if n.multipath_k < 1:
            fail(f"network.multipath_k must be >= 1, got {n.multipath_k}")
        if n.multipath_k > 1 and n.routing != "routed":
            fail("network.multipath_k > 1 requires network.routing='routed' "
                 "(k-path splitting needs the route planner)")
        if n.concurrent_collectives < 1:
            fail(f"network.concurrent_collectives must be >= 1, "
                 f"got {n.concurrent_collectives}")
        if n.concurrent_collectives != 1 and not n.explicit:
            fail("network.concurrent_collectives requires an explicit "
                 "topology or mesh (the calibrated paper default is "
                 "single-channel)")
        if n.concurrent_collectives != 1 and \
                n.channel_scheduler == "fairshare":
            fail("network.concurrent_collectives applies to the serial "
                 "scheduler only (fairshare shares links, not channels)")
        if isinstance(n.bw_scale, str) and n.bw_scale != "auto":
            fail(f"network.bw_scale must be a number, null, or 'auto', "
                 f"got {n.bw_scale!r}")
        if self.method.extensions.adaptive_resync and \
                not impl.supports_adaptive_resync:
            fail(f"method.extensions.adaptive_resync requires a method with "
                 f"Eq. 9 re-derivation (method {self.method.name!r} has a "
                 f"fixed cadence)")
        strategies = ("", "strided", "contiguous", "skewed")
        if self.method.extensions.fragment_strategy not in strategies:
            fail(f"unknown fragment_strategy "
                 f"{self.method.extensions.fragment_strategy!r}; "
                 f"options: {strategies}")
        ext = self.method.extensions
        if ext.wire_codec not in ("none", "int8", "int4"):
            fail(f"method.extensions.wire_codec must be 'none', 'int8' or "
                 f"'int4', got {ext.wire_codec!r}")
        if not (2 <= ext.codec_block <= (1 << 16)) or ext.codec_block % 2:
            fail(f"method.extensions.codec_block must be an even integer in "
                 f"[2, 65536] (int4 packs element pairs), "
                 f"got {ext.codec_block}")
        if ext.fused_updates and impl.overlapped and not impl.fused_delivery:
            fail(f"method.extensions.fused_updates requires a fused delivery "
                 f"mode on the method; {self.method.name!r} defines none "
                 f"(set SyncMethod.fused_delivery to 'blend' or 'compensate')")
        if self.run.loop not in ("segment", "per_step"):
            fail(f"run.loop must be 'segment' or 'per_step', "
                 f"got {self.run.loop!r}")
        if self.run.engine_impl not in ("jit", "host"):
            fail(f"run.engine_impl must be 'jit' or 'host', "
                 f"got {self.run.engine_impl!r}")
        for attr, lo in (("steps", 1), ("local_batch", 1), ("seq_len", 1)):
            if getattr(self.run, attr) < lo:
                fail(f"run.{attr} must be >= {lo}")
        for attr, lo in (("num_workers", 2), ("local_steps", 1),
                         ("num_fragments", 1), ("overlap_depth", 0)):
            if getattr(self.method, attr) < lo:
                fail(f"method.{attr} must be >= {lo}")
        return self

    # --------------------------------------------------------- serialization

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        if not isinstance(d, dict):
            raise ValueError(f"spec must be an object, got {type(d).__name__}")
        known = set(_SECTIONS) | {"name", "note"}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown top-level spec field(s): {unknown}; "
                             f"known: {sorted(known)}")
        kwargs: Dict[str, Any] = {
            key: _from_section(scls, d.get(key) or {}, key)
            for key, scls in _SECTIONS.items()}
        kwargs["name"] = str(d.get("name", ""))
        kwargs["note"] = str(d.get("note", ""))
        return cls(**kwargs)

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_json_file(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    # ----------------------------------------------------------------- hash

    def traj_dict(self) -> Dict[str, Any]:
        """The trajectory-determining subset of the spec: everything except
        labels and the presentation/cadence fields in `_VOLATILE_RUN_FIELDS`
        (whose variants are pinned bitwise-equal or read-only). Derived
        fields are canonicalized (warmup_steps=None hashes as its resolved
        value, so an explicitly-stated equal warmup matches)."""
        # route through from_dict so a directly-constructed spec holding an
        # int in a float field (e.g. mixing_alpha=1) hashes identically to
        # its own JSON round-trip (_coerce runs only on from_dict)
        canon = ExperimentSpec.from_dict(self.to_dict())
        d = canon.to_dict()
        d.pop("name"), d.pop("note")
        for k in _VOLATILE_RUN_FIELDS:
            d["run"].pop(k)
        d["run"]["warmup_steps"] = canon.run.resolved_warmup
        return d

    @property
    def spec_hash(self) -> str:
        """Stable digest of `traj_dict` — written into checkpoints and
        compared on resume: equal hashes guarantee the resumed run replays
        the saved run's exact trajectory."""
        canon = json.dumps(self.traj_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]


def diff_specs(a: Dict[str, Any], b: Dict[str, Any],
               prefix: str = "") -> "list[str]":
    """Dotted-path description of where two spec dicts differ (for resume
    mismatch errors)."""
    out = []
    for k in sorted(set(a) | set(b)):
        path = f"{prefix}{k}"
        va, vb = a.get(k), b.get(k)
        if isinstance(va, dict) and isinstance(vb, dict):
            out.extend(diff_specs(va, vb, prefix=path + "."))
        elif va != vb:
            out.append(f"{path}: {va!r} != {vb!r}")
    return out
