"""Slotted KV-cache management for the continuous-batching serving engine.

The device side is a fixed pool of `n_slots` decode lanes over the models'
``(B, C, KV, hd)`` cache layout (`transformer.init_slot_cache`): every slot
carries its own ring-buffer position map (``kv_pos`` row, -1 = empty) and
decode position, plus the per-slot request registers the engine samples with
(prompt buffer, RNG stream, generation counters). All shapes are fixed at
construction — admission, recycling, and completion never change a traced
shape, so the jitted decode step is traced exactly once no matter how batch
composition churns.

The host side (`SlotManager`) is plain bookkeeping: which slots are free,
which request occupies which slot, and occupancy accounting. It never touches
device memory — slot resets are part of the engine's jitted admission
transition (`reset_slot` below), with the slot index traced so admitting to
slot 7 reuses the trace admitting to slot 0 built.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def init_slot_state(cfg: ModelConfig, n_slots: int, cache_len: int,
                    max_prompt: int, prefill_chunk: int) -> Dict:
    """Full device state of the slot plane: the slotted KV cache plus per-slot
    request registers. The prompt buffer is over-allocated by one chunk so a
    chunk window starting anywhere in [0, max_prompt] is a static slice."""
    st = transformer.init_slot_cache(cfg, n_slots, cache_len)
    st.update({
        "prompt": jnp.zeros((n_slots, max_prompt + prefill_chunk), jnp.int32),
        "prompt_len": jnp.zeros((n_slots,), jnp.int32),
        "prefilled": jnp.zeros((n_slots,), jnp.int32),
        "active": jnp.zeros((n_slots,), bool),
        "last_tok": jnp.zeros((n_slots,), jnp.int32),
        "rng": jnp.zeros((n_slots, 2), jnp.uint32),
        "gen_count": jnp.zeros((n_slots,), jnp.int32),
        "gen_limit": jnp.zeros((n_slots,), jnp.int32),
    })
    return st


def reset_slot(state: Dict, slot, prompt, prompt_len, gen_limit, req_key):
    """Pure slot-admission transition (jit-compatible; `slot` traced). Clears
    the slot's ring-buffer map (stale K/V values stay — they are masked by
    kv_pos = -1 and overwritten as the new request fills the ring) and loads
    the request registers. `req_key`: (2,) uint32 — the request's dedicated
    sampling stream."""
    C = state["kv_pos"].shape[1]
    row = jnp.full((1, C), -1, jnp.int32)
    return {
        **state,
        "kv_pos": jax.lax.dynamic_update_slice_in_dim(state["kv_pos"], row,
                                                      slot, axis=0),
        "pos": state["pos"].at[slot].set(0),
        "prompt": jax.lax.dynamic_update_slice(
            state["prompt"], prompt[None].astype(jnp.int32), (slot, 0)),
        "prompt_len": state["prompt_len"].at[slot].set(prompt_len),
        "prefilled": state["prefilled"].at[slot].set(0),
        "active": state["active"].at[slot].set(False),
        "last_tok": state["last_tok"].at[slot].set(0),
        "rng": state["rng"].at[slot].set(req_key),
        "gen_count": state["gen_count"].at[slot].set(0),
        "gen_limit": state["gen_limit"].at[slot].set(gen_limit),
    }


@dataclasses.dataclass
class SlotManager:
    """Host-side slot allocator: free-list + slot -> request-id map + occupancy
    tallies. Slots are recycled lowest-index-first so runs are deterministic."""
    n_slots: int
    free: List[int] = dataclasses.field(default_factory=list)
    owner: Dict[int, int] = dataclasses.field(default_factory=dict)
    # occupancy accounting: sum of occupied-slot counts over decode ticks
    occupied_ticks: int = 0
    decode_ticks: int = 0

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if not self.free and not self.owner:
            self.free = list(range(self.n_slots))

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def occupied(self) -> List[int]:
        return sorted(self.owner)

    def acquire(self, rid: int) -> Optional[int]:
        """Claim the lowest free slot for request `rid`; None when full."""
        if not self.free:
            return None
        self.free.sort()
        slot = self.free.pop(0)
        self.owner[slot] = rid
        return slot

    def release(self, slot: int) -> int:
        """Return a slot to the pool; returns the evicted request id."""
        if slot not in self.owner:
            raise KeyError(f"slot {slot} is not occupied")
        rid = self.owner.pop(slot)
        self.free.append(slot)
        return rid

    def note_decode_tick(self, n_active: Optional[int] = None) -> None:
        """Record one decode dispatch; `n_active` is how many slots were
        generating (defaults to the occupied count)."""
        self.occupied_ticks += len(self.owner) if n_active is None else n_active
        self.decode_ticks += 1

    @property
    def mean_occupancy(self) -> float:
        """Mean generating fraction of the slot plane over decode ticks — the
        lever continuous batching pulls (every tick pays for all n_slots)."""
        if self.decode_ticks == 0:
            return 0.0
        return self.occupied_ticks / (self.decode_ticks * self.n_slots)
