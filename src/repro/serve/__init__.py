"""Continuous-batching serving over the simulated cross-region WAN.

  cache   — slotted KV-cache state + host-side slot allocator
  engine  — ServeEngine: continuous batching vs lock-step baseline
  router  — region-affine request routing over core.network topologies
  traffic — seeded request-trace generator (diurnal load, skew, bursts)
"""
from repro.serve.cache import SlotManager, init_slot_state, reset_slot
from repro.serve.engine import CostModel, Request, RequestRecord, ServeEngine
from repro.serve.router import ClusterStats, RegionRouter, RoutedCluster
from repro.serve.traffic import TrafficSpec, generate

__all__ = [
    "SlotManager", "init_slot_state", "reset_slot",
    "CostModel", "Request", "RequestRecord", "ServeEngine",
    "ClusterStats", "RegionRouter", "RoutedCluster",
    "TrafficSpec", "generate",
]
