"""Continuous-batching serving engine over the slotted KV cache.

One jitted decode step runs the WHOLE slot plane every tick (`flash_decode` /
reference GQA over per-slot position maps); requests join and leave by
flipping per-slot registers, never by changing a traced shape — the decode
step is traced exactly once per engine (asserted in tests via
`decode_trace_count`). Prompt ingestion is CHUNKED: each admission prefills
`prefill_chunk` tokens per scheduler round, interleaved with decode steps, so
a long prompt cannot starve in-flight decodes and time-to-first-token stays
bounded.

Two scheduling modes share every jitted function:

  * ``continuous`` — admit into any free slot immediately, recycle a slot the
    tick its request completes (the serving path);
  * ``static``     — the lock-step baseline: admit a wave of up to `n_slots`
    requests, prefill them all, decode until the LAST one finishes, then
    recycle the whole wave (what `launch/serve.py` did before this engine).

Time: the engine keeps a VIRTUAL clock advanced by an explicit `CostModel`
(seconds per decode dispatch over the plane, per prefill chunk, per
admission). Latency/throughput numbers are therefore deterministic for a
given trace and directly comparable across modes — the decode dispatch
computes every slot whether or not it is occupied, which is exactly why
occupancy (what continuous batching buys) shows up as throughput.

Sampling: every request gets a dedicated RNG stream folded from the engine
seed and the request id at admission; the token at sequence position p is
sampled with `fold_in(request_stream, p)` INSIDE the jitted step — no key is
ever shared with prompt generation or across requests.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.retrace import RetraceSentinel
from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.serve import cache as cache_lib


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request. `arrival_s` is on the virtual clock; `region` is
    only meaningful when routed through a `RegionRouter`."""
    rid: int
    prompt: np.ndarray                   # (P,) int32 token ids
    max_new_tokens: int
    region: int = 0
    arrival_s: float = 0.0


@dataclasses.dataclass
class RequestRecord:
    """Per-request lifecycle trace (virtual-clock timestamps)."""
    rid: int
    region: int
    arrival_s: float
    n_prompt: int
    max_new: int
    admit_s: float = 0.0
    first_tok_s: Optional[float] = None
    done_s: Optional[float] = None
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    tok_times: List[float] = dataclasses.field(default_factory=list)
    # filled by RoutedCluster
    replica: int = -1
    req_hop_s: float = 0.0
    resp_hop_s: float = 0.0
    held_s: float = 0.0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_tok_s is None:
            return None
        return self.first_tok_s + self.resp_hop_s - self.arrival_s

    @property
    def mean_tok_latency_s(self) -> Optional[float]:
        if self.done_s is None or len(self.tokens) < 2:
            return None
        return (self.done_s - self.first_tok_s) / (len(self.tokens) - 1)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Virtual seconds charged per engine dispatch. The decode charge covers
    the FULL slot plane (the dispatch computes every slot regardless of
    occupancy — that is the physical contract of the fixed-shape step), so
    idle slots cost real time: occupancy is throughput."""
    decode_base_s: float = 0.02          # per decode dispatch
    decode_slot_s: float = 0.002         # x n_slots, occupied or not
    prefill_base_s: float = 0.01         # per prefill-chunk dispatch
    prefill_token_s: float = 0.001       # x chunk width (padded chunk computed)
    admit_s: float = 0.0005              # per admission transition

    def decode_cost(self, n_slots: int) -> float:
        return self.decode_base_s + self.decode_slot_s * n_slots

    def prefill_cost(self, chunk: int) -> float:
        return self.prefill_base_s + self.prefill_token_s * chunk


class ServeEngine:
    """Continuous-batching (or lock-step baseline) serving over one model
    replica. See module docstring for the scheduling/time model."""

    MODES = ("continuous", "static")

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 cache_len: int = 128, max_prompt: int = 64,
                 prefill_chunk: int = 16, mode: str = "continuous",
                 temperature: float = 0.0, seed: int = 0,
                 eos_id: Optional[int] = None, attn_impl: str = "auto",
                 cost: Optional[CostModel] = None,
                 prefill_chunks_per_tick: int = 2):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"ServeEngine supports transformer decode (dense/moe), got "
                f"family {cfg.family!r}; use the legacy lock-step path in "
                f"launch/serve.py for SSM/hybrid archs")
        if mode not in self.MODES:
            raise ValueError(f"unknown mode {mode!r}; options: {self.MODES}")
        if attn_impl == "auto":
            # interpret-mode Pallas is orders slower than the reference path
            # on CPU; on real accelerators the kernel is the point
            attn_impl = "ref" if jax.default_backend() == "cpu" else "flash"
        if attn_impl not in ("ref", "flash"):
            raise ValueError(f"unknown attn_impl {attn_impl!r}")
        self.cfg = cfg
        self.params = params
        self.n_slots = int(n_slots)
        self.cache_len = int(cache_len)
        self.max_prompt = int(max_prompt)
        self.prefill_chunk = int(prefill_chunk)
        self.mode = mode
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.eos_id = eos_id
        self.attn_impl = attn_impl
        self.cost = cost or CostModel()
        self.prefill_chunks_per_tick = int(prefill_chunks_per_tick)
        self.window = cfg.attn_window

        self.state = cache_lib.init_slot_state(cfg, self.n_slots,
                                               self.cache_len, self.max_prompt,
                                               self.prefill_chunk)
        self.slots = cache_lib.SlotManager(self.n_slots)
        self.queue: Deque[Request] = collections.deque()
        self.records: Dict[int, RequestRecord] = {}      # rid -> record
        self.by_slot: Dict[int, RequestRecord] = {}      # occupied slot -> rec
        self.completed: List[RequestRecord] = []
        self.clock = 0.0
        self.n_decode_dispatches = 0
        self.n_prefill_dispatches = 0
        self._wave: List[int] = []                       # static mode slots
        self._build_fns()

    # ------------------------------------------------------------ jitted fns

    def _build_fns(self):
        cfg, Pc = self.cfg, self.prefill_chunk
        window, attn_impl = self.window, self.attn_impl
        temp, eos = self.temperature, self.eos_id
        base_key = jax.random.PRNGKey(self.seed)
        cache_keys = ("k", "v", "kv_pos", "pos")

        def sample(logits, key):
            if temp <= 0.0:
                return jnp.argmax(logits, -1).astype(jnp.int32)
            return jax.random.categorical(key, logits / temp).astype(jnp.int32)

        def eos_hit(tok):
            if eos is None:
                return jnp.zeros(tok.shape, bool)
            return tok == eos

        def admit(state, slot, prompt, plen, glimit, rid):
            # the request's dedicated sampling stream: engine seed x request
            # id — never the key that generated the prompt, never shared
            req_key = jax.random.fold_in(base_key, rid)
            return cache_lib.reset_slot(state, slot, prompt, plen, glimit,
                                        req_key)

        def prefill(params, state, slot):
            start = state["prefilled"][slot]
            plen = state["prompt_len"][slot]
            n_valid = jnp.minimum(plen - start, Pc)
            chunk = jax.lax.dynamic_slice(state["prompt"], (slot, start),
                                          (1, Pc))[0]
            kv = {k: state[k] for k in cache_keys}
            logits, kv = transformer.prefill_chunk_slotted(
                cfg, params, kv, chunk, slot, start, n_valid, window=window)
            done = (start + n_valid) >= plen
            # token at sequence position p samples fold_in(stream, p); the
            # first generated token sits at position plen
            key = jax.random.fold_in(state["rng"][slot], start + n_valid)
            tok = jnp.where(done, sample(logits, key), state["last_tok"][slot])
            glimit = state["gen_limit"][slot]
            finished = done & ((glimit <= 1) | eos_hit(tok))
            new = {**state, **kv}
            new["prefilled"] = state["prefilled"].at[slot].set(start + n_valid)
            new["active"] = state["active"].at[slot].set(done & ~finished)
            new["last_tok"] = state["last_tok"].at[slot].set(tok)
            new["gen_count"] = state["gen_count"].at[slot].set(
                done.astype(jnp.int32))
            return new, tok

        def decode(params, state):
            active = state["active"]
            pos0 = state["pos"]
            kv = {k: state[k] for k in cache_keys}
            logits, kv = transformer.decode_step_slotted(
                cfg, params, kv, state["last_tok"], active=active,
                window=window, attn_impl=attn_impl)
            # generated token's sequence position is pos0 + 1 (its input, the
            # previous token, is written at pos0) — so streams never collide
            # with the first token's fold_in(stream, plen)
            keys = jax.vmap(jax.random.fold_in)(state["rng"], pos0 + 1)
            toks = jax.vmap(sample)(logits, keys)
            toks = jnp.where(active, toks, state["last_tok"])
            gen_count = state["gen_count"] + active.astype(jnp.int32)
            finished = active & ((gen_count >= state["gen_limit"])
                                 | eos_hit(toks))
            new = {**state, **kv, "last_tok": toks, "gen_count": gen_count,
                   "active": active & ~finished}
            return new, toks, finished

        # trace-once is ENFORCED per engine, not just asserted in tests: the
        # shared RetraceSentinel (repro.analysis.retrace) fails the exact
        # call whose input churned a traced shape/dtype, for all three steps
        donate = () if jax.default_backend() == "cpu" else (0,)
        donate1 = () if jax.default_backend() == "cpu" else (1,)
        self._admit_fn = RetraceSentinel(
            jax.jit(admit, donate_argnums=donate), name="serve.admit")
        self._prefill_fn = RetraceSentinel(
            jax.jit(prefill, donate_argnums=donate1), name="serve.prefill")
        self._decode_fn = RetraceSentinel(
            jax.jit(decode, donate_argnums=donate1), name="serve.decode")

    def decode_trace_count(self) -> int:
        """Number of distinct traces the decode step has compiled — the
        zero-recompile contract says this stays 1 across any batch churn."""
        return self._decode_fn.trace_count

    def prefill_trace_count(self) -> int:
        return self._prefill_fn.trace_count

    # --------------------------------------------------------------- intake

    def submit(self, req: Request) -> None:
        """Queue a request (validates it fits the slot plane)."""
        P = int(np.asarray(req.prompt).shape[0])
        if P < 1 or P > self.max_prompt:
            raise ValueError(f"prompt length {P} outside [1, {self.max_prompt}]")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.window is None and P + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request needs {P + req.max_new_tokens} cache positions > "
                f"cache_len {self.cache_len} (no sliding window to wrap into)")
        if req.rid in self.records:
            raise ValueError(f"duplicate request id {req.rid}")
        self.records[req.rid] = RequestRecord(
            rid=req.rid, region=req.region, arrival_s=req.arrival_s,
            n_prompt=P, max_new=req.max_new_tokens)
        self.queue.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.slots.owner)

    # ------------------------------------------------------------ scheduling

    def _admit_one(self, req: Request) -> None:
        slot = self.slots.acquire(req.rid)
        assert slot is not None
        rec = self.records[req.rid]
        rec.slot, rec.admit_s = slot, self.clock
        self.by_slot[slot] = rec
        P = int(np.asarray(req.prompt).shape[0])
        buf = np.zeros((self.max_prompt + self.prefill_chunk,), np.int32)
        buf[:P] = np.asarray(req.prompt, np.int32)
        # int() coercions: an np-int scalar here would trace a distinct dtype
        # and trip the admit sentinel — the guard's first real catch
        self.state = self._admit_fn(self.state, slot, buf, P,
                                    int(req.max_new_tokens), int(req.rid))
        self.clock += self.cost.admit_s

    def _prefill_one(self, rec: RequestRecord) -> None:
        self.state, tok = self._prefill_fn(self.params, self.state, rec.slot)
        self.n_prefill_dispatches += 1
        self.clock += self.cost.prefill_cost(self.prefill_chunk)
        done_now = min(self.prefill_chunk, rec.n_prompt - len_prefilled(rec))
        rec.prefill_host = len_prefilled(rec) + done_now
        if rec.prefill_host >= rec.n_prompt:
            t = int(tok)                                 # host sync: 1st token
            rec.tokens.append(t)
            rec.tok_times.append(self.clock)
            rec.first_tok_s = self.clock
            if rec.max_new <= 1 or (self.eos_id is not None
                                    and t == self.eos_id):
                self._complete(rec)

    def _decode_tick(self) -> None:
        active = [s for s, r in self.by_slot.items()
                  if r.first_tok_s is not None and r.done_s is None]
        self.state, toks, finished = self._decode_fn(self.params, self.state)
        self.n_decode_dispatches += 1
        self.clock += self.cost.decode_cost(self.n_slots)
        self.slots.note_decode_tick(len(active))
        toks = np.asarray(toks)
        finished = np.asarray(finished)
        for slot in active:
            rec = self.by_slot[slot]
            rec.tokens.append(int(toks[slot]))
            rec.tok_times.append(self.clock)
            if finished[slot]:
                self._complete(rec)

    def _complete(self, rec: RequestRecord) -> None:
        rec.done_s = self.clock
        self.completed.append(rec)
        if self.mode == "continuous":
            self.slots.release(rec.slot)
            del self.by_slot[rec.slot]

    def tick(self) -> None:
        """One scheduler round: admissions, prefill chunks, one decode step."""
        if self.mode == "static":
            self._tick_static()
        else:
            self._tick_continuous()

    def _tick_continuous(self) -> None:
        while self.queue and self.slots.n_free:
            self._admit_one(self.queue.popleft())
        budget = self.prefill_chunks_per_tick
        for slot in sorted(self.by_slot):
            if budget == 0:
                break
            rec = self.by_slot[slot]
            if rec.done_s is None and len_prefilled(rec) < rec.n_prompt:
                self._prefill_one(rec)
                budget -= 1
        if any(r.first_tok_s is not None and r.done_s is None
               for r in self.by_slot.values()):
            self._decode_tick()

    def _tick_static(self) -> None:
        if not self._wave and self.queue:
            # admit a wave, then prefill it COMPLETELY before any decode —
            # the lock-step baseline's head-of-line blocking, made explicit
            while self.queue and self.slots.n_free:
                self._admit_one(self.queue.popleft())
            self._wave = sorted(self.by_slot)
            for slot in self._wave:
                rec = self.by_slot[slot]
                while rec.done_s is None and len_prefilled(rec) < rec.n_prompt:
                    self._prefill_one(rec)
            return
        if any(r.done_s is None for r in self.by_slot.values()):
            self._decode_tick()
        if self._wave and all(self.by_slot[s].done_s is not None
                              for s in self._wave):
            for slot in self._wave:
                self.slots.release(slot)
                del self.by_slot[slot]
            self._wave = []

    # -------------------------------------------------------------- driving

    def run_trace(self, requests: List[Request]) -> List[RequestRecord]:
        """Feed a timed trace through the engine on the virtual clock and run
        to completion. Requests are delivered when the clock passes their
        arrival; the clock jumps over idle gaps."""
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        i = 0
        t_wall = time.perf_counter()
        while i < len(reqs) or self.has_work:
            while i < len(reqs) and reqs[i].arrival_s <= self.clock:
                self.submit(reqs[i])
                i += 1
            if not self.has_work:
                self.clock = max(self.clock, reqs[i].arrival_s)
                continue
            self.tick()
        self.wall_s = time.perf_counter() - t_wall
        return self.completed

    # ---------------------------------------------------------------- stats

    def stats(self) -> Dict[str, float]:
        """p50/p99 TTFT, per-token latency, sustained throughput, occupancy —
        all on the virtual clock (deterministic for a given trace)."""
        recs = [r for r in self.completed if r.first_tok_s is not None]
        if not recs:
            return {"completed": 0}
        ttft = np.array([r.ttft_s for r in recs])
        tok_lat = np.array([r.mean_tok_latency_s for r in recs
                            if r.mean_tok_latency_s is not None])
        total_tokens = sum(len(r.tokens) for r in recs)
        t0 = min(r.arrival_s for r in recs)
        t1 = max(r.done_s for r in recs)
        makespan = max(t1 - t0, 1e-9)
        return {
            "completed": len(recs),
            "total_tokens": total_tokens,
            "makespan_s": makespan,
            "tok_per_s": total_tokens / makespan,
            "qps": len(recs) / makespan,
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p99_s": float(np.percentile(ttft, 99)),
            "tok_latency_mean_s": float(tok_lat.mean()) if tok_lat.size else 0.0,
            "tok_latency_p99_s": (float(np.percentile(tok_lat, 99))
                                  if tok_lat.size else 0.0),
            "occupancy": self.slots.mean_occupancy,
            "decode_dispatches": self.n_decode_dispatches,
            "prefill_dispatches": self.n_prefill_dispatches,
            "wall_s": getattr(self, "wall_s", 0.0),
        }


def len_prefilled(rec: RequestRecord) -> int:
    """Host mirror of the device `prefilled` counter (no sync needed: chunk
    size and prompt length are host-known)."""
    return getattr(rec, "prefill_host", 0)
