"""Seeded request-trace generator for the serving benchmark.

Per-region inhomogeneous Poisson arrivals via thinning: each region draws a
homogeneous candidate stream at its peak rate and keeps candidates with
probability rate(t)/peak. The rate curve is diurnal (sinusoid with a
per-region phase offset, so regions peak at different times — the
cross-region serving story), optionally with periodic bursts, and region
shares follow a Zipf skew. Everything derives from one `numpy` SeedSequence,
so a spec is its trace: same spec -> identical requests, arrival times,
prompts, and lengths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

from repro.serve.engine import Request


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    horizon_s: float = 60.0
    base_rps: float = 2.0                 # mesh-wide mean arrival rate
    n_regions: int = 4
    region_skew: float = 1.0              # Zipf exponent over regions (0=flat)
    diurnal_depth: float = 0.5            # amplitude in [0, 1)
    diurnal_period_s: float = 30.0
    burst_factor: float = 3.0             # rate multiplier inside a burst
    burst_every_s: float = 0.0            # 0 disables bursts
    burst_dur_s: float = 2.0
    prompt_len: tuple = (4, 24)           # inclusive range
    gen_len: tuple = (4, 32)
    vocab: int = 512
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.diurnal_depth < 1.0:
            raise ValueError("diurnal_depth must be in [0, 1)")
        if self.n_regions < 1 or self.base_rps <= 0 or self.horizon_s <= 0:
            raise ValueError("need n_regions >= 1, base_rps > 0, horizon > 0")


def region_weights(spec: TrafficSpec) -> np.ndarray:
    w = np.array([(r + 1.0) ** -spec.region_skew
                  for r in range(spec.n_regions)])
    return w / w.sum()


def rate_at(spec: TrafficSpec, region: int, t: float,
            weights: np.ndarray) -> float:
    """Arrival rate (req/s) of `region` at time t."""
    phase = region / max(spec.n_regions, 1)
    diurnal = 1.0 + spec.diurnal_depth * math.sin(
        2.0 * math.pi * (t / spec.diurnal_period_s + phase))
    rate = spec.base_rps * float(weights[region]) * diurnal
    if spec.burst_every_s > 0.0:
        if (t % spec.burst_every_s) < spec.burst_dur_s:
            rate *= spec.burst_factor
    return rate


def generate(spec: TrafficSpec) -> List[Request]:
    """The trace for `spec`: Requests sorted by arrival time, rids assigned
    in arrival order."""
    weights = region_weights(spec)
    root = np.random.SeedSequence(spec.seed)
    arrivals = []                                 # (t, region)
    for region, child in enumerate(root.spawn(spec.n_regions)):
        rng = np.random.default_rng(child)
        peak = (spec.base_rps * float(weights[region])
                * (1.0 + spec.diurnal_depth)
                * (spec.burst_factor if spec.burst_every_s > 0.0 else 1.0))
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / peak))
            if t >= spec.horizon_s:
                break
            if rng.random() < rate_at(spec, region, t, weights) / peak:
                arrivals.append((t, region))
    arrivals.sort()
    body = np.random.default_rng(root.spawn(1)[0])
    out = []
    for rid, (t, region) in enumerate(arrivals):
        P = int(body.integers(spec.prompt_len[0], spec.prompt_len[1] + 1))
        G = int(body.integers(spec.gen_len[0], spec.gen_len[1] + 1))
        prompt = body.integers(0, spec.vocab, size=P).astype(np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=G,
                           region=region, arrival_s=t))
    return out
