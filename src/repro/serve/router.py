"""Region-affine request routing over the simulated cross-region WAN.

`RegionRouter` places model replicas on regions of a `core.network.Topology`
and prices every request hop (origin region -> replica) and response hop
(replica -> origin) with `RoutePlanner.point_latency_at` — the same
latency + bytes/effective-bandwidth cost the training planner uses, replayed
against the topology's link dynamics. When a region's links go dark the
router fails over to the cheapest reachable replica; when NO replica is
reachable the request is HELD and retried at the next dynamics transition
(`LinkDynamics.next_change`), never dropped.

`RoutedCluster` runs one `ServeEngine` per replica over a routed trace.
Routing decisions depend only on each request's arrival instant (plus a
deterministic cumulative-load tiebreak), so the cluster routes all arrivals
in order, then drains each engine independently on its own virtual clock —
no cross-engine event loop needed. Response hops are priced at each
request's completion time, so a reply that finishes mid-outage pays the
wait until the link returns.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.network import RoutePlanner, Topology
from repro.serve.engine import Request, RequestRecord, ServeEngine


class RegionRouter:
    """Maps an origin region to the best replica at a given wall-time."""

    def __init__(self, topo: Topology, replica_regions: Sequence[int], *,
                 req_bytes: int = 2048, resp_base_bytes: int = 256,
                 resp_bytes_per_tok: int = 8, load_penalty_s: float = 0.002,
                 max_retries: int = 64):
        if not replica_regions:
            raise ValueError("need at least one replica region")
        m = topo.num_workers
        for r in replica_regions:
            if not 0 <= r < m:
                raise ValueError(f"replica region {r} outside mesh of {m}")
        self.topo = topo
        self.replica_regions = tuple(int(r) for r in replica_regions)
        self.planner = RoutePlanner(topo, hub_failover=True,
                                    ref_bytes=req_bytes)
        self.req_bytes = int(req_bytes)
        self.resp_base_bytes = int(resp_base_bytes)
        self.resp_bytes_per_tok = int(resp_bytes_per_tok)
        self.load_penalty_s = float(load_penalty_s)
        self.max_retries = int(max_retries)
        # the affinity baseline: which replica each origin prefers on the
        # UNDEGRADED topology — deviations from it at route time are failovers
        static = RoutePlanner(dataclasses.replace(topo, dynamics=None),
                              ref_bytes=req_bytes)
        self.primary: Dict[int, int] = {}
        for origin in range(m):
            best, best_lat = 0, float("inf")
            for idx, region in enumerate(self.replica_regions):
                lat = static.point_latency_at(0.0, origin, region,
                                              self.req_bytes)
                if lat is not None and lat < best_lat:
                    best, best_lat = idx, lat
            self.primary[origin] = best

    def route(self, origin: int, t: float,
              loads: Sequence[int]) -> Optional[Tuple[int, float]]:
        """Cheapest reachable replica for a request from `origin` at t:
        (replica_idx, request-hop latency). `loads` adds a deterministic
        per-queued-request penalty so equidistant replicas share traffic.
        None when every replica is unreachable (caller holds + retries)."""
        best = None
        for idx, region in enumerate(self.replica_regions):
            lat = self.planner.point_latency_at(t, origin, region,
                                                self.req_bytes)
            if lat is None:
                continue
            score = lat + self.load_penalty_s * loads[idx]
            if best is None or score < best[0]:
                best = (score, idx, lat)
        if best is None:
            return None
        return best[1], best[2]

    def response_latency(self, replica_idx: int, origin: int, t: float,
                         n_tokens: int) -> Tuple[float, float]:
        """(hop latency, held wait) for a reply of `n_tokens` leaving
        `replica_idx` at t. If the return path is dark at t, the reply waits
        for the next dynamics transition (accumulated in the wait term)."""
        region = self.replica_regions[replica_idx]
        nbytes = self.resp_base_bytes + self.resp_bytes_per_tok * n_tokens
        wait = 0.0
        for _ in range(self.max_retries):
            lat = self.planner.point_latency_at(t + wait, region, origin,
                                                nbytes)
            if lat is not None:
                return lat, wait
            nxt = self.next_retry(t + wait)
            if nxt is None or nxt <= t + wait:
                break
            wait = nxt - t
        raise RuntimeError(
            f"reply {region}->{origin} unroutable past t={t + wait:.3f}s "
            f"(no further link transitions)")

    def next_retry(self, t: float) -> Optional[float]:
        """Next instant any link's state changes after t (when a held request
        should re-attempt routing); None if the topology is static."""
        dyn = self.topo.dynamics
        if dyn is None:
            return None
        m = self.topo.num_workers
        pairs = [(i, j) for i in range(m) for j in range(m) if i != j]
        return dyn.next_change(pairs, t)


@dataclasses.dataclass
class ClusterStats:
    completed: int
    dropped: int
    failovers: int
    held: int
    ttft_p50_s: float
    ttft_p99_s: float
    tok_per_s: float
    per_engine: List[Dict[str, float]]


class RoutedCluster:
    """One ServeEngine per replica behind a RegionRouter. `run(requests)`
    routes every arrival (holding + retrying through outages — zero drops),
    drains each engine, then prices response hops at completion time."""

    def __init__(self, cfg, params, topo: Topology,
                 replica_regions: Sequence[int], *,
                 router_kwargs: Optional[dict] = None, seed: int = 0,
                 **engine_kwargs):
        self.router = RegionRouter(topo, replica_regions,
                                   **(router_kwargs or {}))
        self.engines = [
            ServeEngine(cfg, params, seed=seed + 1000 * i, **engine_kwargs)
            for i in range(len(replica_regions))
        ]
        self.failovers = 0
        self.held = 0

    def run(self, requests: Sequence[Request]) -> List[RequestRecord]:
        router = self.router
        loads = [0] * len(self.engines)
        assigned: List[List[Request]] = [[] for _ in self.engines]
        meta: Dict[int, Tuple[int, float, float]] = {}   # rid -> (idx, lat, held)
        for req in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
            t, held_s = req.arrival_s, 0.0
            hit = router.route(req.region, t, loads)
            for _ in range(router.max_retries):
                if hit is not None:
                    break
                nxt = router.next_retry(t)
                if nxt is None or nxt <= t:
                    raise RuntimeError(
                        f"request {req.rid} from region {req.region} is "
                        f"permanently unroutable at t={t:.3f}s")
                held_s += nxt - t
                t = nxt
                hit = router.route(req.region, t, loads)
            if hit is None:
                raise RuntimeError(f"request {req.rid} unroutable after "
                                   f"{router.max_retries} retries")
            idx, lat = hit
            loads[idx] += 1
            if held_s > 0.0:
                self.held += 1
            if idx != router.primary[req.region]:
                self.failovers += 1
            meta[req.rid] = (idx, lat, held_s)
            assigned[idx].append(
                dataclasses.replace(req, arrival_s=t + lat))

        out: List[RequestRecord] = []
        for idx, eng in enumerate(self.engines):
            for rec in eng.run_trace(assigned[idx]):
                ridx, lat, held_s = meta[rec.rid]
                rec.replica = ridx
                rec.req_hop_s = lat
                rec.held_s = held_s
                # ttft_s/done are measured from the ORIGINAL arrival: restore
                # it and fold the held wait + request hop into the timeline
                rec.arrival_s -= lat + held_s
                resp, wait = router.response_latency(
                    ridx, rec.region, rec.done_s, len(rec.tokens))
                rec.resp_hop_s = resp + wait
                out.append(rec)
        return out

    def stats(self, records: Sequence[RequestRecord]) -> ClusterStats:
        import numpy as np
        done = [r for r in records if r.done_s is not None]
        ttft = np.array([r.ttft_s for r in done]) if done else np.zeros(1)
        total_tok = sum(len(r.tokens) for r in done)
        t0 = min((r.arrival_s for r in done), default=0.0)
        t1 = max((r.done_s + r.resp_hop_s for r in done), default=1e-9)
        return ClusterStats(
            completed=len(done),
            dropped=0,
            failovers=self.failovers,
            held=self.held,
            ttft_p50_s=float(np.percentile(ttft, 50)),
            ttft_p99_s=float(np.percentile(ttft, 99)),
            tok_per_s=total_tok / max(t1 - t0, 1e-9),
            per_engine=[e.stats() for e in self.engines],
        )
