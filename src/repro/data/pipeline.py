"""Deterministic synthetic non-IID LM data pipeline (offline C4 stand-in).

Each worker (datacenter) draws from its own sparse Zipfian Markov chain over the
vocabulary — per-worker transition structure differs (non-IID, paper §II-A) but
shares a global backbone so a consensus model is learnable. Generation is a pure
function of (worker_id, step) — infinitely replayable, shardable, resumable with no
state files, and cheap enough to never bottleneck the host.

A real deployment would swap this module for a C4/TFDS loader; the trainer only
sees `next_batch(step) -> {tokens, labels}`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _gen_batch(succ, weights, seed_arr, step_arr, batch_size, seq_len):
    """One (B, S) batch as a pure function of (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed_arr), step_arr)
    k0, k1 = jax.random.split(key)
    state = jax.random.randint(k0, (batch_size,), 0, succ.shape[0])
    choice_keys = jax.random.split(k1, seq_len + 1)

    def gen(state, k):
        idx = jax.random.categorical(
            k, jnp.log(weights)[None].repeat(batch_size, 0))
        nxt = succ[state, idx]
        return nxt, nxt

    _, toks = jax.lax.scan(gen, state, choice_keys)
    toks = jnp.moveaxis(toks, 0, 1)             # (B, S+1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@functools.lru_cache(maxsize=None)
def _jit_gen_batch():
    return jax.jit(_gen_batch, static_argnums=(4, 5))


@functools.lru_cache(maxsize=None)
def _jit_gen_segment():
    # vmap over the step axis: segment[i] == batch(t0 + i) leaf-for-leaf
    # (jax.random is vmap-invariant), in one dispatch instead of n
    return jax.jit(jax.vmap(_gen_batch, in_axes=(None, None, None, 0, None, None)),
                   static_argnums=(4, 5))


@dataclasses.dataclass
class MarkovCorpus:
    vocab: int
    branch: int = 8             # successors per token
    seed: int = 0
    worker_id: int = 0
    noniid_frac: float = 0.25   # fraction of rows rewired per worker

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        V, Br = self.vocab, self.branch
        # global backbone: successor table (V, Br) + Zipf weights
        self.succ = rng.randint(0, V, size=(V, Br)).astype(np.int32)
        if self.noniid_frac > 0 and self.worker_id >= 0:
            wrng = np.random.RandomState(self.seed + 7919 * (self.worker_id + 1))
            n_rewire = int(V * self.noniid_frac)
            rows = wrng.choice(V, size=n_rewire, replace=False)
            self.succ[rows] = wrng.randint(0, V, size=(n_rewire, Br))
        w = 1.0 / np.arange(1, Br + 1) ** 1.2
        self.weights = jnp.asarray(w / w.sum(), jnp.float32)
        self.succ_j = jnp.asarray(self.succ)

    @property
    def _seed32(self) -> int:
        return (self.seed * 1_000_003 + self.worker_id) % (1 << 31)

    def batch(self, step: int, batch_size: int, seq_len: int):
        """Pure function of (worker, step): {tokens, labels} (B, S) int32."""
        return _jit_gen_batch()(self.succ_j, self.weights, self._seed32, step,
                                batch_size, seq_len)

    def segment(self, t0: int, n: int, batch_size: int, seq_len: int):
        """Prefetch `n` consecutive batches in ONE dispatch: {tokens, labels}
        with shape (n, B, S). Vmapped over the step axis of the same generator
        as `batch`, so segment(t0, n)[i] == batch(t0 + i) leaf-for-leaf —
        segment boundaries never change the data (pure function of
        (worker, step); pinned by tests/test_pipeline.py).

        Generation is padded to the next power-of-two step count and sliced
        (steps are independent, so the first n rows are unchanged): protocol
        event gaps vary run-long and a compile per distinct length would
        dominate the prefetch."""
        m = 1 << max(0, n - 1).bit_length()
        steps = jnp.arange(t0, t0 + m)
        out = _jit_gen_segment()(self.succ_j, self.weights, self._seed32,
                                 steps, batch_size, seq_len)
        return out if m == n else jax.tree.map(lambda x: x[:n], out)


def make_worker_streams(num_workers: int, vocab: int, *, seed: int = 0,
                        noniid_frac: float = 0.25):
    """One non-IID corpus per worker/datacenter."""
    return [MarkovCorpus(vocab=vocab, seed=seed, worker_id=m,
                         noniid_frac=noniid_frac) for m in range(num_workers)]


def stacked_batch(streams, step: int, batch_size: int, seq_len: int):
    """Worker-stacked batch: leaves (M, B, S) — feeds the worker-dim train step."""
    batches = [s.batch(step, batch_size, seq_len) for s in streams]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def stacked_segment(streams, t0: int, n: int, batch_size: int, seq_len: int):
    """Segment prefetch for the scanned execution engine: leaves (n, M, B, S) —
    step-major so `lax.scan` slices one worker-stacked batch per iteration.
    Equals stacking `stacked_batch(streams, t0 + i)` over i, in M dispatches
    instead of n * M."""
    segs = [s.segment(t0, n, batch_size, seq_len) for s in streams]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *segs)
