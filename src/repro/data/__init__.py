from repro.data.pipeline import (MarkovCorpus, make_worker_streams,  # noqa: F401
                                 stacked_batch, stacked_segment)
