"""Tiny dense LM shared by the benchmark harnesses (convergence, sweep) and
the spec-driven smoke grid. Registered so `ModelRef(arch="bench_tiny")`
resolves through the ordinary config registry instead of an inline
ModelConfig duplicated per benchmark. CPU-tractable: ~4 layers x 96 dims."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(name="bench_tiny", family="dense", n_layers=4, d_model=96,
                     n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                     compute_dtype="float32",
                     source="synthetic benchmark model (no external card)")
