"""command-r-35b — dense GQA, no-bias, 256k vocab. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    attn_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    long_decode_window=4096,   # long_500k sliding-window variant (DESIGN.md)
    source="hf:CohereForAI/c4ai-command-r-v01",
)
