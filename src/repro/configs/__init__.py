"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (CoCoDCConfig, InputShape, INPUT_SHAPES, ModelConfig,
                                MoEConfig)

ARCH_IDS = [
    "dbrx_132b",
    "llava_next_mistral_7b",
    "qwen3_0_6b",
    "rwkv6_3b",
    "granite_moe_3b_a800m",
    "llama3_405b",
    "phi3_medium_14b",
    "seamless_m4t_large_v2",
    "command_r_35b",
    "recurrentgemma_9b",
    "paper_150m",
    "bench_tiny",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}


def canonical(arch_id: str) -> str:
    a = arch_id.replace("-", "_").replace(".", "_")
    if a not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_IDS)}")
    return a


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.CONFIG


__all__ = ["ARCH_IDS", "get_config", "canonical", "ModelConfig", "MoEConfig",
           "CoCoDCConfig", "InputShape", "INPUT_SHAPES"]
