"""The paper's own experimental model: ~150M-param LLaMA-style decoder, 12 layers
(paper §IV-A), C4 LM task, seq 1024, global batch 256."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-150m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab=32000,
    source="CoCoDC paper §IV-A (LLaMA-style, ~150M)",
)
