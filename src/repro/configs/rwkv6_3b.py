"""rwkv6-3b — RWKV-6 "Finch", attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                # 2560 / head_dim 64
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892",
)
