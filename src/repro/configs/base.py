"""Model/config dataclasses for the repro framework.

Every assigned architecture gets a ``ModelConfig`` in ``src/repro/configs/<id>.py``.
Configs are plain frozen dataclasses — no jax import at module scope so importing a
config never touches device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor only matters for dropping implementations; the dense-dispatch
    # einsum path used here never drops tokens.
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    """Architecture config.

    family:
      dense   — decoder-only transformer (GQA + RoPE + SwiGLU)
      moe     — dense skeleton with MoE FFN every layer
      ssm     — RWKV-6 (attention free)
      hybrid  — RecurrentGemma (RG-LRU + local attention, pattern)
      vlm     — dense decoder consuming projected patch embeddings (frontend stubbed)
      audio   — encoder-decoder; encoder consumes frame embeddings (frontend stubbed)
    """
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window attention; None = full causal. For hybrid archs this is the
    # local-attention window.
    attn_window: Optional[int] = None
    # window used ONLY for the long_500k decode variant of natively-full-attention
    # archs (the allowed block-sparse/sliding carve-out, DESIGN.md §4). None = the
    # arch has no long-decode variant (either native window/SSM covers it, or skip).
    long_decode_window: Optional[int] = None
    # hybrid pattern, e.g. ("rglru","rglru","attn") repeated; only for family=hybrid
    block_pattern: Tuple[str, ...] = ()
    # encoder layers (family=audio enc-dec); n_layers is then the decoder depth
    n_enc_layers: int = 0
    # rwkv6
    rwkv_head_dim: int = 64
    # vlm / audio stub frontends: number of prefix embedding tokens & their dim
    n_prefix_tokens: int = 0
    prefix_dim: int = 0
    # citation for the config (model card / paper)
    source: str = ""
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """sub-quadratic decode path: SSM/hybrid natively; dense/moe/vlm only when a
        sliding window is configured (block-sparse carve-out, see DESIGN.md)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family == "audio":
            return False  # enc-dec full-attention decoder: skip long_500k (DESIGN.md)
        return self.attn_window is not None or self.long_decode_window is not None

    def reduced(self) -> "ModelConfig":
        """Reduced smoke-test variant of the same family (<=2 layers, d_model<=512,
        <=4 experts) per the deliverable-(f) spec."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, 2))
        moe = None
        if self.moe is not None:
            moe = MoEConfig(num_experts=min(self.moe.num_experts, 4),
                            top_k=min(self.moe.top_k, 2))
        pattern = self.block_pattern[:3] if self.block_pattern else ()
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 if not pattern else len(pattern),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            moe=moe,
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            long_decode_window=min(self.long_decode_window, 64)
            if self.long_decode_window else None,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_prefix_tokens=min(self.n_prefix_tokens, 8) if self.n_prefix_tokens else 0,
            prefix_dim=min(self.prefix_dim, 64) if self.prefix_dim else 0,
            rwkv_head_dim=min(self.rwkv_head_dim, 32),
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class CoCoDCConfig:
    """Protocol hyperparameters (paper §IV defaults)."""
    num_workers: int = 4           # M
    local_steps: int = 100         # H
    num_fragments: int = 4         # K
    overlap_depth: int = 5         # tau
    mixing_alpha: float = 0.5      # Streaming DiLoCo blending (Eq. 3)
    comp_lambda: float = 0.5       # delay compensation strength (Eq. 7)
    net_utilization: float = 0.4   # gamma (Eq. 9)
    eq4_sign: float = 1.0          # +1 = self-consistent form; -1 = literal Eq. (4)
    outer_lr: float = 0.7
    outer_momentum: float = 0.9    # Nesterov (DiLoCo defaults)
    strided_fragments: bool = True # Streaming DiLoCo strided layer->fragment pattern
    # fragmentation strategy override: "" derives from strided_fragments
    # ("strided"/"contiguous"); "skewed" builds size-skewed fragments
    # (geometric byte shares) so per-fragment WAN costs differ enough for
    # Algorithm-2 link pricing to flip selections (ROADMAP PR 2 finding)
    fragment_strategy: str = ""
    # WAN payload dtype for the pseudo-gradient all-reduce. bf16 halves the
    # cross-region bytes (beyond-paper optimization, §Perf iteration 4);
    # outer-optimizer accumulation stays f32 either way.
    sync_dtype: str = "float32"
    # top-k magnitude sparsification of pseudo-gradients before the WAN
    # all-reduce (beyond-paper): 1.0 = dense. Accounted bytes scale by
    # 2*frac (values + indices).
    sync_topk_frac: float = 1.0
    # Algorithm-2 link-aware pricing (beyond-paper): rank fragments by
    # change-rate per WAN-second (R_p / T_s,p) instead of raw R_p, so cheaper
    # fragments win ties on heterogeneous topologies. Off = literal Eq. 12.
    link_pricing: bool = False
    # Routed communication plans (beyond-paper): "static" keeps the fixed
    # ring/hierarchical cost formulas bitwise (PR 3 behavior); "routed" plans
    # every collective over the CURRENT link state — deterministic multi-hop
    # min-cost routes, re-planned at each LinkDynamics edge — and refreshes
    # the Algorithm-2 cost vector from the active plan.
    routing: str = "static"
    # With routing="routed": while the declared hub's links are out,
    # deterministically re-elect the next-best-connected region as hub
    # (restored on recovery) and drop fully dark regions from the collective
    # instead of stalling it.
    hub_failover: bool = False
    # Re-derive Eq. 9's target sync count N (and Eq. 10's h) once per outer
    # round from the MEASURED durations of recent transfers, so the cocodc
    # initiation cadence tracks the network the run actually sees.
    adaptive_resync: bool = False
    # Wire-compression codec for the pseudo-gradient payload (beyond-paper,
    # Streaming-DiLoCo-style compressed outer deltas): "none" keeps the
    # f32/sync_dtype wire format bitwise; "int8"/"int4" quantize each delta
    # per `codec_block`-element block (absmax scaling, kernels/delta_codec)
    # before it crosses the WAN. The codec subsumes sync_dtype accounting —
    # whatever dtype the payload was in, the wire carries codes + scales.
    wire_codec: str = "none"
    # quantization granularity: one f32 absmax scale ships per `codec_block`
    # consecutive elements of each leaf (wire overhead 4/codec_block B/elem)
    codec_block: int = 256
    # error feedback: keep the per-element quantization residual locally and
    # fold it into the same elements' next initiation, driving the cumulative
    # quantization bias to ~0 over repeated syncs (EF-SGD)
    codec_error_feedback: bool = True
    # WAN channel scheduler (beyond-paper traffic plane). "serial" keeps the
    # fixed `concurrent_collectives` channel queue bitwise (PR 6 behavior);
    # "fairshare" drops the queue entirely: every in-flight collective shares
    # link capacity via max-min water-filling (core/network.FairShareSim), so
    # a transfer's completion depends on who shares its bottleneck links and
    # Eq. 9's measured durations include real contention.
    channel_scheduler: str = "serial"
    # With routing="routed": split every logical link's payload across up to
    # k edge-disjoint min-cost paths (inverse-cost byte shares; completion =
    # slowest subflow). 1 = single-path (bitwise-pinned arithmetic).
    multipath_k: int = 1
    # Fused outer-update plane: route every protocol transition through the
    # flat fragment plane (core/flatplane.py) + kernels/outer_update — one
    # Pallas dispatch per fragment per stage instead of one per leaf per
    # stage, and flat (rows, LANES) in-flight/residual buffers instead of
    # full-model pytrees. Off keeps the per-leaf path bitwise (PR 7 goldens);
    # on pins bitwise against its own pure-jnp oracle. Flat-plane semantics:
    # top-k sparsification and codec blocks span the fragment's concatenated
    # leaves rather than respecting leaf boundaries.
    fused_updates: bool = False
