"""llava-next-mistral-7b — VLM; Mistral-7B backbone + anyres patch-embedding stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1_000_000.0,
    attn_window=4096,          # Mistral sliding window
    # anyres tiling: base 24x24 grid + 4 tiles -> 2880 patch tokens, projected from
    # the (stubbed) CLIP/SigLIP hidden size 1024 by a 2-layer MLP projector.
    n_prefix_tokens=2880,
    prefix_dim=1024,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
