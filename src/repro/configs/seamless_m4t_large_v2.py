"""seamless-m4t-large-v2 — enc-dec multimodal backbone; speech frontend stubbed to
frame embeddings per the assignment carve-out. [arXiv:2308.11596]

24L is interpreted per the model card as 24 encoder layers + 24 decoder layers
(w2v-BERT speech encoder / text decoder are each 24L in the reference card).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,               # decoder depth
    n_enc_layers=24,           # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    attn_bias=True,
    # stub frontend: mel->conv feature extractor replaced by precomputed frame
    # embeddings (d=160 mel-ish features projected in-model to d_model)
    n_prefix_tokens=1024,      # encoder frames for the dry-run input spec
    prefix_dim=160,
    source="arXiv:2308.11596",
)
