"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attention 1:2 (attn:lru).
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,              # MQA in the local-attention blocks
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    attn_window=2048,          # local attention window
    block_pattern=("rglru", "rglru", "attn"),
    source="arXiv:2402.19427",
)
