"""Serving benchmark: continuous batching vs lock-step, and routed failover.

    PYTHONPATH=src python -m benchmarks.serving [--smoke]

Two scenarios on the virtual clock (deterministic for a given trace):

  A. continuous_vs_static — the same seeded traffic trace through a
     `ServeEngine` in both modes on `bench_tiny`. Reports p50/p99 TTFT,
     per-token latency, sustained tok/s, and slot occupancy.
  B. routed_failover — a RoutedCluster on a hub_spoke mesh whose hub goes
     dark mid-trace (`hub_failure` dynamics). Requests failover to the
     surviving replica; requests from fully-darkened regions are HELD and
     retried at the link transition, never dropped.

Gates (--smoke exits 1 when violated; benchmarks/run.py --fast and the
serve-smoke CI job run this):

  * continuous sustains >= {SPEEDUP_GATE}x the static-mode tok/s on the
    smoke trace at no worse p99 TTFT;
  * the failover scenario completes EVERY admitted request through the hub
    outage (zero drops) and the outage is non-trivially exercised
    (failovers + held > 0);
  * the decode step of every engine was traced exactly once (zero
    recompiles across batch churn).
"""
from __future__ import annotations

import argparse
import sys

import jax

from benchmarks.common import Timer, emit, save_json

SPEEDUP_GATE = 1.3


def _model():
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config("bench_tiny")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def scenario_ab(cfg, params, *, smoke: bool):
    """Scenario A: one trace, both modes."""
    from repro.serve import ServeEngine, TrafficSpec, generate

    spec = TrafficSpec(horizon_s=12.0 if smoke else 30.0, base_rps=6.0,
                       n_regions=4, seed=7, prompt_len=(4, 24),
                       gen_len=(2, 48), vocab=cfg.vocab)
    reqs = generate(spec)
    out = {"n_requests": len(reqs)}
    failures = []
    for mode in ("continuous", "static"):
        eng = ServeEngine(cfg, params, n_slots=8, cache_len=96, max_prompt=24,
                          prefill_chunk=8, mode=mode, temperature=0.8, seed=0)
        with Timer() as tm:
            eng.run_trace(reqs)
        s = eng.stats()
        s["traces"] = eng.decode_trace_count()
        out[mode] = s
        emit(f"serving/{mode}", tm.dt * 1e6,
             f"tok_s={s['tok_per_s']:.1f};occ={s['occupancy']:.2f};"
             f"ttft_p99_ms={s['ttft_p99_s']*1e3:.0f}")
        if s["completed"] != len(reqs):
            failures.append(f"{mode}: completed {s['completed']}/{len(reqs)}")
        if s["traces"] != 1:
            failures.append(f"{mode}: decode traced {s['traces']}x (want 1)")
    speedup = out["continuous"]["tok_per_s"] / out["static"]["tok_per_s"]
    out["speedup"] = speedup
    emit("serving/speedup", 0.0, f"continuous/static={speedup:.2f}")
    if speedup < SPEEDUP_GATE:
        failures.append(f"continuous/static speedup {speedup:.2f} < "
                        f"{SPEEDUP_GATE} gate")
    if out["continuous"]["ttft_p99_s"] > out["static"]["ttft_p99_s"]:
        failures.append(
            f"continuous p99 TTFT {out['continuous']['ttft_p99_s']:.3f}s "
            f"worse than static {out['static']['ttft_p99_s']:.3f}s")
    return out, failures


def scenario_failover(cfg, params, *, smoke: bool):
    """Scenario B: routed cluster through a hub outage, zero drops."""
    from repro.core.network import apply_dynamics, generate_mesh
    from repro.serve import RoutedCluster, TrafficSpec, generate

    horizon = 20.0 if smoke else 45.0
    topo = generate_mesh(4, "hub_spoke", seed=0)
    # the hub's links go dark for half the trace; replicas sit on two spokes
    # so hub-region requests must cross a (possibly dark) link -> held+retried
    topo = apply_dynamics(
        topo, f"hub_failure:start={horizon * 0.25}:dur={horizon * 0.5}",
        seed=0)
    replicas = [(topo.hub + 1) % 4, (topo.hub + 2) % 4]
    spec = TrafficSpec(horizon_s=horizon, base_rps=3.0, n_regions=4, seed=3,
                       prompt_len=(4, 16), gen_len=(4, 24), vocab=cfg.vocab)
    reqs = generate(spec)
    cluster = RoutedCluster(cfg, params, topo, replicas, n_slots=4,
                            cache_len=48, max_prompt=16, prefill_chunk=8,
                            mode="continuous", temperature=0.5)
    with Timer() as tm:
        records = cluster.run(reqs)
    st = cluster.stats(records)
    out = {"n_requests": len(reqs), "completed": st.completed,
           "dropped": st.dropped, "failovers": st.failovers, "held": st.held,
           "ttft_p50_s": st.ttft_p50_s, "ttft_p99_s": st.ttft_p99_s,
           "tok_per_s": st.tok_per_s, "replicas": replicas, "hub": topo.hub}
    emit("serving/failover", tm.dt * 1e6,
         f"completed={st.completed}/{len(reqs)};failovers={st.failovers};"
         f"held={st.held};ttft_p99_ms={st.ttft_p99_s*1e3:.0f}")
    failures = []
    if st.completed != len(reqs):
        failures.append(f"failover: completed {st.completed}/{len(reqs)} "
                        f"(drops through the outage)")
    if st.failovers + st.held == 0:
        failures.append("failover: outage never exercised (no failovers or "
                        "held requests) — scenario is vacuous")
    for i, es in enumerate(st.per_engine):
        if es.get("completed", 0) and es.get("decode_dispatches"):
            tr = cluster.engines[i].decode_trace_count()
            if tr != 1:
                failures.append(f"failover engine{i}: decode traced {tr}x")
    return out, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace + hard gates (CI)")
    args = ap.parse_args(argv)

    cfg, params = _model()
    ab, fail_a = scenario_ab(cfg, params, smoke=args.smoke)
    fo, fail_b = scenario_failover(cfg, params, smoke=args.smoke)
    payload = {"continuous_vs_static": ab, "routed_failover": fo,
               "speedup_gate": SPEEDUP_GATE}
    path = save_json("serving/serving", payload)
    print(f"# wrote {path}", flush=True)
    failures = fail_a + fail_b
    for f in failures:
        print(f"# GATE FAIL: {f}", flush=True)
    if failures:
        return 1
    print("# serving gates passed", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
