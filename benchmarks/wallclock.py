"""Wall-clock efficiency under the WAN model (paper §IV-B discussion): DiLoCo's
blocking synchronization vs Streaming/CoCoDC's overlapped transmission, across
network regimes (latency x bandwidth) INCLUDING heterogeneous topologies
(asymmetric 4-region mesh, hub-and-spoke hierarchical all-reduce). Pure
protocol accounting — no training — so it covers the paper's 150M config AND
the assigned big archs exactly.

Also measures the HOST-SIDE per-step overhead of the protocol engine itself
(the coordinator cost that rides on every local step): the functional jitted
`EngineState` path vs the same transitions executed eagerly ("host", the
legacy per-leaf tree-map churn).

    PYTHONPATH=src python benchmarks/wallclock.py           # full sweep
    PYTHONPATH=src python benchmarks/wallclock.py --smoke   # CI quick check
"""
from __future__ import annotations

import argparse
import time

import jax

if __package__ in (None, ""):              # direct `python benchmarks/wallclock.py`
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, save_json

from repro.configs import CoCoDCConfig, get_config
from repro.configs.base import ModelConfig
from repro.core.fragments import make_fragmenter
from repro.core.network import (NetworkModel, Topology, four_region_asymmetric,
                                hub_and_spoke, transpacific_flaky)
from repro.launch.steps import abstract_params

REGIMES = {
    "metro_100G": dict(latency_s=0.01, bandwidth_Bps=12.5e9),
    "inter_region_10G": dict(latency_s=0.15, bandwidth_Bps=1.25e9),
    "intercontinental_2G": dict(latency_s=0.4, bandwidth_Bps=0.25e9),
}


def hetero_regimes(t_c: float):
    """Heterogeneous topologies the scalar model cannot express."""
    return {
        "asym4_mesh": four_region_asymmetric(step_time_s=t_c),
        "asym4_flaky": transpacific_flaky(step_time_s=t_c),
        "hub_spoke_tree": hub_and_spoke(4, step_time_s=t_c,
                                        spoke_latency_s=0.05,
                                        spoke_bandwidth_Bps=1.25e9),
    }


def simulate(method: str, total_bytes: int, K: int, H: int, steps: int,
             net) -> dict:
    """Closed-form protocol wall-clock over `steps` local steps. `net` is any
    cost model with t_c / allreduce_time (NetworkModel or Topology)."""
    rounds = steps // H
    t_c = net.t_c
    if method == "diloco":
        comm = rounds * net.allreduce_time(total_bytes)
        wall = steps * t_c + comm
        hidden = 0.0
    else:
        frag_bytes = total_bytes // K
        t_s = net.allreduce_time(frag_bytes)
        if method == "streaming":
            n_syncs = rounds * K
        else:  # cocodc adaptive: up to gamma capacity (Eq. 9)
            from repro.core.adaptive import target_syncs
            n_syncs = rounds * target_syncs(K, H, t_c, t_s, 0.4)
        comm = n_syncs * t_s
        # overlapped: comm hides under compute unless the channel saturates
        spare = steps * t_c
        wall = steps * t_c + max(0.0, comm - spare)
        hidden = min(comm, spare)
    return {"wall_s": wall, "comm_s": comm, "hidden_s": hidden,
            "blocking_s": wall - steps * t_c}


# ---------------------------------------------------------------------------
# host-side engine overhead: jitted EngineState vs eager host path
# ---------------------------------------------------------------------------

BENCH_MODEL = ModelConfig(name="bench-eng", family="dense", n_layers=4,
                          d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                          vocab=256, compute_dtype="float32")


LOOP_MODEL = ModelConfig(name="bench-loop", family="dense", n_layers=2,
                         d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                         vocab=64, compute_dtype="float32")


def loop_overhead(method: str, loop: str, warm: int = 128,
                  bench: int = 128, windows: int = 3) -> float:
    """Host seconds per TRAINING step of the full loop (data prefetch + inner
    step + protocol) under the segment-scanned engine vs the legacy
    one-dispatch-per-step loop. The model is tiny and the sync interval long
    (H=64), so per-step dispatch overhead — the cost the scan fuses away —
    dominates. Steady state: best of `windows` timed windows, after a warm
    window that compiles the power-of-two chunk set."""
    from repro.core.trainer import CrossRegionTrainer, TrainerConfig

    ccfg = CoCoDCConfig(num_workers=2, local_steps=64, num_fragments=4,
                        overlap_depth=8)
    total = warm + windows * bench
    tcfg = TrainerConfig(method=method, local_batch=1, seq_len=8,
                         total_steps=total, warmup_steps=8,
                         inner_lr=3e-3, eval_batch=2, loop=loop)
    tr = CrossRegionTrainer(LOOP_MODEL, ccfg, tcfg)
    no_eval = 1 << 30
    tr.run(steps=warm, eval_every=no_eval, log=lambda s: None)  # compile+warm
    best = float("inf")
    for w in range(windows):
        t0 = time.perf_counter()
        tr.run(steps=warm + (w + 1) * bench, eval_every=no_eval,
               log=lambda s: None)
        best = min(best, (time.perf_counter() - t0) / bench)
    return best


def engine_overhead(method: str, engine_impl: str, steps: int = 96,
                    **ccfg_kw) -> float:
    """Seconds of host+device time per on_step_end call (no inner training),
    i.e. the coordinator overhead the protocol adds to every local step.
    `ccfg_kw` overrides protocol knobs (e.g. wire_codec for the codec bench)."""
    import jax.numpy as jnp
    from repro.core.protocol import ProtocolEngine
    from repro.models import api

    ccfg = CoCoDCConfig(num_workers=4, local_steps=12, num_fragments=4,
                        overlap_depth=3, **ccfg_kw)
    params = api.init_params(BENCH_MODEL, jax.random.PRNGKey(0))
    stack = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (4,) + a.shape).copy(), params)
    shape = jax.eval_shape(lambda: params)
    frag = make_fragmenter(BENCH_MODEL, shape, 4)
    net = NetworkModel(num_workers=4, latency_s=0.05, bandwidth_Bps=1.25e9,
                      step_time_s=1.0)

    eng = ProtocolEngine(method, ccfg, frag, net, stack,
                         engine_impl=engine_impl)
    s = stack
    warmup = 2 * ccfg.local_steps        # covers every fragment's compile
    for t in range(warmup):
        s = eng.on_step_end(t, s)
    jax.block_until_ready(jax.tree.leaves(s)[0])
    t0 = time.perf_counter()
    for t in range(warmup, warmup + steps):
        s = eng.on_step_end(t, s)
    jax.block_until_ready(jax.tree.leaves(s)[0])
    return (time.perf_counter() - t0) / steps


def codec_encode_throughput(codec: str, n: int = 1 << 21,
                            reps: int = 4) -> float:
    """Encoded f32 elements per second of the fused quantize+pack path (the
    per-initiation codec cost is this stream plus its decode mirror)."""
    import jax.numpy as jnp
    from repro.kernels.delta_codec import ops as codec_ops

    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    packed, scales = codec_ops.encode_array(x, codec=codec, block=256)
    jax.block_until_ready(packed)                       # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        packed, scales = codec_ops.encode_array(x, codec=codec, block=256)
    jax.block_until_ready(packed)
    return n * reps / (time.perf_counter() - t0)


# --smoke guard: a codec-enabled engine step may pay for the quantize+pack
# round trip but must stay the same order of magnitude as the plain f32
# initiate — a blowup here means the codec fell off the fused/jitted path
CODEC_OVERHEAD_MAX_X = 8.0

# --smoke guard: the flat-plane fused engine replaces the per-leaf tree-map
# transitions (one dispatch per leaf per stage) with one dispatch per stage.
# The guard measures CPU ORACLE mode (engine_impl="host": eager, per-dispatch
# overhead real — the CPU proxy for accelerator kernel-launch count); there
# the fused deliver must never be SLOWER than the per-leaf path it replaces
# (both measured best-of-2 to shave scheduler noise). Under jit-on-CPU both
# paths compile to ONE XLA computation, so that mode is reported for context
# but can't show a dispatch-count win and is not guarded.
FUSED_MIN_SPEEDUP = 1.0


def main(steps: int = 1000, smoke: bool = False) -> dict:
    out = {}
    archs = {
        "paper_150m": 1.0,          # paper's model: ~1 s/step on its A100 setup
        "qwen3_0_6b": 0.4,
        "llama3_405b": 25.0,        # per-step compute time scales with size
    }
    if smoke:
        archs = {"paper_150m": 1.0}
        steps = min(steps, 400)
    for arch, t_c in archs.items():
        cfg = get_config(arch)
        params_sds = abstract_params(cfg)
        total_bytes = sum(
            int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params_sds))
        K, H = 4, 100
        regimes: dict = {
            name: NetworkModel(num_workers=4, step_time_s=t_c, **kw)
            for name, kw in REGIMES.items()}
        regimes.update(hetero_regimes(t_c))
        for regime, net in regimes.items():
            row = {}
            for method in ("diloco", "streaming", "cocodc"):
                r = simulate(method, total_bytes, K, H, steps, net)
                row[method] = r
            speedup = row["diloco"]["wall_s"] / row["cocodc"]["wall_s"]
            emit(f"wallclock/{arch}/{regime}", 0.0,
                 f"diloco={row['diloco']['wall_s']:.0f}s;"
                 f"cocodc={row['cocodc']['wall_s']:.0f}s;"
                 f"speedup={speedup:.2f}x;"
                 f"hidden={row['cocodc']['hidden_s']:.0f}s")
            out[f"{arch}/{regime}"] = row

    # coordinator overhead per local step: jitted EngineState vs eager host
    overhead = {}
    bench_steps = 48 if smoke else 96
    for method in ("streaming", "cocodc"):
        row = {}
        for impl in ("host", "jit"):
            row[impl] = engine_overhead(method, impl, steps=bench_steps)
        row["speedup"] = row["host"] / row["jit"] if row["jit"] > 0 else 0.0
        emit(f"engine_overhead/{method}", row["jit"] * 1e6,
             f"host={row['host']*1e3:.2f}ms/step;jit={row['jit']*1e3:.2f}ms/step;"
             f"speedup={row['speedup']:.2f}x")
        overhead[method] = row
    out["engine_overhead"] = overhead

    # wire-codec cost at the two places it can bite: raw fused quantize+pack
    # throughput (the kernel itself), and the per-step coordinator overhead a
    # codec-enabled engine pays vs the plain f32 initiate it replaces. The
    # WAN seconds the codec SAVES are regime-dependent (see the sweep
    # frontier); this section shows what it costs.
    codec_rows = {}
    codec_base = engine_overhead("cocodc", "jit", steps=bench_steps)
    codec_rows["none"] = {"per_step_s": codec_base}
    for codec in (("int8",) if smoke else ("int8", "int4")):
        per = engine_overhead("cocodc", "jit", steps=bench_steps,
                              wire_codec=codec)
        thr = codec_encode_throughput(codec)
        row = {"per_step_s": per,
               "overhead_x": per / codec_base if codec_base > 0 else 0.0,
               "encode_elems_per_s": thr}
        emit(f"codec_overhead/{codec}", per * 1e6,
             f"per_step={per*1e3:.2f}ms;base={codec_base*1e3:.2f}ms;"
             f"overhead={row['overhead_x']:.2f}x;"
             f"encode={thr/1e6:.0f}Melem/s")
        codec_rows[codec] = row
    out["codec_overhead"] = codec_rows

    # fused outer-update plane: per-step coordinator overhead of the
    # flat-plane engine (fused_updates=on — state already flat, ONE fused
    # Nesterov + ONE fused deliver dispatch per transition) vs the per-leaf
    # tree-map path it replaces, same protocol schedule. The guarded
    # comparison runs the CPU oracle in EAGER mode ("host"), where each
    # tree-map leaf is a real dispatch — the CPU stand-in for accelerator
    # kernel-launch count. The jit numbers are context only: XLA fuses the
    # whole per-leaf transition into one computation there, so the flat
    # plane's remaining pack/unpack of the worker stack reads as overhead.
    fused_rows = {}
    for method in (("cocodc",) if smoke else ("streaming", "cocodc")):
        base = min(engine_overhead(method, "host", steps=bench_steps)
                   for _ in range(2))
        fused = min(engine_overhead(method, "host", steps=bench_steps,
                                    fused_updates=True)
                    for _ in range(2))
        jit_base = engine_overhead(method, "jit", steps=bench_steps)
        jit_fused = engine_overhead(method, "jit", steps=bench_steps,
                                    fused_updates=True)
        row = {"per_leaf_s": base, "fused_s": fused,
               "speedup": base / fused if fused > 0 else 0.0,
               "jit_per_leaf_s": jit_base, "jit_fused_s": jit_fused}
        emit(f"outer_update/{method}", fused * 1e6,
             f"per_leaf={base*1e3:.2f}ms/step;fused={fused*1e3:.2f}ms/step;"
             f"speedup={row['speedup']:.2f}x;"
             f"jit_per_leaf={jit_base*1e3:.2f}ms/step;"
             f"jit_fused={jit_fused*1e3:.2f}ms/step")
        fused_rows[method] = row
    out["outer_update"] = fused_rows

    # dispatch savings of the segment-scanned execution engine: full training
    # loop (data + inner step + protocol), scanned segments vs per-step.
    # "local" has no protocol events (64-step segments) — the upper bound on
    # what fusing dispatches can save
    loop_rows = {}
    warm, bench, windows = (96, 96, 2) if smoke else (128, 128, 3)
    loop_methods = (("cocodc",) if smoke
                    else ("diloco", "streaming", "cocodc", "local"))
    for method in loop_methods:
        row = {}
        for loop in ("per_step", "segment"):
            row[loop] = loop_overhead(method, loop, warm=warm, bench=bench,
                                      windows=windows)
        row["speedup"] = (row["per_step"] / row["segment"]
                          if row["segment"] > 0 else 0.0)
        emit(f"loop_overhead/{method}", row["segment"] * 1e6,
             f"per_step={row['per_step']*1e3:.2f}ms/step;"
             f"segment={row['segment']*1e3:.2f}ms/step;"
             f"speedup={row['speedup']:.2f}x")
        loop_rows[method] = row
    out["loop_overhead"] = loop_rows

    save_json("wallclock", out)
    if smoke:
        # CI regression guard: the scanned path must never be slower than the
        # per-step loop it replaces
        worst = min(r["speedup"] for r in loop_rows.values())
        if worst < 1.0:
            raise SystemExit(
                f"loop_overhead regression: scanned path speedup {worst:.2f}x "
                f"< 1.0x vs per-step loop")
        worst_codec = max(r["overhead_x"] for c, r in codec_rows.items()
                          if c != "none")
        if worst_codec > CODEC_OVERHEAD_MAX_X:
            raise SystemExit(
                f"codec_overhead regression: codec-enabled engine step is "
                f"{worst_codec:.2f}x the no-codec initiate "
                f"(> {CODEC_OVERHEAD_MAX_X}x) — codec off the fused path?")
        worst_fused = min(r["speedup"] for r in fused_rows.values())
        if worst_fused < FUSED_MIN_SPEEDUP:
            raise SystemExit(
                f"outer_update regression: fused flat-plane engine step is "
                f"only {worst_fused:.2f}x the per-leaf path in CPU oracle "
                f"(eager) mode (< {FUSED_MIN_SPEEDUP}x) — fused deliver "
                f"slower than the tree-map transitions it replaces")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--smoke", action="store_true",
                    help="single arch + short engine bench (CI)")
    a = ap.parse_args()
    main(steps=a.steps, smoke=a.smoke)
