"""Wall-clock efficiency under the WAN model (paper §IV-B discussion): DiLoCo's
blocking synchronization vs Streaming/CoCoDC's overlapped transmission, across
network regimes (latency x bandwidth). Pure protocol accounting — no training —
so it covers the paper's 150M config AND the assigned big archs exactly.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, save_json

from repro.configs import CoCoDCConfig, get_config
from repro.core.fragments import make_fragmenter
from repro.core.network import NetworkModel
from repro.launch.steps import abstract_params

REGIMES = {
    "metro_100G": dict(latency_s=0.01, bandwidth_Bps=12.5e9),
    "inter_region_10G": dict(latency_s=0.15, bandwidth_Bps=1.25e9),
    "intercontinental_2G": dict(latency_s=0.4, bandwidth_Bps=0.25e9),
}


def simulate(method: str, total_bytes: int, K: int, H: int, steps: int,
             net: NetworkModel) -> dict:
    """Closed-form protocol wall-clock over `steps` local steps."""
    rounds = steps // H
    t_c = net.t_c
    if method == "diloco":
        comm = rounds * net.allreduce_time(total_bytes)
        wall = steps * t_c + comm
        hidden = 0.0
    else:
        frag_bytes = total_bytes // K
        t_s = net.allreduce_time(frag_bytes)
        if method == "streaming":
            n_syncs = rounds * K
        else:  # cocodc adaptive: up to gamma capacity (Eq. 9)
            from repro.core.adaptive import target_syncs
            n_syncs = rounds * target_syncs(K, H, t_c, t_s, 0.4)
        comm = n_syncs * t_s
        # overlapped: comm hides under compute unless the channel saturates
        spare = steps * t_c
        wall = steps * t_c + max(0.0, comm - spare)
        hidden = min(comm, spare)
    return {"wall_s": wall, "comm_s": comm, "hidden_s": hidden,
            "blocking_s": wall - steps * t_c}


def main(steps: int = 1000) -> dict:
    out = {}
    archs = {
        "paper_150m": 1.0,          # paper's model: ~1 s/step on its A100 setup
        "qwen3_0_6b": 0.4,
        "llama3_405b": 25.0,        # per-step compute time scales with size
    }
    for arch, t_c in archs.items():
        cfg = get_config(arch)
        params_sds = abstract_params(cfg)
        total_bytes = sum(
            int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params_sds))
        K, H = 4, 100
        frag = None
        for regime, netkw in REGIMES.items():
            net = NetworkModel(num_workers=4, step_time_s=t_c, **netkw)
            row = {}
            for method in ("diloco", "streaming", "cocodc"):
                r = simulate(method, total_bytes, K, H, steps, net)
                row[method] = r
            speedup = row["diloco"]["wall_s"] / row["cocodc"]["wall_s"]
            emit(f"wallclock/{arch}/{regime}", 0.0,
                 f"diloco={row['diloco']['wall_s']:.0f}s;"
                 f"cocodc={row['cocodc']['wall_s']:.0f}s;"
                 f"speedup={speedup:.2f}x;"
                 f"hidden={row['cocodc']['hidden_s']:.0f}s")
            out[f"{arch}/{regime}"] = row
    save_json("wallclock", out)
    return out


if __name__ == "__main__":
    main()
