"""Roofline post-processing (deliverable g): read the dry-run JSONL artifacts and
derive the three roofline terms per (arch x input shape) on the single-pod mesh.

Methodology (see EXPERIMENTS.md §Roofline): XLA's HLO cost analysis counts a
`while` (scan) body ONCE, so the full-depth scanned program under-reports. Each
pair therefore also lowers depth-1 and depth-2 UNROLLED probes (full width, same
sharding); per-depth-unit cost = C(2) - C(1), fixed cost = C(1) - per_unit, and
full-program cost = fixed + units * per_unit. Collective bytes are parsed from
the post-SPMD HLO (operand bytes of all-gather/all-reduce/reduce-scatter/
all-to-all/collective-permute) and extrapolated identically.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

from benchmarks.common import RESULTS_DIR, emit, save_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}


def depth_units(cfg) -> int:
    if cfg.block_pattern:
        return cfg.n_layers // len(cfg.block_pattern)
    return cfg.n_layers


def active_params(cfg) -> float:
    """6*N*D convention: non-embedding params; MoE counts only routed-active
    experts (top_k/E of expert weights)."""
    import jax
    from repro.launch.steps import abstract_params
    sds = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    total = 0.0
    for path, leaf in flat:
        p = "/".join(str(getattr(x, "key", x)) for x in path)
        n = 1
        for d in leaf.shape:
            n *= d
        if p in ("embed", "lm_head"):
            continue
        if "/moe/w_" in p and cfg.moe:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return total


def model_flops(cfg, shape_name: str, chips: int) -> float:
    """Per-device useful model FLOPs: 6*N_active*tokens (train: fwd+bwd),
    2*N_active*tokens (inference)."""
    n = active_params(cfg)
    toks = SHAPE_TOKENS[shape_name]
    mult = 6.0 if shape_name == "train_4k" else 2.0
    return mult * n * toks / chips


def load_records():
    recs = []
    for path in glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*.jsonl")):
        with open(path) as f:
            for line in f:
                recs.append(json.loads(line))
    return recs


def extrapolate(full, p1, p2, units: int):
    """Extrapolate a probe-measured metric to full depth."""
    out = {}
    for key in ("flops_per_device", "bytes_accessed_per_device",
                "collective_bytes_per_device", "argument_bytes", "output_bytes",
                "temp_bytes"):
        c1, c2 = p1.get(key, 0.0), p2.get(key, 0.0)
        per_unit = max(0.0, c2 - c1)
        fixed = max(0.0, c1 - per_unit)
        out[key] = fixed + units * per_unit
    return out


def stream_roofline() -> "list[dict]":
    """Analytic roofline placement of the PROTOCOL STREAM kernels — the
    single-pass engine kernels (delta wire codec encode/decode, the fused
    outer-update family). Entries come from the ONE registry
    (`repro.kernels.stream_kernel_specs`), not a hardcoded list, so new
    stream kernels land here by registering. Every entry sits orders of
    magnitude left of the v5e ridge (PEAK_FLOPS/HBM_BW ~ 241 flop/B): these
    kernels are HBM streams; time-per-byte, not flops, is the budget
    (benchmarks/kernels.py measures the same thing empirically)."""
    from repro.kernels import stream_kernel_specs

    ridge = PEAK_FLOPS / HBM_BW
    rows = []
    for spec in stream_kernel_specs():
        flops_per_elem = spec["flops_per_elem"]
        bpe = spec["bytes_per_elem"]
        intensity = flops_per_elem / bpe
        t_mem = bpe / HBM_BW                        # s/elem at the HBM roof
        t_comp = flops_per_elem / PEAK_FLOPS
        rows.append({
            "kernel": spec["kernel"],
            "flops_per_elem": flops_per_elem, "bytes_per_elem": bpe,
            "intensity_flop_per_byte": intensity,
            "ridge_flop_per_byte": ridge,
            "bound": "memory" if intensity < ridge else "compute",
            "roofline_us_per_MB": t_mem / bpe * 1e6 * 1e6,
        })
        emit(f"roofline/stream/{spec['kernel']}", 0.0,
             f"intensity={intensity:.2f}flop/B;ridge={ridge:.0f}flop/B;"
             f"bound={rows[-1]['bound']};headroom={ridge/intensity:.0f}x;"
             f"mem_ns_per_elem={t_mem*1e9:.3f};"
             f"compute_ns_per_elem={t_comp*1e9:.5f}")
    return rows


def main() -> dict:
    from repro.configs import get_config
    recs = load_records()
    by_key = defaultdict(dict)
    for r in recs:
        if r.get("status") != "ok":
            by_key[(r["arch"], r["shape"], r["mesh"])].setdefault("skip", r)
            continue
        k = (r["arch"], r["shape"], r["mesh"])
        if "probe_depth" in r:
            by_key[k][f"probe{r['probe_depth']}"] = r
        else:
            by_key[k]["full"] = r

    table = []
    for (arch, shape, mesh), entry in sorted(by_key.items()):
        if mesh != "single_pod":
            continue
        if "skip" in entry and "full" not in entry:
            table.append({"arch": arch, "shape": shape, "status": "skipped",
                          "reason": entry["skip"].get("reason",
                                                      entry["skip"].get("error"))})
            continue
        if not {"full", "probe1", "probe2"} <= set(entry):
            table.append({"arch": arch, "shape": shape, "status": "incomplete"})
            continue
        cfg = get_config(arch.replace("-", "_").replace(".", "_"))
        units = depth_units(cfg)
        full = entry["full"]
        ext = extrapolate(full, entry["probe1"], entry["probe2"], units)
        chips = full["chips"]
        t_comp = ext["flops_per_device"] / PEAK_FLOPS
        # cost_analysis "bytes accessed" counts every HLO op operand with no
        # fusion modeling -> UPPER bound on HBM traffic. The lower bound reads
        # each argument/output/temp buffer once (perfect fusion).
        t_mem = ext["bytes_accessed_per_device"] / HBM_BW
        t_mem_lb = (ext["argument_bytes"] + ext["output_bytes"]
                    + ext["temp_bytes"]) / HBM_BW
        t_coll = ext["collective_bytes_per_device"] / LINK_BW
        # dominant term judged with the LOWER memory bound (the upper bound
        # would spuriously mark every program memory-bound; see EXPERIMENTS.md)
        dominant = max((t_comp, "compute"), (t_mem_lb, "memory"),
                       (t_coll, "collective"))[1]
        mf = model_flops(cfg, shape, chips)
        ratio = mf / ext["flops_per_device"] if ext["flops_per_device"] else 0.0
        rec_txt = _recommend(cfg, shape, dominant, ratio)
        rec = {
            "arch": arch, "shape": shape, "status": "ok", "chips": chips,
            "compute_s": t_comp, "memory_s": t_mem, "memory_lb_s": t_mem_lb,
            "collective_s": t_coll,
            "dominant": dominant,
            "model_flops_per_device": mf,
            "hlo_flops_per_device": ext["flops_per_device"],
            "useful_flops_ratio": ratio,
            "peak_hbm_bytes": full.get("peak_bytes", 0),
            "recommendation": rec_txt,
        }
        table.append(rec)
        emit(f"roofline/{arch}/{shape}", 0.0,
             f"compute={t_comp*1e3:.2f}ms;memory_ub={t_mem*1e3:.2f}ms;"
             f"memory_lb={t_mem_lb*1e3:.2f}ms;"
             f"collective={t_coll*1e3:.2f}ms;dominant={dominant};"
             f"useful_ratio={ratio:.2f}")

    stream = stream_roofline()
    save_json("roofline_table", table)
    save_json("roofline_stream", stream)
    _write_markdown(table)
    return {"table": table, "stream": stream}


def _recommend(cfg, shape, dominant, ratio) -> str:
    """One sentence per (arch, shape): what would move the dominant term down."""
    if dominant == "collective":
        if cfg.moe is not None:
            return ("Megatron row/column expert sharding removes one of the two "
                    "partial-sum all-reduces per MoE layer (-37% measured, §Perf "
                    "iter 3).")
        if shape.startswith("decode") or shape == "long_500k":
            return ("Batch the decode wider per chip or drop TP for the small "
                    "per-token matmuls (DP profile) to amortize the per-layer "
                    "d_model all-reduce.")
        n = 1e9 if cfg.d_model <= 2048 else 1e10
        if cfg.d_model <= 2048:
            return ("Sub-2B model: pure-DP profile replaces per-layer TP "
                    "all-reduces with one grad all-reduce (84x measured, §Perf "
                    "iter 2).")
        return ("Sequence-parallel TP (manual RS/AG around norms via shard_map) "
                "halves activation all-reduce bytes; the single-constraint "
                "shortcut regressed (§Perf iter 5).")
    if dominant == "memory":
        if shape.startswith("decode") or shape == "long_500k":
            return ("Decode is cache/param-streaming bound: quantize KV to int8 "
                    "or widen the batch so each param read serves more tokens.")
        return ("Increase per-device arithmetic intensity: larger microbatch or "
                "less remat; fuse norm/elementwise passes (rms_norm kernel).")
    return ("Compute-bound at high useful-FLOPs ratio — at roofline; gains now "
            "come from MXU utilization inside kernels (block shapes, bf16).")


def _write_markdown(table):
    lines = [
        "| arch | shape | compute (ms) | memory ub (ms) | memory lb (ms) | "
        "collective (ms) | dominant | useful-FLOPs ratio | what moves it down |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in table:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"{r.get('status')}: {r.get('reason','')} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['memory_lb_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r.get('recommendation','')} |")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "roofline.md"), "w") as f:
        f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
