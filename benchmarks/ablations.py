"""Ablations over the paper's two mechanisms:
  * compensation strength lambda (0 = no Taylor correction, Eq. 7)
  * Eq. (4) literal sign vs the self-consistent form (DESIGN.md §5)
  * adaptive transmission (gamma) vs fixed round-robin (Streaming schedule)
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, save_json

from repro.configs import CoCoDCConfig
from repro.core.trainer import CrossRegionTrainer, TrainerConfig
from benchmarks.convergence import MODEL


def run(ccfg: CoCoDCConfig, method="cocodc", steps=160, seed=0):
    tcfg = TrainerConfig(method=method, local_batch=4, seq_len=32,
                         total_steps=steps, warmup_steps=steps // 10,
                         inner_lr=3e-3, seed=seed, eval_batch=8,
                         noniid_frac=0.3)
    tr = CrossRegionTrainer(MODEL, ccfg, tcfg)
    tr.run(eval_every=steps, log=lambda s: None)  # eval at end only
    return tr.history[-1]


def main(steps: int = 160) -> dict:
    base = CoCoDCConfig(num_workers=4, local_steps=24, num_fragments=4,
                        overlap_depth=8, comp_lambda=0.5, net_utilization=0.4)
    out = {}

    # NOTE (finding): at SGD scales the Hadamard term lam*g*g*dtheta/H is
    # ~1e-8 of g, so small-lam results coincide to print precision — the
    # structural first-order compensation (theta_g + g*tau) carries the method;
    # lam=1e4 stress-tests that the term is wired correctly.
    for lam in (0.0, 0.5, 1.0, 1e4):
        rec = run(dataclasses.replace(base, comp_lambda=lam), steps=steps)
        out[f"lambda={lam}"] = rec
        emit(f"ablation/lambda={lam}", 0.0,
             f"nll={rec['nll']:.4f};ppl={rec['ppl']:.2f}")

    rec = run(dataclasses.replace(base, eq4_sign=-1.0), steps=steps)
    out["eq4_literal_sign"] = rec
    emit("ablation/eq4_literal_sign", 0.0,
         f"nll={rec['nll']:.4f};ppl={rec['ppl']:.2f}")

    for gamma in (0.1, 0.4, 0.8):
        rec = run(dataclasses.replace(base, net_utilization=gamma), steps=steps)
        out[f"gamma={gamma}"] = rec
        emit(f"ablation/gamma={gamma}", 0.0,
             f"nll={rec['nll']:.4f};ppl={rec['ppl']:.2f}")

    rec = run(base, method="streaming", steps=steps)
    out["streaming_baseline"] = rec
    emit("ablation/streaming_baseline", 0.0,
         f"nll={rec['nll']:.4f};ppl={rec['ppl']:.2f}")

    save_json("ablations", out)
    return out


if __name__ == "__main__":
    main()
