"""Paper Figs. 1-2 + Table I analog: validation loss/PPL vs training steps for
DiLoCo / Streaming DiLoCo / CoCoDC, and steps-to-target-PPL.

Scaled-down setting (CPU container): tiny LLaMA-style model (the registered
``bench_tiny`` arch), synthetic non-IID corpus; protocol constants keep the
paper's RATIOS (K fragments, tau/h overlap pressure, gamma, lambda). The claim
under test is the ORDERING and the step-count reduction, not absolute
perplexities. Every run is declared as an `ExperimentSpec` and constructed
through `repro.api.build_experiment` — the same path as the CLI and the sweep.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):              # `python benchmarks/convergence.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import Timer, emit, save_json

from repro.api import (ExperimentSpec, MethodExtensions, MethodSpec, ModelRef,
                       NetworkSpec, RunSpec, build_experiment, resolve_model)

MODEL_ARCH = "bench_tiny"
# kept for ablations.py (`from benchmarks.convergence import MODEL`)
MODEL = resolve_model(ExperimentSpec(model=ModelRef(arch=MODEL_ARCH)))


def base_spec(method: str, steps: int, seed: int = 0,
              engine_impl: str = "jit", *,
              extensions: MethodExtensions = MethodExtensions(),
              network: NetworkSpec = NetworkSpec()) -> ExperimentSpec:
    """Aggressive-overlap regime: tau comparable to the sync interval h, so the
    staleness/inconsistency the paper targets actually bites. The paper (§IV-B)
    notes its own tau=5/H=100 setting is mild and that CoCoDC's advantages are
    'expected to become significantly more pronounced' at larger H and tau —
    this is that regime, scaled to CPU step counts."""
    return ExperimentSpec(
        name=f"convergence_{method}",
        model=ModelRef(arch=MODEL_ARCH),
        method=MethodSpec(name=method, num_workers=4, local_steps=24,
                          num_fragments=4, overlap_depth=8, comp_lambda=0.5,
                          net_utilization=0.4, mixing_alpha=0.5,
                          extensions=extensions),
        network=network,
        run=RunSpec(steps=steps, warmup_steps=steps // 10, inner_lr=3e-3,
                    local_batch=4, seq_len=32, seed=seed, eval_batch=8,
                    noniid_frac=0.3, eval_every=max(10, steps // 20),
                    engine_impl=engine_impl))


def run_spec(spec: ExperimentSpec) -> dict:
    tr = build_experiment(spec)
    with Timer() as t:
        hist = tr.run(eval_every=spec.run.eval_every, log=lambda s: None)
    return {"history": hist, "stats": tr.engine.stats(), "host_s": t.dt,
            "link_stats": tr.engine.link_stats(), "trainer": tr}


def run_method(method: str, steps: int, seed: int = 0,
               engine_impl: str = "jit", **spec_kw):
    return run_spec(base_spec(method, steps, seed, engine_impl, **spec_kw))


def link_pricing_compare(steps: int) -> dict:
    """Eq. 12 (raw R_p argmax) vs Algorithm-2 cost-aware fragment selection
    (R_p per WAN-second) under the `transpacific_flaky` heterogeneous topology
    (ROADMAP open item). Uses the SIZE-SKEWED fragmenter: the greedy balanced
    fragmenter makes per-fragment WAN costs near-uniform, so selection rarely
    flips at toy scale (PR 2 finding) — geometric byte shares give the two
    policies meaningfully different prices to disagree over. Emits per-link
    stats for both runs so the busiest-link shift is visible in the JSON."""
    out = {}
    for pricing, key in ((False, "eq12"), (True, "cost_aware")):
        r = run_method(
            "cocodc", steps,
            extensions=MethodExtensions(link_pricing=pricing,
                                        fragment_strategy="skewed"),
            network=NetworkSpec(topology="transpacific_flaky", step_time_s=1.0))
        out[key] = {k: r[k] for k in ("history", "stats", "host_s",
                                      "link_stats")}
        final = r["history"][-1]
        emit(f"link_pricing/{key}", 0.0,
             f"final_ppl={final['ppl']:.2f};"
             f"busiest_s={r['stats']['busiest_link_seconds']:.1f};"
             f"wall={r['stats']['wall_clock_s']:.0f}s;"
             f"busiest_link={r['link_stats']['busiest_link']}")
    b_eq = out["eq12"]["stats"]["busiest_link_seconds"]
    b_ca = out["cost_aware"]["stats"]["busiest_link_seconds"]
    if b_eq > 0:
        emit("link_pricing/busiest_link_relief", 0.0,
             f"{100 * (1 - b_ca / b_eq):.1f}%")
    return out


def steps_to_ppl(hist, target):
    for rec in hist:
        if rec["ppl"] <= target:
            return rec["step"]
    return None


def main(steps: int = 480, seeds=(0,), link_pricing: bool = False) -> dict:
    out = {}
    for method in ("diloco", "streaming", "cocodc"):
        runs = []
        for seed in seeds:
            r = run_method(method, steps, seed)
            runs.append({k: r[k]
                         for k in ("history", "stats", "host_s", "link_stats")})
        out[method] = runs
        final = runs[0]["history"][-1]
        emit(f"convergence/{method}",
             runs[0]["host_s"] * 1e6 / steps,
             f"final_ppl={final['ppl']:.2f};final_nll={final['nll']:.4f};"
             f"sim_wall={runs[0]['stats']['wall_clock_s']:.0f}s")

    # steps-to-target (Table I analog): the paper picks an absolute PPL (20.0)
    # that every method reaches before the end; the equivalent here is the
    # weakest method's best-so-far ppl — guaranteed reachable by all
    worst_best = max(min(rec["ppl"] for rec in r[0]["history"])
                     for r in out.values())
    target = worst_best
    table = {}
    for method, runs in out.items():
        s = steps_to_ppl(runs[0]["history"], target)
        table[method] = s
        emit(f"steps_to_ppl_{target:.1f}/{method}", 0.0,
             f"steps={s}")
    if table.get("cocodc") and table.get("streaming"):
        red = 100 * (1 - table["cocodc"] / table["streaming"])
        emit("cocodc_vs_streaming_step_reduction", 0.0, f"{red:.1f}%")
    if table.get("cocodc") and table.get("diloco"):
        red = 100 * (1 - table["cocodc"] / table["diloco"])
        emit("cocodc_vs_diloco_step_reduction", 0.0, f"{red:.1f}%")
    payload = {"runs": out, "target_ppl": target, "steps_to_target": table}
    if link_pricing:
        payload["link_pricing"] = link_pricing_compare(steps)
    save_json("convergence", payload)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=480)
    ap.add_argument("--link-pricing", action="store_true",
                    help="also compare Eq. 12 vs Algorithm-2 cost-aware "
                         "fragment selection under transpacific_flaky")
    a = ap.parse_args()
    main(steps=a.steps, link_pricing=a.link_pricing)
