"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and saves full
JSON artifacts under experiments/.

  convergence — Figs. 1-2 + Table I (loss/PPL vs steps; steps-to-target)
  wallclock   — §IV-B wall-clock claims across WAN regimes
  ablations   — lambda / gamma / Eq-4-sign ablations
  kernels     — Pallas-kernel oracle timings + TPU roofline projections
  roofline    — deliverable (g): three-term roofline from the dry-run artifacts
  sweep       — dynamic-WAN scenario x method grid (generated meshes,
                diurnal/outage dynamics; per-scenario JSON under
                experiments/sweep/; scenarios are experiments/specs/*.json;
                with --fast runs --smoke incl. the routed-vs-static stall
                gate and the fairshare-vs-serial transfer-time gate)
  spec_smoke  — declarative-path guard: every experiments/specs/*.json
                round-trips + runs via repro.api.build_experiment, and the
                CLI flag path maps onto the identical spec
  serving     — continuous-batching vs lock-step serving (p50/p99 TTFT,
                tok/s, occupancy) + routed failover through a hub outage;
                gates: >= 1.3x speedup at no worse p99 TTFT, zero drops,
                decode traced once
  analysis    — static-analysis gate (src/repro/analysis): jaxpr dispatch
                budgets, banned primitives, donation wiring, kernel-contract
                lint; same checks as the CI static-analysis job
"""
from __future__ import annotations

import argparse
import sys
import traceback


def _require_zero(code, name: str) -> None:
    if code:
        raise RuntimeError(f"{name} exited with status {code}")


def _analysis_main() -> int:
    from repro.analysis.__main__ import main as analysis_main
    return analysis_main(["--smoke"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter convergence/ablation runs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    args = ap.parse_args()

    from benchmarks import (ablations, convergence, kernels, roofline,
                            serving, spec_smoke, sweep, wallclock)

    steps = 240 if args.fast else 480
    ab_steps = 120 if args.fast else 240
    jobs = {
        "kernels": lambda: kernels.main(),
        "wallclock": lambda: wallclock.main(),
        "roofline": lambda: roofline.main(),
        "convergence": lambda: convergence.main(steps=steps),
        "ablations": lambda: ablations.main(steps=ab_steps),
        "sweep": lambda: _require_zero(
            sweep.main(["--smoke"] if args.fast else []), "sweep"),
        "spec_smoke": lambda: _require_zero(spec_smoke.main(), "spec_smoke"),
        "serving": lambda: _require_zero(
            serving.main(["--smoke"] if args.fast else []), "serving"),
        "analysis": lambda: _require_zero(_analysis_main(), "analysis"),
    }
    only = set(args.only.split(",")) if args.only else None
    failed = []
    for name, job in jobs.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            job()
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", flush=True)
        sys.exit(1)
    print("# all benchmarks complete", flush=True)


if __name__ == "__main__":
    main()
