"""Spec-driven smoke (CI): prove the declarative path stands on its own.

1. Every spec under ``experiments/specs/*.json`` must load, validate, and
   round-trip through JSON exactly (spec -> dict -> spec identical, stable
   spec_hash).
2. A 2-spec x 2-method grid runs PURELY from the spec files via
   `repro.api.build_experiment` (steps clamped for CI) — finite eval NLL and
   non-empty link traffic required.
3. The CLI flag path must keep mapping onto the identical spec
   (`spec_from_args(flags) == ExperimentSpec(...)`) so the declarative path
   and the flag path cannot drift apart.

    PYTHONPATH=src python benchmarks/spec_smoke.py            # exit 1 on drift
"""
from __future__ import annotations

import dataclasses
import glob
import math
import os
import sys

if __package__ in (None, ""):               # `python benchmarks/spec_smoke.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import RESULTS_DIR, Timer, emit

from repro.api import ExperimentSpec, build_experiment

SPECS_DIR = os.path.join(RESULTS_DIR, "specs")
# (spec file stem, methods, CI step budget)
SMOKE_GRID = (
    ("static4_paper", ("streaming", "cocodc"), 8),
    ("n8_geo_diurnal_hub", ("streaming", "cocodc"), 6),
)


def check_roundtrips() -> "list[str]":
    failures = []
    paths = sorted(glob.glob(os.path.join(SPECS_DIR, "*.json")))
    if not paths:
        return [f"no spec files under {SPECS_DIR!r}"]
    for path in paths:
        name = os.path.basename(path)
        try:
            spec = ExperimentSpec.from_json_file(path).validate()
        except (ValueError, KeyError) as e:
            failures.append(f"{name}: does not load/validate: {e}")
            continue
        rt = ExperimentSpec.from_dict(spec.to_dict())
        if rt != spec:
            failures.append(f"{name}: spec -> dict -> spec not identical")
        if ExperimentSpec.from_json(spec.to_json()) != spec:
            failures.append(f"{name}: JSON round-trip not identical")
        if rt.spec_hash != spec.spec_hash:
            failures.append(f"{name}: spec_hash unstable across round-trip")
        emit(f"spec_smoke/roundtrip/{name}", 0.0, f"hash={spec.spec_hash}")
    return failures


def run_grid() -> "list[str]":
    # reuse the sweep's re-targeting rule (method swap + cadence derivation +
    # adaptive_resync compatibility drop) so this guard cannot drift from it
    from benchmarks.sweep import retarget_spec
    failures = []
    for stem, methods, steps in SMOKE_GRID:
        base = ExperimentSpec.from_json_file(
            os.path.join(SPECS_DIR, f"{stem}.json"))
        for method in methods:
            spec = retarget_spec(base, method, steps)
            spec = dataclasses.replace(
                spec, run=dataclasses.replace(spec.run, eval_every=steps))
            tr = build_experiment(spec)
            with Timer() as t:
                hist = tr.run(eval_every=spec.run.eval_every,
                              log=lambda s: None)
            nll = hist[-1]["nll"]
            emit(f"spec_smoke/run/{stem}/{method}", t.dt * 1e6 / steps,
                 f"final_nll={nll:.4f}")
            if not math.isfinite(nll):
                failures.append(f"{stem}/{method}: non-finite eval nll {nll}")
            if not tr.engine.link_stats()["links"]:
                failures.append(f"{stem}/{method}: no WAN traffic recorded")
    return failures


def check_flag_parity() -> "list[str]":
    """The CLI flag path must compose the exact spec the equivalent flags
    describe — same object, same hash (trainer-level bitwise parity is pinned
    by tests/test_experiment_spec.py)."""
    from repro.api import MethodSpec, ModelRef, NetworkSpec, RunSpec
    from repro.launch.train import make_parser, spec_from_args
    args = make_parser().parse_args(
        ["--arch", "bench_tiny", "--method", "streaming", "--workers", "4",
         "--H", "12", "--fragments", "2", "--tau", "3", "--steps", "24",
         "--topology", "asym4", "--lr", "0.003", "--seed", "7"])
    from_flags = spec_from_args(args)
    expected = ExperimentSpec(
        model=ModelRef(arch="bench_tiny"),
        method=MethodSpec(name="streaming", num_workers=4, local_steps=12,
                          num_fragments=2, overlap_depth=3),
        network=NetworkSpec(topology="asym4"),
        run=RunSpec(steps=24, inner_lr=3e-3, seed=7))
    if from_flags != expected:
        return [f"flag path drifted from the spec path:\n"
                f"  flags: {from_flags.to_json(indent=None)}\n"
                f"  spec : {expected.to_json(indent=None)}"]
    if from_flags.spec_hash != expected.spec_hash:
        return ["flag path spec_hash drifted"]
    emit("spec_smoke/flag_parity", 0.0, f"hash={expected.spec_hash}")
    return []


def main() -> int:
    failures = check_roundtrips() + check_flag_parity() + run_grid()
    for f in failures:
        print(f"SPEC SMOKE FAIL {f}", file=sys.stderr, flush=True)
    if failures:
        print(f"{len(failures)} failure(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
