"""Kernel micro-benchmarks: oracle (pure-XLA) path timings on CPU + analytic TPU
projections. The Pallas kernels themselves target TPU; on this CPU container they
execute in interpret mode (correctness only), so us_per_call here times the
ref/oracle path and `derived` carries the projected v5e-roofline time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json

HBM_BW = 819e9          # v5e
PEAK_FLOPS = 197e12


def bench(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)

    # delay_comp: memory-bound fused elementwise (3 reads + 1 write)
    from repro.kernels.delay_comp.ref import delay_comp_ref
    n = 4_000_000
    tl, tp, tg = (jax.random.normal(jax.random.fold_in(key, i), (n,))
                  for i in range(3))
    f = jax.jit(lambda a, b, c: delay_comp_ref(a, b, c, tau=5.0, lam=0.5, H=100.0))
    us = bench(f, tl, tp, tg)
    tpu_us = 4 * n * 4 / HBM_BW * 1e6
    emit("kernel/delay_comp_4M", us, f"tpu_roofline_us={tpu_us:.1f}")
    out["delay_comp"] = {"cpu_us": us, "tpu_us": tpu_us}

    # flash attention: compute-bound
    from repro.kernels.flash_attention.ref import flash_attention_ref
    B, S, H, KV, hd = 1, 1024, 8, 2, 128
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, hd), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = bench(f, q, k, v)
    flops = 2 * 2 * B * H * S * S // 2 * hd  # qk + pv, causal half
    emit("kernel/flash_attn_1k", us, f"tpu_roofline_us={flops/PEAK_FLOPS*1e6:.1f}")
    out["flash_attention"] = {"cpu_us": us}

    # rglru scan: memory-bound recurrence
    from repro.kernels.rglru_scan.ref import lru_scan_ref
    B, T, D = 2, 2048, 1024
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 4), (B, T, D)))
    b = jax.random.normal(jax.random.fold_in(key, 5), (B, T, D))
    f = jax.jit(lambda a, b: lru_scan_ref(a, b))
    us = bench(f, a, b)
    tpu_us = 3 * B * T * D * 4 / HBM_BW * 1e6
    emit("kernel/rglru_scan_2k", us, f"tpu_roofline_us={tpu_us:.1f}")
    out["rglru_scan"] = {"cpu_us": us, "tpu_us": tpu_us}

    # rwkv6 wkv scan
    from repro.models.rwkv6 import wkv_scan_ref
    B, T, H, hd = 1, 512, 8, 64
    r, kk, vv = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd)) * 0.5
                 for i in (6, 7, 8))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 9), (B, T, H, hd)))
    u = jax.random.normal(jax.random.fold_in(key, 10), (H, hd)) * 0.1
    f = jax.jit(lambda *xs: wkv_scan_ref(*xs)[0])
    us = bench(f, r, kk, vv, w, u)
    flops = 4 * B * T * H * hd * hd  # two rank-1 updates + matvec per step
    emit("kernel/rwkv6_wkv_512", us, f"tpu_roofline_us={flops/PEAK_FLOPS*1e6:.2f}")
    out["rwkv6_scan"] = {"cpu_us": us}

    # fused rms_norm: memory-bound (2 passes -> 1)
    from repro.kernels.rms_norm.ref import rms_norm_ref
    x = jax.random.normal(jax.random.fold_in(key, 11), (8192, 4096))
    w = jnp.ones((4096,))
    f = jax.jit(lambda x, w: rms_norm_ref(x, w))
    us = bench(f, x, w)
    tpu_us = 2 * x.size * 4 / HBM_BW * 1e6
    emit("kernel/rms_norm_8kx4k", us, f"tpu_roofline_us={tpu_us:.1f}")
    out["rms_norm"] = {"cpu_us": us, "tpu_us": tpu_us}

    # flash_decode: one token over a 32k ring cache — memory-bound on the cache
    from repro.kernels.flash_decode.ref import flash_decode_ref
    B, H, KV, hd, C = 4, 8, 2, 128, 8192
    q = jax.random.normal(jax.random.fold_in(key, 12), (B, H, hd))
    kc = jax.random.normal(jax.random.fold_in(key, 13), (B, C, KV, hd),
                           jnp.bfloat16)
    vc = jax.random.normal(jax.random.fold_in(key, 14), (B, C, KV, hd),
                           jnp.bfloat16)
    kv_pos = jnp.arange(C)
    qpos = jnp.asarray(C - 1, jnp.int32)
    f = jax.jit(lambda *a: flash_decode_ref(*a))
    us = bench(f, q, kc, vc, kv_pos, qpos)
    tpu_us = 2 * B * C * KV * hd * 2 / HBM_BW * 1e6  # read k+v once
    emit("kernel/flash_decode_8k", us, f"tpu_roofline_us={tpu_us:.1f}")
    out["flash_decode"] = {"cpu_us": us, "tpu_us": tpu_us}

    # delta_codec: bandwidth-bound single-pass stream — encode reads 4 B/elem
    # and writes bits/8 (+ scales); decode is the mirror. ~3 flops/elem keeps
    # both far left of the ridge, so the roofline is the HBM stream.
    from repro.kernels.delta_codec import ops as codec_ops
    from repro.kernels.delta_codec.ops import CODEC_BITS
    n = 4_000_000
    x = jax.random.normal(jax.random.fold_in(key, 15), (n,))
    for codec, bits in sorted(CODEC_BITS.items()):
        fe = jax.jit(lambda x, c=codec: codec_ops.encode_array(
            x, codec=c, block=256))
        us = bench(lambda x: fe(x)[0], x)
        packed, scales = fe(x)
        fd = jax.jit(lambda p, s, c=codec: codec_ops.decode_array(
            p, s, x.shape, x.dtype, codec=c, block=256))
        dus = bench(fd, packed, scales)
        enc_bytes = n * 4 + n * bits // 8 + (n // 256) * 4
        tpu_us = enc_bytes / HBM_BW * 1e6
        emit(f"kernel/delta_codec_{codec}_4M", us,
             f"decode_us={dus:.0f};tpu_roofline_us={tpu_us:.1f}")
        out[f"delta_codec_{codec}"] = {"cpu_us": us, "decode_cpu_us": dus,
                                       "tpu_us": tpu_us}

    # outer_update: the fused protocol-transition family over the flat
    # fragment plane. Nesterov streams 3 reads + 2 writes; deliver streams
    # the worker-stacked fragment (+ snapshot for compensate) in one pass.
    # Analytic projections come from the SAME registry roofline.py plots.
    from repro.kernels import stream_kernel_specs
    from repro.kernels.outer_update import ops as ou_ops
    specs = {s["kernel"]: s for s in stream_kernel_specs()}
    rows, M = 4096, 4                  # rows x 1024 = 4.2M elems, 4 workers
    t, m, d, g = (jax.random.normal(jax.random.fold_in(key, 20 + i),
                                    (rows, 1024)) for i in range(4))
    loc = jax.random.normal(jax.random.fold_in(key, 24), (M, rows, 1024))
    snap = jax.random.normal(jax.random.fold_in(key, 25), (M, rows, 1024))
    avail = jnp.ones((M,))
    fn = jax.jit(lambda t, m, d: ou_ops.outer_nesterov(
        t, m, d, lr=0.7, mu=0.9, impl="ref"))
    us = bench(lambda *a: fn(*a)[0], t, m, d)
    sp = specs["outer_update_nesterov"]
    tpu_us = rows * 1024 * sp["bytes_per_elem"] / HBM_BW * 1e6
    emit("kernel/outer_nesterov_4M", us, f"tpu_roofline_us={tpu_us:.1f}")
    out["outer_nesterov"] = {"cpu_us": us, "tpu_us": tpu_us}
    for mode, args in (("blend", (loc, loc, g)), ("compensate",
                                                  (loc, snap, g))):
        fn = jax.jit(lambda l, s, g, md=mode: ou_ops.fused_deliver(
            l, s, g, avail, mode=md, alpha=0.5, tau=3.0, lam=0.5, H=100.0,
            impl="ref"))
        us = bench(fn, *args)
        sp = specs[f"outer_update_deliver_{mode}"]
        tpu_us = M * rows * 1024 * sp["bytes_per_elem"] / HBM_BW * 1e6
        emit(f"kernel/outer_deliver_{mode}_4Mx4", us,
             f"tpu_roofline_us={tpu_us:.1f}")
        out[f"outer_deliver_{mode}"] = {"cpu_us": us, "tpu_us": tpu_us}

    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    main()
