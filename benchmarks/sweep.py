"""Scenario sweep harness for the dynamic WAN simulator — spec-driven.

Runs the four methods (diloco / streaming / cocodc / local) across a grid of
network scenarios LOADED FROM ``experiments/specs/*.json`` (one declarative
`ExperimentSpec` per scenario — the same files `repro.launch.train --spec`
accepts) and emits one JSON per scenario under ``experiments/sweep/`` plus a
cross-scenario summary. Every trainer is constructed through
`repro.api.build_experiment`; this harness only swaps the method name and the
step budget onto each scenario's spec. This is the stress rig the adaptive
transmission strategy (Eq. 11/12) was designed for: static topologies never
exercise it.

    PYTHONPATH=src python benchmarks/sweep.py                 # full grid
    PYTHONPATH=src python benchmarks/sweep.py --scenario hub_failure8
    PYTHONPATH=src python benchmarks/sweep.py --smoke         # CI: tiny grid
                                                              # + routed compare

Per (scenario, method) the JSON records steps-to-target-PPL (target = the
weakest method's best PPL, the Table-I analog), WAN bytes/busy-seconds per
link, stall seconds/fraction (time lost to troughs+outages vs the static
cost), outage retries, and the full eval history. The ``*_routed`` scenarios
rerun a dynamic scenario with the routed communication planner (multi-hop
routes + hub failover + Eq. 9 re-derivation); the ``*_fairshare`` scenario
reruns the routed diurnal hub mesh under the max-min fair-share traffic
plane (FairShareSim + k=2 multipath). ``--smoke`` fails (exit 1) on schema
drift, non-finite metrics, a routed hub-failure run whose stall fraction is
not strictly below its static-route twin's, or a fair-share run that does
not cut the mean transfer sojourn by >= FAIRSHARE_MIN_GAIN at matched
perplexity vs its serial-queue twin.

Bandwidth scales are AUTO-CALIBRATED (`NetworkSpec.bw_scale="auto"` in the
spec files -> `core.network.calibrate_bw_scale`) from the sweep model's mean
fragment byte size: one fragment collective spends ~CALIB_BW_STEPS compute
steps in bandwidth, so the toy transfers are bandwidth-dominated and the
dynamics under test actually bite. A float in the spec overrides the
calibration.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import glob
import math
import os
import sys

if __package__ in (None, ""):                     # `python benchmarks/sweep.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import RESULTS_DIR, Timer, emit, save_json

from repro.api import (ExperimentSpec, MethodSpec, ModelRef, build_experiment,
                       get_method, mean_fragment_bytes)
from repro.api import build_network as api_build_network
from repro.core.network import CALIB_BW_STEPS, apply_dynamics, calibrate_bw_scale

METHODS = ("diloco", "streaming", "cocodc", "local")
NUM_FRAGMENTS = 4
SPECS_DIR = os.path.join(RESULTS_DIR, "specs")
# CALIB_BW_STEPS / calibrate_bw_scale moved to core.network (PR 5) and are
# re-imported above so existing `from benchmarks.sweep import ...` call sites
# keep working.


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Runtime view of one scenario spec file: the network-identity fields
    the harness branches on, plus the full `ExperimentSpec` it was loaded
    from (`spec` — the single source of truth for everything else)."""
    name: str
    n: int = 4
    mesh: str | None = None          # generated-mesh profile
    topology: str | None = None      # named fixed scenario
    dynamics: str | None = None
    seed: int = 0
    steps: int = 96
    bw_scale: float | str | None = "auto"
    routing: str = "static"          # routed communication plans
    hub_failover: bool = False       # re-elect the hub while its links are out
    adaptive_resync: bool = False    # re-derive Eq. 9's N from measured T_s
    note: str = ""
    spec: ExperimentSpec = dataclasses.field(default_factory=ExperimentSpec)


def load_scenarios(specs_dir: str = SPECS_DIR) -> "list[Scenario]":
    """One Scenario per ``experiments/specs/*.json`` — the grid is data."""
    scenarios = []
    for path in sorted(glob.glob(os.path.join(specs_dir, "*.json"))):
        spec = ExperimentSpec.from_json_file(path).validate()
        scenarios.append(Scenario(
            name=spec.name or os.path.splitext(os.path.basename(path))[0],
            n=spec.method.num_workers, mesh=spec.network.mesh,
            topology=spec.network.topology, dynamics=spec.network.dynamics,
            seed=spec.network.mesh_seed, steps=spec.run.steps,
            bw_scale=spec.network.bw_scale, routing=spec.network.routing,
            hub_failover=spec.network.hub_failover,
            adaptive_resync=spec.method.extensions.adaptive_resync,
            note=spec.note, spec=spec))
    if not scenarios:
        raise FileNotFoundError(
            f"no scenario specs under {specs_dir!r} — the sweep grid is "
            f"driven by experiments/specs/*.json")
    return scenarios


@functools.lru_cache(maxsize=1)
def _grid_scenarios() -> "tuple[Scenario, ...]":
    return tuple(load_scenarios())


def __getattr__(name: str):
    # `SCENARIOS` is loaded lazily (PEP 562) so importing this module — e.g.
    # from benchmarks/run.py for an unrelated benchmark — never does disk
    # I/O or fails on a checkout without experiments/specs/.
    if name == "SCENARIOS":
        return list(_grid_scenarios())
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

SMOKE_METHODS = ("streaming", "cocodc")
# smoke grid: (scenario name, methods, steps). The hub-failure pair runs long
# enough to cover the outage window [24, 40) AND recovery, because the smoke
# contract compares routed vs static stall fractions across it.
SMOKE_GRID = (
    ("static4_paper", SMOKE_METHODS, 12),
    ("n8_geo_diurnal_hub", SMOKE_METHODS, 12),
    ("hub_failure8", ("cocodc",), 44),
    ("hub_failure8_routed", ("cocodc",), 44),
    # fairshare-vs-serial pair at the full 64-step budget: the transfer-time
    # contract compares queueing-inclusive sojourns, which need enough syncs
    # past the outage window to be meaningful
    ("n8_geo_diurnal_hub_routed", ("cocodc",), 64),
    ("n8_geo_diurnal_hub_fairshare", ("cocodc",), 64),
)
# routed scenario -> its static-route twin; --smoke FAILS if the routed run's
# stall_fraction is not strictly below the static run's on any shared method
ROUTED_COMPARE = {
    "hub_failure8_routed": "hub_failure8",
    "n8_geo_diurnal_hub_routed": "n8_geo_diurnal_hub",
}
# fair-share scenario -> its serial-queue twin (identical spec apart from
# channel_scheduler/multipath_k); --smoke FAILS unless the fair-share run's
# mean transfer sojourn is >= FAIRSHARE_MIN_GAIN lower AND its final
# perplexity is no more than FAIRSHARE_PPL_TOL WORSE than the twin's (being
# better always passes) — the PR 7 acceptance contract
FAIRSHARE_COMPARE = {
    "n8_geo_diurnal_hub_fairshare": "n8_geo_diurnal_hub_routed",
}
FAIRSHARE_MIN_GAIN = 0.20    # required relative reduction of transfer_mean_s
FAIRSHARE_PPL_TOL = 0.02     # max (ppl - ppl_serial) / ppl_serial, one-sided

# Required result schema per (scenario, method) — drift fails --smoke.
RUN_SCHEMA = {
    "final_ppl": float, "final_nll": float, "steps_to_target": (int, type(None)),
    "host_s": float, "history": list, "stats": dict, "link_stats": dict,
}
STATS_KEYS = ("wall_clock_s", "comm_seconds", "bytes_sent", "n_syncs",
              "overlap_ratio", "stall_seconds", "stall_fraction", "n_retries",
              "reroutes", "hub_elections",
              "busiest_link_bytes", "busiest_link_seconds",
              "wire_bytes_total", "wire_bytes_raw", "compression_ratio",
              "mean_transfer_s",
              "transfer_mean_s", "transfer_p50_s", "transfer_p95_s",
              "multipath_splits", "max_link_busy_fraction")

# ---- convergence-vs-bandwidth frontier (PR 6) --------------------------------
# The frontier re-runs ONE scenario with the wire codec dialed across
# none/int8/int4 for each method, holding everything else (seed, mesh,
# dynamics, step budget) fixed, and reports bytes-on-wire, compression ratio,
# mean transfer seconds, and final perplexity per point. --smoke enforces the
# int8 acceptance contract against the codec="none" twin.
FRONTIER_SCENARIO = "n8_geo_diurnal_hub"
FRONTIER_CODECS = ("none", "int8", "int4")
FRONTIER_METHODS = ("streaming", "cocodc")
FRONTIER_MIN_RATIO = 3.5     # int8 wire bytes must drop >= 3.5x vs raw f32
FRONTIER_PPL_TOL = 0.02      # |ppl - ppl_none| / ppl_none at smoke scale


@functools.lru_cache(maxsize=1)
def fragment_wire_bytes() -> int:
    """Mean fragment payload of the sweep model (f32 wire format), from the
    real fragmenter — the calibration input."""
    return mean_fragment_bytes(ExperimentSpec(
        model=ModelRef(arch="bench_tiny"),
        method=MethodSpec(num_fragments=NUM_FRAGMENTS)))


def build_network(sc: Scenario, step_time_s: "float | None" = None):
    """None = let the trainer build the calibrated symmetric paper network.
    Delegates assembly (mesh/scenario + bw_scale calibration) to the API
    factory. The Scenario VIEW fields are authoritative here, so a
    `dataclasses.replace(sc, bw_scale=..., mesh=...)` override is honored
    consistently (the calibration tests rely on this); `run_one` reads
    `sc.spec` directly and never consults the view. `step_time_s=None`
    keeps the spec's own T_c, so this path builds the same topology the
    sweep actually runs on."""
    net_spec = dataclasses.replace(sc.spec.network, mesh=sc.mesh,
                                   topology=sc.topology, mesh_seed=sc.seed,
                                   bw_scale=sc.bw_scale)
    if step_time_s is not None:
        net_spec = dataclasses.replace(net_spec, step_time_s=step_time_s)
    method = dataclasses.replace(sc.spec.method, num_workers=sc.n)
    net = api_build_network(dataclasses.replace(sc.spec, network=net_spec,
                                                method=method))
    if net is None:
        return None
    return apply_dynamics(net, sc.dynamics, seed=sc.seed)


def retarget_spec(spec: ExperimentSpec, method: str,
                  steps: int) -> ExperimentSpec:
    """A scenario spec re-targeted at `method` over `steps`: the harness
    derives warmup/eval cadence from the (possibly overridden) step budget,
    and drops adaptive_resync for methods with a fixed cadence (the routed
    scenario files declare it for cocodc). Shared with spec_smoke so the CI
    guard cannot drift from the sweep's re-targeting rule."""
    ext = dataclasses.replace(
        spec.method.extensions,
        adaptive_resync=(spec.method.extensions.adaptive_resync and
                         get_method(method).supports_adaptive_resync))
    return dataclasses.replace(
        spec,
        method=dataclasses.replace(spec.method, name=method, extensions=ext),
        run=dataclasses.replace(spec.run, steps=steps,
                                warmup_steps=max(2, steps // 10),
                                eval_every=max(4, steps // 6)))


def run_one(sc: Scenario, method: str, steps: int) -> dict:
    spec = retarget_spec(sc.spec, method, steps)
    tr = build_experiment(spec)
    with Timer() as t:
        hist = tr.run(eval_every=spec.run.eval_every, log=lambda s: None)
    final = hist[-1]
    return {"final_ppl": float(final["ppl"]), "final_nll": float(final["nll"]),
            "steps_to_target": None,     # filled once the target is known
            "host_s": t.dt, "history": hist, "stats": tr.engine.stats(),
            "link_stats": tr.engine.link_stats()}


def steps_to_ppl(hist, target):
    for rec in hist:
        if rec["ppl"] <= target:
            return rec["step"]
    return None


def run_scenario(sc: Scenario, methods=METHODS, steps: int | None = None) -> dict:
    steps = steps or sc.steps
    runs = {}
    for method in methods:
        r = run_one(sc, method, steps)
        runs[method] = r
        emit(f"sweep/{sc.name}/{method}", r["host_s"] * 1e6 / steps,
             f"final_ppl={r['final_ppl']:.2f};"
             f"wall={r['stats']['wall_clock_s']:.0f}s;"
             f"stall={r['stats']['stall_fraction']*100:.0f}%;"
             f"retries={int(r['stats']['n_retries'])}")
    # Table-I analog target: the weakest method's best-so-far PPL, so every
    # method is guaranteed to reach it within the run
    target = max(min(rec["ppl"] for rec in r["history"])
                 for r in runs.values())
    for method, r in runs.items():
        r["steps_to_target"] = steps_to_ppl(r["history"], target)
    payload = {"scenario": dataclasses.asdict(sc), "steps": steps,
               "target_ppl": target, "runs": runs}
    return payload


def validate_payload(payload: dict, scenario: str):
    """Schema + sanity guard for one scenario payload (CI --smoke contract):
    required keys with the right types, finite metrics, non-empty link stats,
    and dynamics actually exercised when the scenario declares any."""
    def fail(msg):
        raise AssertionError(f"[{scenario}] {msg}")

    for key in ("scenario", "steps", "target_ppl", "runs"):
        if key not in payload:
            fail(f"missing top-level key {key!r}")
    if not math.isfinite(payload["target_ppl"]):
        fail(f"target_ppl not finite: {payload['target_ppl']}")
    for method, r in payload["runs"].items():
        for key, typ in RUN_SCHEMA.items():
            if key not in r:
                fail(f"{method}: missing run key {key!r}")
            if not isinstance(r[key], typ):
                fail(f"{method}: {key} has type {type(r[key]).__name__}, "
                     f"want {typ}")
        for key in ("final_ppl", "final_nll"):
            if not math.isfinite(r[key]):
                fail(f"{method}: {key} is not finite ({r[key]})")
        for key in STATS_KEYS:
            if key not in r["stats"]:
                fail(f"{method}: stats missing {key!r}")
            if not math.isfinite(float(r["stats"][key])):
                fail(f"{method}: stats[{key}] not finite")
        codec = (payload["scenario"].get("spec", {}).get("method", {})
                 .get("extensions", {}).get("wire_codec", "none"))
        if codec != "none" and float(r["stats"]["compression_ratio"]) < 1.0:
            fail(f"{method}: wire_codec={codec} but compression_ratio "
                 f"{r['stats']['compression_ratio']:.3f} < 1.0 — the codec "
                 f"is INFLATING the wire")
        for rec in r["history"]:
            if not math.isfinite(rec["nll"]):
                fail(f"{method}: NaN/inf eval nll at step {rec['step']}")
        if method != "local" and not r["link_stats"]["links"]:
            fail(f"{method}: no per-link WAN traffic recorded")
        for link, rec in r["link_stats"]["links"].items():
            if "busy_fraction" not in rec:
                fail(f"{method}: link_stats[{link!r}] missing busy_fraction")
            bf = float(rec["busy_fraction"])
            if not math.isfinite(bf) or bf < 0.0:
                fail(f"{method}: link_stats[{link!r}] busy_fraction {bf} "
                     f"not a finite non-negative fraction")
    dyn = payload["scenario"].get("dynamics")
    if dyn and "cocodc" in payload["runs"]:
        stalled = any(r["stats"]["stall_seconds"] > 0 or
                      r["stats"]["n_retries"] > 0
                      for m, r in payload["runs"].items() if m != "local")
        if not stalled and ("hub_failure" in dyn or "diurnal" in dyn):
            fail("dynamics declared but no run recorded any stall/retry")


def compare_routed(payloads: dict) -> "list[str]":
    """Routed-vs-static stall comparison over `ROUTED_COMPARE` pairs present
    in `payloads` (scenario name -> payload). Returns failure strings for any
    shared method where the routed run's stall_fraction is NOT strictly below
    the static-route run's — the failover acceptance contract."""
    failures = []
    for routed_name, static_name in ROUTED_COMPARE.items():
        rp, sp = payloads.get(routed_name), payloads.get(static_name)
        if rp is None or sp is None:
            continue
        if rp.get("steps") != sp.get("steps"):
            # mismatched step budgets (e.g. only one side raised to the
            # fair-share 64-step floor in --smoke) make the normalized stall
            # fractions apples-to-oranges — skip rather than spuriously fail
            continue
        shared = [m for m in rp["runs"] if m in sp["runs"] and m != "local"]
        for m in shared:
            rf = rp["runs"][m]["stats"]["stall_fraction"]
            sf = sp["runs"][m]["stats"]["stall_fraction"]
            st = rp["runs"][m]["stats"]
            emit(f"sweep/{routed_name}/{m}/stall_vs_static", 0.0,
                 f"routed={rf*100:.1f}%;static={sf*100:.1f}%;"
                 f"reroutes={int(st['reroutes'])};"
                 f"hub_elections={int(st['hub_elections'])}")
            if rf >= sf:
                failures.append(
                    f"[{routed_name}] {m}: routed stall_fraction {rf:.4f} is "
                    f"not strictly below static {sf:.4f}")
    return failures


def compare_fairshare(payloads: dict) -> "list[str]":
    """Fair-share-vs-serial transfer-time comparison over `FAIRSHARE_COMPARE`
    pairs present in `payloads`. The fair-share run must cut the mean transfer
    sojourn (initiation -> delivery, queueing INCLUDED) by at least
    FAIRSHARE_MIN_GAIN relative to the serial-queue twin WITHOUT giving up
    convergence: its final perplexity may not sit more than FAIRSHARE_PPL_TOL
    ABOVE the serial twin's — faster transfers bought with convergence are
    not a win. The guard is one-sided on purpose: shorter sojourns mean
    fresher deliveries, so the fair-share run typically converges strictly
    BETTER at a fixed step budget (measured ~38% lower ppl at smoke scale),
    and an improvement must never fail the gate."""
    failures = []
    for fs_name, serial_name in FAIRSHARE_COMPARE.items():
        fp, sp = payloads.get(fs_name), payloads.get(serial_name)
        if fp is None or sp is None:
            continue
        if fp.get("steps") != sp.get("steps"):
            continue
        shared = [m for m in fp["runs"] if m in sp["runs"] and m != "local"]
        for m in shared:
            ft = float(fp["runs"][m]["stats"]["transfer_mean_s"])
            st_ = float(sp["runs"][m]["stats"]["transfer_mean_s"])
            fppl = float(fp["runs"][m]["final_ppl"])
            sppl = float(sp["runs"][m]["final_ppl"])
            rel_ppl = (fppl - sppl) / sppl      # > 0 = fairshare WORSE
            splits = int(fp["runs"][m]["stats"]["multipath_splits"])
            gain = 1.0 - ft / st_ if st_ > 0 else 0.0
            emit(f"sweep/{fs_name}/{m}/transfer_vs_serial", 0.0,
                 f"fairshare={ft:.2f}s;serial={st_:.2f}s;"
                 f"gain={gain*100:.1f}%;splits={splits};"
                 f"ppl_delta={rel_ppl*100:+.2f}%")
            if not ft <= (1.0 - FAIRSHARE_MIN_GAIN) * st_:
                failures.append(
                    f"[{fs_name}] {m}: fair-share transfer_mean_s {ft:.3f} is "
                    f"not >= {FAIRSHARE_MIN_GAIN*100:.0f}% below serial "
                    f"{st_:.3f} (gain {gain*100:.1f}%)")
            if rel_ppl > FAIRSHARE_PPL_TOL:
                failures.append(
                    f"[{fs_name}] {m}: final_ppl {fppl:.3f} is "
                    f"{rel_ppl*100:.1f}% WORSE than serial {sppl:.3f} "
                    f"(> {FAIRSHARE_PPL_TOL*100:.0f}%)")
    return failures


def with_codec(spec: ExperimentSpec, codec: str) -> ExperimentSpec:
    """`spec` re-dialed to ship `codec` on the wire, everything else equal."""
    ext = dataclasses.replace(spec.method.extensions, wire_codec=codec)
    return dataclasses.replace(
        spec, method=dataclasses.replace(spec.method, extensions=ext))


def run_frontier(sc: Scenario, methods=FRONTIER_METHODS,
                 codecs=FRONTIER_CODECS, steps: "int | None" = None) -> dict:
    """Codec x method frontier over one scenario: every run shares the
    scenario's seed/mesh/dynamics, only `wire_codec` varies. Keys are
    "method:codec"."""
    steps = steps or sc.steps
    runs = {}
    for method in methods:
        for codec in codecs:
            sc_c = dataclasses.replace(sc, spec=with_codec(sc.spec, codec))
            r = run_one(sc_c, method, steps)
            st = r["stats"]
            runs[f"{method}:{codec}"] = r
            emit(f"frontier/{sc.name}/{method}/{codec}",
                 r["host_s"] * 1e6 / steps,
                 f"ppl={r['final_ppl']:.2f};"
                 f"wire_MB={st['wire_bytes_total']/1e6:.1f};"
                 f"ratio={st['compression_ratio']:.2f}x;"
                 f"mean_transfer={st['mean_transfer_s']:.1f}s")
    return {"scenario": sc.name, "steps": steps, "methods": list(methods),
            "codecs": list(codecs), "runs": runs}


def validate_frontier(payload: dict) -> "list[str]":
    """The codec acceptance contract, per method in the frontier payload:
    the int8 run must move >= FRONTIER_MIN_RATIO x fewer bytes per element
    (its own raw/wire ratio — invariant to sync-count drift between runs),
    strictly shrink the mean transfer time vs the codec="none" twin, and
    land within FRONTIER_PPL_TOL of its perplexity. Any active codec with
    ratio < 1.0 fails outright."""
    failures = []
    name = payload["scenario"]
    for key, r in payload["runs"].items():
        method, codec = key.split(":")
        ratio = float(r["stats"]["compression_ratio"])
        if codec != "none" and ratio < 1.0:
            failures.append(f"[{name}] {key}: compression_ratio {ratio:.3f} "
                            f"< 1.0 under an active codec")
    for method in payload["methods"]:
        base = payload["runs"].get(f"{method}:none")
        int8 = payload["runs"].get(f"{method}:int8")
        if base is None or int8 is None:
            continue
        ratio = float(int8["stats"]["compression_ratio"])
        bt = float(base["stats"]["mean_transfer_s"])
        it = float(int8["stats"]["mean_transfer_s"])
        rel = abs(int8["final_ppl"] - base["final_ppl"]) / base["final_ppl"]
        if ratio < FRONTIER_MIN_RATIO:
            failures.append(f"[{name}] {method}: int8 compression_ratio "
                            f"{ratio:.2f}x < {FRONTIER_MIN_RATIO}x")
        if not it < bt:
            failures.append(f"[{name}] {method}: int8 mean_transfer_s {it:.2f}"
                            f" not strictly below codec=none {bt:.2f}")
        if rel > FRONTIER_PPL_TOL:
            failures.append(f"[{name}] {method}: int8 ppl "
                            f"{int8['final_ppl']:.3f} departs codec=none "
                            f"{base['final_ppl']:.3f} by {rel*100:.1f}% "
                            f"(> {FRONTIER_PPL_TOL*100:.0f}%)")
    return failures


def main(argv=None) -> int:
    scenarios = _grid_scenarios()
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default=None,
                    choices=[s.name for s in scenarios],
                    help="run a single scenario from the spec grid")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the per-scenario step budget")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny grid incl. the routed hub-failure "
                         "compare and the fairshare-vs-serial transfer-time "
                         "compare; exits 1 on schema drift, NaN metrics, a "
                         "routed run that does not beat its static twin's "
                         "stall fraction, or a fair-share run that does not "
                         "cut mean transfer time >= 20%% without giving up "
                         "ppl (> 2%% worse than serial fails)")
    ap.add_argument("--frontier", action="store_true",
                    help="run ONLY the convergence-vs-bandwidth frontier "
                         "(codec x method over the diurnal hub-failure mesh); "
                         "with --smoke: int8-vs-none cocodc acceptance checks "
                         "at smoke scale")
    args = ap.parse_args(argv)

    by_name = {s.name: s for s in scenarios}
    if args.frontier:
        grid = []
    elif args.smoke:
        # --steps may shorten the quick scenarios but never the routed-vs-
        # static or fairshare-vs-serial pairs below their grid budgets:
        # cutting a run before the outage window would fail the strict
        # stall/transfer comparisons spuriously
        compare_names = (set(ROUTED_COMPARE) | set(ROUTED_COMPARE.values()) |
                         set(FAIRSHARE_COMPARE) |
                         set(FAIRSHARE_COMPARE.values()))
        grid = [(by_name[name], methods,
                 max(args.steps, steps) if args.steps and name
                 in compare_names else (args.steps or steps))
                for name, methods, steps in SMOKE_GRID]
    else:
        names = [args.scenario] if args.scenario else [s.name
                                                       for s in scenarios]
        grid = [(by_name[n], METHODS, args.steps) for n in names]

    summary = {}
    failures = []
    payloads = {}
    for sc, methods, steps in grid:
        payload = run_scenario(sc, methods=methods, steps=steps)
        payloads[sc.name] = payload
        try:
            validate_payload(payload, sc.name)
        except AssertionError as e:
            failures.append(str(e))
            print(f"SCHEMA FAIL {e}", file=sys.stderr, flush=True)
        save_json(os.path.join("sweep", sc.name), payload)
        summary[sc.name] = {
            "note": sc.note, "n": sc.n, "steps": payload["steps"],
            "routing": sc.routing,
            "target_ppl": payload["target_ppl"],
            "steps_to_target": {m: r["steps_to_target"]
                                for m, r in payload["runs"].items()},
            "stall_fraction": {m: r["stats"]["stall_fraction"]
                               for m, r in payload["runs"].items()},
            "wall_clock_s": {m: r["stats"]["wall_clock_s"]
                             for m, r in payload["runs"].items()},
            "reroutes": {m: r["stats"]["reroutes"]
                         for m, r in payload["runs"].items()},
            "hub_elections": {m: r["stats"]["hub_elections"]
                              for m, r in payload["runs"].items()},
        }
        stt = summary[sc.name]["steps_to_target"]
        if stt.get("cocodc") and stt.get("streaming"):
            emit(f"sweep/{sc.name}/cocodc_vs_streaming", 0.0,
                 f"{100 * (1 - stt['cocodc'] / stt['streaming']):.1f}%")
    routed_failures = compare_routed(payloads)
    fairshare_failures = compare_fairshare(payloads)
    if args.smoke:
        failures.extend(routed_failures)
        failures.extend(fairshare_failures)
    for f in routed_failures:
        print(f"ROUTED COMPARE FAIL {f}", file=sys.stderr, flush=True)
    for f in fairshare_failures:
        print(f"FAIRSHARE COMPARE FAIL {f}", file=sys.stderr, flush=True)
    if args.frontier:
        sc = by_name[FRONTIER_SCENARIO]
        fsteps = args.steps or (12 if args.smoke else None)
        fmethods = ("cocodc",) if args.smoke else FRONTIER_METHODS
        fcodecs = ("none", "int8") if args.smoke else FRONTIER_CODECS
        fpayload = run_frontier(sc, methods=fmethods, codecs=fcodecs,
                                steps=fsteps)
        save_json("sweep_frontier", fpayload)
        frontier_failures = validate_frontier(fpayload)
        failures.extend(frontier_failures)
        for f in frontier_failures:
            print(f"FRONTIER FAIL {f}", file=sys.stderr, flush=True)
    if summary:   # a pure --frontier run must not clobber the grid summary
        save_json("sweep_summary", summary)
    if failures:
        print(f"{len(failures)} failure(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
